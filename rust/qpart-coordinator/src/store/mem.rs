//! The in-memory terminal layer of the store stack.

use super::{Column, Identity, Layer, ReadLayer, WriteLayer};
use std::collections::HashMap;

/// A plain in-memory byte store: one map per [`Column`]. This is the
/// terminal layer everything else composes over — the segment log keeps
/// one as its live-state mirror, and tests drive the trait stack against
/// it directly.
#[derive(Debug, Default)]
pub struct MemLayer {
    cols: [HashMap<Vec<u8>, Vec<u8>>; Column::ALL.len()],
}

impl MemLayer {
    pub fn new() -> MemLayer {
        MemLayer::default()
    }

    /// Every live `(key, value)` of `col`, sorted by key — the
    /// deterministic snapshot the log's compaction and warm replay use.
    pub fn sorted_entries(&self, col: Column) -> Vec<(Vec<u8>, Vec<u8>)> {
        let mut v: Vec<(Vec<u8>, Vec<u8>)> =
            self.cols[col.index()].iter().map(|(k, val)| (k.clone(), val.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

impl Layer for MemLayer {
    type Base = Identity;
}

impl ReadLayer for MemLayer {
    fn has(&self, col: Column, key: &[u8]) -> bool {
        self.cols[col.index()].contains_key(key)
    }

    fn get(&self, col: Column, key: &[u8]) -> Option<Vec<u8>> {
        self.cols[col.index()].get(key).cloned()
    }

    fn for_each(&self, col: Column, f: &mut dyn FnMut(&[u8], &[u8]) -> bool) {
        for (k, v) in &self.cols[col.index()] {
            if !f(k, v) {
                return;
            }
        }
    }

    fn len(&self, col: Column) -> usize {
        self.cols[col.index()].len()
    }
}

impl WriteLayer for MemLayer {
    fn put(&mut self, col: Column, key: &[u8], value: &[u8]) {
        self.cols[col.index()].insert(key.to_vec(), value.to_vec());
    }

    fn delete(&mut self, col: Column, key: &[u8]) {
        self.cols[col.index()].remove(key);
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Shared property suite: any [`WriteLayer`] must round-trip
    /// put/get/delete through the trait stack. Reused by the temporal
    /// overlay's and the segment log's tests so all three layers are
    /// held to identical semantics.
    pub fn exercise_layer(layer: &mut dyn WriteLayer) {
        for col in Column::ALL {
            assert!(!layer.has(col, b"k"), "{col:?} starts empty");
            assert_eq!(layer.get(col, b"k"), None);
        }
        layer.put(Column::Decision, b"k", b"v1");
        assert!(layer.has(Column::Decision, b"k"));
        assert!(!layer.has(Column::Reply, b"k"), "columns are disjoint namespaces");
        assert_eq!(layer.get(Column::Decision, b"k"), Some(b"v1".to_vec()));
        // replace
        layer.put(Column::Decision, b"k", b"v2");
        assert_eq!(layer.get(Column::Decision, b"k"), Some(b"v2".to_vec()));
        assert_eq!(layer.len(Column::Decision), 1);
        // second key + iteration
        layer.put(Column::Decision, b"k2", b"w");
        let mut seen = Vec::new();
        layer.for_each(Column::Decision, &mut |k, v| {
            seen.push((k.to_vec(), v.to_vec()));
            true
        });
        seen.sort();
        assert_eq!(seen, vec![(b"k".to_vec(), b"v2".to_vec()), (b"k2".to_vec(), b"w".to_vec())]);
        // early-stop iteration visits exactly one entry
        let mut n = 0;
        layer.for_each(Column::Decision, &mut |_, _| {
            n += 1;
            false
        });
        assert_eq!(n, 1);
        // delete (and deleting an absent key is a no-op)
        layer.delete(Column::Decision, b"k");
        assert!(!layer.has(Column::Decision, b"k"));
        layer.delete(Column::Decision, b"missing");
        assert_eq!(layer.len(Column::Decision), 1);
        layer.delete(Column::Decision, b"k2");
        assert!(layer.is_empty(Column::Decision));
    }

    #[test]
    fn mem_layer_satisfies_the_stack_contract() {
        let mut mem = MemLayer::new();
        exercise_layer(&mut mem);
    }

    #[test]
    fn sorted_entries_is_deterministic() {
        let mut mem = MemLayer::new();
        mem.put(Column::Reply, b"b", b"2");
        mem.put(Column::Reply, b"a", b"1");
        mem.put(Column::Reply, b"c", b"3");
        let entries = mem.sorted_entries(Column::Reply);
        let keys: Vec<&[u8]> = entries.iter().map(|(k, _)| k.as_slice()).collect();
        assert_eq!(keys, vec![b"a".as_slice(), b"b", b"c"]);
    }
}
