//! Protocol messages (manual JSON mapping, tagged by a `"type"` field).
//!
//! Serving flow (two-phase, mirroring Fig. 1/2 of the paper):
//!
//! 1. device → `infer` (model, accuracy budget, channel + compute profile)
//! 2. server → `segment` (the quantized, bit-packed model segment + the
//!    chosen pattern) — the downlink the paper's Eq. 14 charges for
//! 3. device runs layers `1..=p` locally, → `activation` (quantized,
//!    bit-packed boundary activation) — the uplink
//! 4. server finishes layers `p+1..=L`, → `result` (prediction + logits)
//!
//! `simulate` collapses 1–4 into one message for load generation: the
//! server plays both roles and reports the cost breakdown.
//!
//! `hello` negotiates per-session framing: a device that speaks binary
//! frames asks for them once, and the server answers with what it will
//! actually use for segment replies on this connection.
//!
//! [`EncodedSegmentBody`] is the serving hot path's unit of reuse: the
//! session-independent part of a segment reply, serialized **once** (JSON
//! body, binary header, and raw blob) and then stamped per connection
//! with the session id and the request's objective value.

use crate::base64;
use crate::frame::{BinaryFrame, Frame, BINARY_MAGIC, MAX_FRAME_BYTES};
use qpart_core::json::{parse, Value};
use qpart_core::{Error, Result};
use std::sync::Arc;

/// Requests a client can send.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    ListModels,
    Stats,
    Hello(HelloRequest),
    Infer(InferRequest),
    Activation(ActivationUpload),
    Simulate(SimulateRequest),
}

/// Framing negotiation (handled by the connection front-end, never queued).
#[derive(Debug, Clone, PartialEq)]
pub struct HelloRequest {
    /// Device asks for length-prefixed binary segment frames.
    pub binary_frames: bool,
    /// Device asks for request tracing on this connection. When granted,
    /// the server echoes the trace id in `segment`/`result` replies and
    /// the timeline is queryable at `/trace?id=` on the metrics listener.
    /// Serialized only when true, so untraced hellos are byte-identical
    /// to older peers (absent field ≡ old peer).
    pub trace: bool,
    /// Device-class fairness weight (`DeviceClass.weight`): the server
    /// scales this connection's fair-queuing token-bucket rate by it, so
    /// a rare class is not crowded out by a hot class of polite devices.
    /// Clamped server-side; `1.0` means the base `--fair-rate`.
    /// Serialized only when ≠ 1.0, so default hellos are byte-identical
    /// to older peers (absent field ≡ old peer).
    pub weight: f64,
    /// Device-class label (e.g. `"phone"`, `"sensor"`): purely
    /// observational — the server breaks its throttle/shed/degrade
    /// counters out per class so the fleet's view can be cross-checked
    /// against the clients'. Serialized only when non-empty, so unlabeled
    /// hellos stay byte-identical to older peers (absent field ≡ old peer).
    pub class: String,
}

impl Default for HelloRequest {
    fn default() -> HelloRequest {
        HelloRequest { binary_frames: false, trace: false, weight: 1.0, class: String::new() }
    }
}

/// Paper Algorithm 2's Require-tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub model: String,
    /// Max acceptable accuracy degradation `a` (fraction).
    pub accuracy_budget: f64,
    /// Reported channel capacity `r` (bit/s).
    pub channel_capacity_bps: f64,
    /// Transmit power `π` (W).
    pub tx_power_w: f64,
    /// `f_local` (Hz).
    pub clock_hz: f64,
    /// `γ_local` (cycles/MAC).
    pub cycles_per_mac: f64,
    /// `κ` energy-efficiency parameter.
    pub kappa: f64,
    /// Device memory capacity (bits).
    pub memory_bits: u64,
    /// Objective weights ω/τ/η (None → server defaults).
    pub weights: Option<(f64, f64, f64)>,
    /// Optional soft deadline in milliseconds, measured from server
    /// receipt of the request. A request still waiting in the scheduler
    /// queue past its deadline is shed at drain time with a
    /// `deadline_exceeded` error line instead of being planned — by then
    /// the device has given up, so serving it only adds queue pressure.
    ///
    /// Wire spec: serialized as an integer `deadline_ms` field only when
    /// present, so deadline-less requests are byte-identical to older
    /// peers (absent field ≡ old peer, which is never shed).
    pub deadline_ms: Option<u64>,
}

/// Quantized boundary activation upload.
#[derive(Debug, Clone, PartialEq)]
pub struct ActivationUpload {
    pub session: u64,
    pub bits: u8,
    pub qmin: f32,
    pub step: f32,
    pub dims: Vec<usize>,
    /// Bit-packed codes.
    pub packed: Vec<u8>,
}

impl ActivationUpload {
    /// Encode as a binary request frame: (JSON header, raw packed blob).
    /// The uplink sibling of `InferReply::to_binary` — the packed codes
    /// ship without base64 expansion or JSON escaping.
    pub fn to_binary(&self) -> (String, Vec<u8>) {
        let v = Value::obj([
            ("type", "activation".into()),
            ("session", self.session.into()),
            ("bits", (self.bits as u64).into()),
            ("qmin", (self.qmin as f64).into()),
            ("step", (self.step as f64).into()),
            ("dims", dims_json(&self.dims)),
            ("packed_off", 0usize.into()),
            ("packed_nbytes", self.packed.len().into()),
        ]);
        (v.to_string_compact(), self.packed.clone())
    }

    /// Decode a binary request frame (header + blob) back into an upload.
    pub fn from_binary(header: &str, blob: &[u8]) -> Result<ActivationUpload> {
        let v = parse(header)?;
        if v.req_str("type")? != "activation" {
            return Err(Error::schema("type", "binary frame is not an activation"));
        }
        let off = v.req_usize("packed_off")?;
        let nbytes = v.req_usize("packed_nbytes")?;
        Ok(ActivationUpload {
            session: v.req_u64("session")?,
            bits: v.req_u64("bits")? as u8,
            qmin: v.req_f64("qmin")? as f32,
            step: v.req_f64("step")? as f32,
            dims: usize_arr(&v, "dims")?,
            packed: blob_slice(blob, off, nbytes, "packed_off")?.to_vec(),
        })
    }
}

/// One-shot request: the server simulates the device side too.
#[derive(Debug, Clone, PartialEq)]
pub struct SimulateRequest {
    pub req: InferRequest,
    /// Raw f32 input (little-endian bytes).
    pub input: Vec<f32>,
    pub input_dims: Vec<usize>,
}

/// Responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Models(Vec<ModelInfo>),
    Stats(Value),
    Hello(HelloReply),
    Segment(InferReply),
    Result(ResultReply),
    Error(ErrorReply),
}

/// Answer to `hello`: the framing the server will use on this connection.
#[derive(Debug, Clone, PartialEq)]
pub struct HelloReply {
    /// Segment replies on this connection will use binary frames.
    pub binary_frames: bool,
    /// Granted trace id for this connection (`Some` only when the hello
    /// asked for tracing and the server supports it). Replies on this
    /// connection echo the same id in their `trace` field. Absent on the
    /// wire when not granted, so old peers see unchanged bytes.
    pub trace: Option<u64>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub arch: String,
    pub dataset: String,
    pub layers: usize,
    pub params: u64,
    pub test_accuracy: f64,
}

/// The chosen pattern, reported back to the device.
#[derive(Debug, Clone, PartialEq)]
pub struct PatternInfo {
    pub partition: usize,
    pub weight_bits: Vec<u8>,
    pub activation_bits: u8,
    pub accuracy_level: f64,
    pub predicted_degradation: f64,
    pub objective: f64,
}

/// One quantized layer on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerBlob {
    pub layer: usize,
    pub bits: u8,
    pub w_dims: Vec<usize>,
    pub w_qmin: f32,
    pub w_step: f32,
    pub w_packed: Vec<u8>,
    pub b_qmin: f32,
    pub b_step: f32,
    pub b_len: usize,
    pub b_packed: Vec<u8>,
}

/// The shipped model segment.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SegmentBlob {
    pub layers: Vec<LayerBlob>,
}

/// Phase-1 reply: session + pattern + segment.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReply {
    pub session: u64,
    /// Echoed trace id (hello-negotiated tracing only; absent otherwise).
    pub trace: Option<u64>,
    /// Brownout marker: the server planned this request at a coarser
    /// accuracy level than its nominal Algorithm-2 choice (still within
    /// the request's accuracy budget — degradation never exceeds it).
    /// Serialized as `"degraded":true` only when set, so non-degraded
    /// replies stay byte-identical to older peers.
    pub degraded: bool,
    pub model: String,
    pub pattern: PatternInfo,
    pub segment: SegmentBlob,
}

/// Phase-2 (or simulate) reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultReply {
    pub session: u64,
    /// Echoed trace id (hello-negotiated tracing only; absent otherwise).
    pub trace: Option<u64>,
    pub prediction: i32,
    pub logits: Vec<f64>,
    /// Cost breakdown (simulate only): the Eq. 17 terms.
    pub costs: Option<Value>,
    /// Server-side wall-clock microseconds spent on this request.
    pub server_us: u64,
}

/// Soft error line. Notable codes in the overload/failure paths:
///
/// - `"deadline_exceeded"` — the request's [`InferRequest::deadline_ms`]
///   elapsed while it waited in the scheduler queue; it was shed before
///   planning. Retry with a fresh deadline (ideally after backoff).
/// - `"draining"` — the server received SIGTERM/SIGINT and refuses new
///   connections while it finishes in-flight work; reconnect elsewhere.
/// - `"overloaded"` / `"throttled"` — queue full / fair-queue token
///   exhausted; back off and retry on the same connection.
/// - `"internal"` — a worker failed (e.g. panicked) while serving the
///   request; the connection survives and may retry.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReply {
    pub code: String,
    pub message: String,
}

// ---------------------------------------------------------------------------
// f32 <-> bytes helpers
// ---------------------------------------------------------------------------

/// Encode f32s as base64(LE bytes).
pub fn f32s_to_b64(xs: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    base64::encode(&bytes)
}

/// Decode base64(LE bytes) to f32s.
pub fn b64_to_f32s(s: &str) -> Result<Vec<f32>> {
    let bytes = base64::decode(s).map_err(|e| Error::InvalidArg(format!("base64: {e}")))?;
    if bytes.len() % 4 != 0 {
        return Err(Error::InvalidArg("f32 payload not a multiple of 4 bytes".into()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn usize_arr(v: &Value, key: &str) -> Result<Vec<usize>> {
    v.req_arr(key)?
        .iter()
        .map(|x| {
            x.as_i64()
                .and_then(|i| usize::try_from(i).ok())
                .ok_or_else(|| Error::schema(key, "expected index array"))
        })
        .collect()
}

fn dims_json(dims: &[usize]) -> Value {
    Value::Arr(dims.iter().map(|&d| d.into()).collect())
}

fn bytes_field(v: &Value, key: &str) -> Result<Vec<u8>> {
    base64::decode(v.req_str(key)?).map_err(|e| Error::schema(key, format!("base64: {e}")))
}

/// Optional echoed `trace` id (absent field ≡ untraced peer).
fn opt_trace(v: &Value) -> Option<u64> {
    v.get("trace").and_then(Value::as_i64).and_then(|x| u64::try_from(x).ok())
}

// ---------------------------------------------------------------------------
// Request (de)serialization
// ---------------------------------------------------------------------------

impl Request {
    pub fn to_json(&self) -> Value {
        match self {
            Request::Ping => Value::obj([("type", "ping".into())]),
            Request::ListModels => Value::obj([("type", "list_models".into())]),
            Request::Stats => Value::obj([("type", "stats".into())]),
            Request::Hello(h) => {
                let mut v = Value::obj([
                    ("type", "hello".into()),
                    ("binary_frames", h.binary_frames.into()),
                ]);
                // only serialized when asked for: untraced hellos stay
                // byte-identical to pre-trace peers
                if h.trace {
                    v.set("trace", true.into());
                }
                // same byte-compat story for the fairness weight: the
                // default class is indistinguishable from an old peer
                if h.weight != 1.0 {
                    v.set("weight", h.weight.into());
                }
                // and for the observational class label
                if !h.class.is_empty() {
                    v.set("class", h.class.as_str().into());
                }
                v
            }
            Request::Infer(r) => {
                let mut v = r.to_json();
                v.set("type", "infer".into());
                v
            }
            Request::Activation(a) => Value::obj([
                ("type", "activation".into()),
                ("session", a.session.into()),
                ("bits", (a.bits as u64).into()),
                ("qmin", (a.qmin as f64).into()),
                ("step", (a.step as f64).into()),
                ("dims", dims_json(&a.dims)),
                ("packed", base64::encode(&a.packed).into()),
            ]),
            Request::Simulate(s) => {
                let mut v = s.req.to_json();
                v.set("type", "simulate".into());
                v.set("input", f32s_to_b64(&s.input).into());
                v.set("input_dims", dims_json(&s.input_dims));
                v
            }
        }
    }

    pub fn from_json(v: &Value) -> Result<Request> {
        match v.req_str("type")? {
            "ping" => Ok(Request::Ping),
            "list_models" => Ok(Request::ListModels),
            "stats" => Ok(Request::Stats),
            "hello" => Ok(Request::Hello(HelloRequest {
                binary_frames: v.opt_bool("binary_frames", false),
                trace: v.opt_bool("trace", false),
                weight: v.opt_f64("weight", 1.0),
                class: v.get("class").and_then(Value::as_str).unwrap_or("").to_string(),
            })),
            "infer" => Ok(Request::Infer(InferRequest::from_json(v)?)),
            "activation" => Ok(Request::Activation(ActivationUpload {
                session: v.req_u64("session")?,
                bits: v.req_u64("bits")? as u8,
                qmin: v.req_f64("qmin")? as f32,
                step: v.req_f64("step")? as f32,
                dims: usize_arr(v, "dims")?,
                packed: bytes_field(v, "packed")?,
            })),
            "simulate" => Ok(Request::Simulate(SimulateRequest {
                req: InferRequest::from_json(v)?,
                input: b64_to_f32s(v.req_str("input")?)?,
                input_dims: usize_arr(v, "input_dims")?,
            })),
            other => Err(Error::schema("type", format!("unknown request '{other}'"))),
        }
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_line(line: &str) -> Result<Request> {
        Request::from_json(&parse(line)?)
    }

    /// Decode a request frame of either kind. Binary request frames carry
    /// `activation` uploads (the only large client payload); the header's
    /// `type` field dispatches, mirroring `Response::from_frame`.
    pub fn from_frame(frame: &Frame) -> Result<Request> {
        match frame {
            Frame::Json(line) => Request::from_line(line),
            Frame::Binary(BinaryFrame { header, blob }) => {
                Ok(Request::Activation(ActivationUpload::from_binary(header, blob)?))
            }
        }
    }
}

impl InferRequest {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj([
            ("model", self.model.as_str().into()),
            ("accuracy_budget", self.accuracy_budget.into()),
            ("channel_capacity_bps", self.channel_capacity_bps.into()),
            ("tx_power_w", self.tx_power_w.into()),
            ("clock_hz", self.clock_hz.into()),
            ("cycles_per_mac", self.cycles_per_mac.into()),
            ("kappa", self.kappa.into()),
            ("memory_bits", self.memory_bits.into()),
        ]);
        if let Some((o, t, e)) = self.weights {
            v.set("weights", Value::num_arr(&[o, t, e]));
        }
        // only serialized when set: deadline-less requests stay
        // byte-identical to pre-deadline peers
        if let Some(d) = self.deadline_ms {
            v.set("deadline_ms", d.into());
        }
        v
    }

    pub fn from_json(v: &Value) -> Result<InferRequest> {
        let weights = match v.get("weights") {
            Some(w) => {
                let arr = w
                    .as_arr()
                    .ok_or_else(|| Error::schema("weights", "expected [omega, tau, eta]"))?;
                if arr.len() != 3 {
                    return Err(Error::schema("weights", "expected 3 numbers"));
                }
                Some((
                    arr[0].as_f64().ok_or_else(|| Error::schema("weights", "numbers"))?,
                    arr[1].as_f64().ok_or_else(|| Error::schema("weights", "numbers"))?,
                    arr[2].as_f64().ok_or_else(|| Error::schema("weights", "numbers"))?,
                ))
            }
            None => None,
        };
        Ok(InferRequest {
            model: v.req_str("model")?.to_string(),
            accuracy_budget: v.req_f64("accuracy_budget")?,
            channel_capacity_bps: v.req_f64("channel_capacity_bps")?,
            tx_power_w: v.opt_f64("tx_power_w", 1.0),
            clock_hz: v.opt_f64("clock_hz", 200e6),
            cycles_per_mac: v.opt_f64("cycles_per_mac", 5.0),
            kappa: v.opt_f64("kappa", 3e-27),
            memory_bits: v.opt_f64("memory_bits", 2.147_483_648e9) as u64,
            weights,
            deadline_ms: v
                .get("deadline_ms")
                .and_then(Value::as_i64)
                .and_then(|x| u64::try_from(x).ok()),
        })
    }
}

// ---------------------------------------------------------------------------
// Segment layer (de)serialization — shared by the JSON line, the binary
// frame, and the encoded-reply cache
// ---------------------------------------------------------------------------

/// One layer in the JSON (base64) form.
fn layer_json(l: &LayerBlob) -> Value {
    Value::obj([
        ("layer", l.layer.into()),
        ("bits", (l.bits as u64).into()),
        ("w_dims", dims_json(&l.w_dims)),
        ("w_qmin", (l.w_qmin as f64).into()),
        ("w_step", (l.w_step as f64).into()),
        ("w_packed", base64::encode(&l.w_packed).into()),
        ("b_qmin", (l.b_qmin as f64).into()),
        ("b_step", (l.b_step as f64).into()),
        ("b_len", l.b_len.into()),
        ("b_packed", base64::encode(&l.b_packed).into()),
    ])
}

/// The `layers` array in the JSON (base64) form.
fn layers_json(layers: &[LayerBlob]) -> Value {
    Value::Arr(layers.iter().map(layer_json).collect())
}

/// The `layers` array in the binary form (blob offsets instead of base64)
/// plus the blob itself: each layer's packed weights then packed bias,
/// appended in order.
fn layers_binary(layers: &[LayerBlob]) -> (Value, Vec<u8>) {
    let total: usize = layers.iter().map(|l| l.w_packed.len() + l.b_packed.len()).sum();
    let mut blob = Vec::with_capacity(total);
    let metas = layers
        .iter()
        .map(|l| {
            let w_off = blob.len();
            blob.extend_from_slice(&l.w_packed);
            let b_off = blob.len();
            blob.extend_from_slice(&l.b_packed);
            Value::obj([
                ("layer", l.layer.into()),
                ("bits", (l.bits as u64).into()),
                ("w_dims", dims_json(&l.w_dims)),
                ("w_qmin", (l.w_qmin as f64).into()),
                ("w_step", (l.w_step as f64).into()),
                ("w_off", w_off.into()),
                ("w_nbytes", l.w_packed.len().into()),
                ("b_qmin", (l.b_qmin as f64).into()),
                ("b_step", (l.b_step as f64).into()),
                ("b_len", l.b_len.into()),
                ("b_off", b_off.into()),
                ("b_nbytes", l.b_packed.len().into()),
            ])
        })
        .collect();
    (Value::Arr(metas), blob)
}

/// Slice `blob[off .. off + len]` with bound checks.
fn blob_slice<'a>(blob: &'a [u8], off: usize, len: usize, key: &str) -> Result<&'a [u8]> {
    off.checked_add(len)
        .and_then(|end| blob.get(off..end))
        .ok_or_else(|| Error::schema(key, format!("blob range {off}+{len} out of bounds")))
}

impl InferReply {
    /// Encode as a binary frame: (JSON header, raw blob).
    pub fn to_binary(&self) -> (String, Vec<u8>) {
        let (metas, blob) = layers_binary(&self.segment.layers);
        let mut fields = vec![
            ("type", Value::from("segment")),
            ("session", self.session.into()),
        ];
        if let Some(t) = self.trace {
            fields.push(("trace", t.into()));
        }
        if self.degraded {
            fields.push(("degraded", true.into()));
        }
        fields.push(("model", self.model.as_str().into()));
        fields.push(("pattern", self.pattern.to_json()));
        let mut v = Value::obj(fields);
        v.set("layers", metas);
        (v.to_string_compact(), blob)
    }

    /// Decode a binary frame (header + blob) back into a reply.
    pub fn from_binary(header: &str, blob: &[u8]) -> Result<InferReply> {
        let v = parse(header)?;
        if v.req_str("type")? != "segment" {
            return Err(Error::schema("type", "binary frame is not a segment"));
        }
        let mut layers = Vec::new();
        for l in v.req_arr("layers")? {
            let w_off = l.req_usize("w_off")?;
            let w_nbytes = l.req_usize("w_nbytes")?;
            let b_off = l.req_usize("b_off")?;
            let b_nbytes = l.req_usize("b_nbytes")?;
            layers.push(LayerBlob {
                layer: l.req_usize("layer")?,
                bits: l.req_u64("bits")? as u8,
                w_dims: usize_arr(l, "w_dims")?,
                w_qmin: l.req_f64("w_qmin")? as f32,
                w_step: l.req_f64("w_step")? as f32,
                w_packed: blob_slice(blob, w_off, w_nbytes, "w_off")?.to_vec(),
                b_qmin: l.req_f64("b_qmin")? as f32,
                b_step: l.req_f64("b_step")? as f32,
                b_len: l.req_usize("b_len")?,
                b_packed: blob_slice(blob, b_off, b_nbytes, "b_off")?.to_vec(),
            });
        }
        Ok(InferReply {
            session: v.req_u64("session")?,
            trace: opt_trace(&v),
            degraded: v.opt_bool("degraded", false),
            model: v.req_str("model")?.to_string(),
            pattern: PatternInfo::from_json(v.req("pattern")?)?,
            segment: SegmentBlob { layers },
        })
    }
}

/// The session-independent part of a segment reply, fully serialized once.
///
/// Coalesced requests and the coordinator's encoded-reply cache share one
/// of these per `(model, accuracy level, partition)`; stamping a reply for
/// a specific connection is a cheap string splice of the session id and
/// the request's Eq. 17 objective value — no re-quantization, no
/// re-base64, no re-escaping of the multi-megabyte payload.
#[derive(Debug)]
pub struct EncodedSegmentBody {
    model: String,
    /// Pattern with a placeholder objective (the objective is per-request).
    pattern: PatternInfo,
    /// Decoded form, for in-process callers that need the actual blobs.
    segment: SegmentBlob,
    /// `model` as a JSON string literal (quoted + escaped).
    model_json: String,
    /// The `layers` array, JSON/base64 form, serialized compactly. Held
    /// as shared UTF-8 bytes so front-ends can queue it for egress
    /// without copying the multi-megabyte body per connection.
    layers_json: Arc<[u8]>,
    /// The `layers` array, binary-header form (blob offsets).
    bin_layers_json: String,
    /// Raw packed payload bytes the binary header points into. Shared
    /// for the same zero-copy reason as `layers_json`.
    blob: Arc<[u8]>,
}

/// Closing bytes of a JSON-framed segment reply built from splice parts:
/// `json_frame_head + layers_json_shared + JSON_FRAME_TAIL` (the object's
/// closing brace plus the JSON-lines newline).
pub const JSON_FRAME_TAIL: &[u8] = b"}\n";

impl EncodedSegmentBody {
    /// Serialize `segment` once in both wire forms. `pattern.objective` is
    /// ignored — replies stamp the per-request objective at send time.
    pub fn new(model: &str, pattern: PatternInfo, segment: SegmentBlob) -> EncodedSegmentBody {
        let layers = layers_json(&segment.layers).to_string_compact();
        let (bin_metas, blob) = layers_binary(&segment.layers);
        EncodedSegmentBody {
            model_json: Value::Str(model.to_string()).to_string_compact(),
            model: model.to_string(),
            pattern: PatternInfo { objective: f64::NAN, ..pattern },
            segment,
            layers_json: layers.into_bytes().into(),
            bin_layers_json: bin_metas.to_string_compact(),
            blob: blob.into(),
        }
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// The pattern (objective is NaN — it is stamped per request).
    pub fn pattern(&self) -> &PatternInfo {
        &self.pattern
    }

    /// The decoded segment (for in-process callers).
    pub fn segment(&self) -> &SegmentBlob {
        &self.segment
    }

    /// Raw blob for [`crate::frame::write_binary_frame`].
    pub fn blob(&self) -> &[u8] {
        &self.blob
    }

    /// Shared handle on the raw blob: an egress queue can hold this
    /// instead of copying [`Self::blob`] into its own buffer.
    pub fn blob_shared(&self) -> Arc<[u8]> {
        Arc::clone(&self.blob)
    }

    /// Shared handle on the serialized `layers` JSON (the bulk of a
    /// JSON-framed segment reply), for the same zero-copy egress path.
    pub fn layers_json_shared(&self) -> Arc<[u8]> {
        Arc::clone(&self.layers_json)
    }

    /// The `layers` JSON as `&str` (it is serialized UTF-8 by construction).
    fn layers_json_str(&self) -> &str {
        std::str::from_utf8(&self.layers_json).expect("layers_json is serialized JSON")
    }

    /// Packed wire payload size in bytes (weights + biases).
    pub fn wire_bytes(&self) -> u64 {
        self.blob.len() as u64
    }

    /// Bytes of encoding work a cache hit skips, measured as the
    /// serialized JSON body length. Binary-framed replies reuse the (raw,
    /// ~25% smaller) blob instead, so as a "bytes saved" measure this is
    /// an upper bound on those sessions.
    pub fn encoded_len(&self) -> u64 {
        self.layers_json.len() as u64
    }

    /// Approximate resident size (all cached serializations + the blobs),
    /// the unit the encoded-reply cache's byte budget counts.
    pub fn cost_bytes(&self) -> usize {
        // blob appears twice: once raw, once as the decoded segment's
        // packed vectors; 128 covers struct overhead and small strings
        self.layers_json.len() + self.bin_layers_json.len() + 2 * self.blob.len() + 128
    }

    fn pattern_json(&self, objective: f64) -> String {
        let mut p = self.pattern.clone();
        p.objective = objective;
        p.to_json().to_string_compact()
    }

    /// The complete JSON-lines reply for one session (byte-identical to
    /// `Response::Segment(..).to_line()`).
    pub fn json_line(&self, session: u64, objective: f64) -> String {
        self.json_line_traced(session, objective, None)
    }

    /// [`Self::json_line`] with an optional echoed trace id spliced in
    /// right after the session id. `trace: None` is byte-identical to
    /// `json_line` — untraced connections pay nothing.
    pub fn json_line_traced(&self, session: u64, objective: f64, trace: Option<u64>) -> String {
        self.json_line_stamped(session, objective, trace, false)
    }

    /// [`Self::json_line_traced`] plus the brownout `degraded` marker.
    /// `degraded: false` is byte-identical to the untraced/unmarked
    /// stampers — non-degraded replies pay nothing.
    pub fn json_line_stamped(
        &self,
        session: u64,
        objective: f64,
        trace: Option<u64>,
        degraded: bool,
    ) -> String {
        format!(
            "{{\"type\":\"segment\",\"session\":{session},{}{}\"model\":{},\"pattern\":{},\"layers\":{}}}",
            trace_splice(trace),
            degraded_splice(degraded),
            self.model_json,
            self.pattern_json(objective),
            self.layers_json_str(),
        )
    }

    /// The per-connection prefix of a JSON-framed segment reply: the
    /// concatenation `json_frame_head + layers_json_shared + JSON_FRAME_TAIL`
    /// is byte-identical to `write_frame(json_line_traced(..))` output, but
    /// the middle (and by far largest) part is shared, not copied.
    pub fn json_frame_head(&self, session: u64, objective: f64, trace: Option<u64>) -> Vec<u8> {
        self.json_frame_head_stamped(session, objective, trace, false)
    }

    /// [`Self::json_frame_head`] plus the brownout `degraded` marker.
    pub fn json_frame_head_stamped(
        &self,
        session: u64,
        objective: f64,
        trace: Option<u64>,
        degraded: bool,
    ) -> Vec<u8> {
        format!(
            "{{\"type\":\"segment\",\"session\":{session},{}{}\"model\":{},\"pattern\":{},\"layers\":",
            trace_splice(trace),
            degraded_splice(degraded),
            self.model_json,
            self.pattern_json(objective),
        )
        .into_bytes()
    }

    /// The binary-frame header for one session (pair with [`Self::blob`]).
    pub fn binary_header(&self, session: u64, objective: f64) -> String {
        self.binary_header_traced(session, objective, None)
    }

    /// [`Self::binary_header`] with an optional echoed trace id.
    pub fn binary_header_traced(&self, session: u64, objective: f64, trace: Option<u64>) -> String {
        self.binary_header_stamped(session, objective, trace, false)
    }

    /// [`Self::binary_header_traced`] plus the brownout `degraded` marker.
    pub fn binary_header_stamped(
        &self,
        session: u64,
        objective: f64,
        trace: Option<u64>,
        degraded: bool,
    ) -> String {
        format!(
            "{{\"type\":\"segment\",\"session\":{session},{}{}\"model\":{},\"pattern\":{},\"layers\":{}}}",
            trace_splice(trace),
            degraded_splice(degraded),
            self.model_json,
            self.pattern_json(objective),
            self.bin_layers_json,
        )
    }

    /// The per-connection prefix of a binary-framed segment reply: magic
    /// byte, total/header lengths, and the stamped header. The
    /// concatenation `binary_frame_head + blob_shared` is byte-identical
    /// to `write_binary_frame(binary_header_traced(..), blob())` output.
    /// Returns `None` when the frame would exceed
    /// [`crate::frame::MAX_FRAME_BYTES`], exactly when `write_binary_frame`
    /// would refuse with `TooLarge`.
    pub fn binary_frame_head(
        &self,
        session: u64,
        objective: f64,
        trace: Option<u64>,
    ) -> Option<Vec<u8>> {
        self.binary_frame_head_stamped(session, objective, trace, false)
    }

    /// [`Self::binary_frame_head`] plus the brownout `degraded` marker.
    pub fn binary_frame_head_stamped(
        &self,
        session: u64,
        objective: f64,
        trace: Option<u64>,
        degraded: bool,
    ) -> Option<Vec<u8>> {
        let header = self.binary_header_stamped(session, objective, trace, degraded);
        let total = 4 + header.len() + self.blob.len();
        if total > MAX_FRAME_BYTES {
            return None;
        }
        let mut head = Vec::with_capacity(9 + header.len());
        head.push(BINARY_MAGIC);
        head.extend_from_slice(&(total as u32).to_le_bytes());
        head.extend_from_slice(&(header.len() as u32).to_le_bytes());
        head.extend_from_slice(header.as_bytes());
        Some(head)
    }

    /// Rebuild the full reply for one session (in-process compat path).
    pub fn to_reply(&self, session: u64, objective: f64) -> InferReply {
        let mut pattern = self.pattern.clone();
        pattern.objective = objective;
        InferReply {
            session,
            trace: None,
            degraded: false,
            model: self.model.clone(),
            pattern,
            segment: self.segment.clone(),
        }
    }
}

/// `"trace":N,` (trailing comma) or empty — the cached-body stampers
/// splice this between the session id and the model field.
fn trace_splice(trace: Option<u64>) -> String {
    match trace {
        Some(t) => format!("\"trace\":{t},"),
        None => String::new(),
    }
}

/// `"degraded":true,` (trailing comma) or empty — spliced right after the
/// trace id, mirroring `Response::Segment`'s field order.
fn degraded_splice(degraded: bool) -> &'static str {
    if degraded {
        "\"degraded\":true,"
    } else {
        ""
    }
}

// ---------------------------------------------------------------------------
// Response (de)serialization
// ---------------------------------------------------------------------------

impl Response {
    pub fn to_json(&self) -> Value {
        match self {
            Response::Pong => Value::obj([("type", "pong".into())]),
            Response::Models(models) => Value::obj([
                ("type", "models".into()),
                (
                    "models",
                    Value::Arr(
                        models
                            .iter()
                            .map(|m| {
                                Value::obj([
                                    ("name", m.name.as_str().into()),
                                    ("arch", m.arch.as_str().into()),
                                    ("dataset", m.dataset.as_str().into()),
                                    ("layers", m.layers.into()),
                                    ("params", m.params.into()),
                                    ("test_accuracy", m.test_accuracy.into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Stats(v) => {
                let mut o = Value::obj([("type", "stats".into())]);
                o.set("stats", v.clone());
                o
            }
            Response::Hello(h) => {
                let mut v = Value::obj([
                    ("type", "hello".into()),
                    ("binary_frames", h.binary_frames.into()),
                ]);
                if let Some(t) = h.trace {
                    v.set("trace", t.into());
                }
                v
            }
            Response::Segment(r) => {
                let mut fields = vec![
                    ("type", Value::from("segment")),
                    ("session", r.session.into()),
                ];
                // the trace id (and the degraded marker) sit right after
                // the session id so the cached-body splice
                // (`json_line_stamped`) can reproduce this serialization
                // byte-for-byte
                if let Some(t) = r.trace {
                    fields.push(("trace", t.into()));
                }
                if r.degraded {
                    fields.push(("degraded", true.into()));
                }
                fields.push(("model", r.model.as_str().into()));
                fields.push(("pattern", r.pattern.to_json()));
                fields.push(("layers", layers_json(&r.segment.layers)));
                Value::obj(fields)
            }
            Response::Result(r) => {
                let mut fields = vec![
                    ("type", Value::from("result")),
                    ("session", r.session.into()),
                ];
                if let Some(t) = r.trace {
                    fields.push(("trace", t.into()));
                }
                fields.push(("prediction", (r.prediction as i64).into()));
                fields.push(("logits", Value::num_arr(&r.logits)));
                fields.push(("server_us", r.server_us.into()));
                let mut v = Value::obj(fields);
                if let Some(c) = &r.costs {
                    v.set("costs", c.clone());
                }
                v
            }
            Response::Error(e) => Value::obj([
                ("type", "error".into()),
                ("code", e.code.as_str().into()),
                ("message", e.message.as_str().into()),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Response> {
        match v.req_str("type")? {
            "pong" => Ok(Response::Pong),
            "models" => {
                let mut models = Vec::new();
                for m in v.req_arr("models")? {
                    models.push(ModelInfo {
                        name: m.req_str("name")?.to_string(),
                        arch: m.req_str("arch")?.to_string(),
                        dataset: m.req_str("dataset")?.to_string(),
                        layers: m.req_usize("layers")?,
                        params: m.req_u64("params")?,
                        test_accuracy: m.opt_f64("test_accuracy", f64::NAN),
                    });
                }
                Ok(Response::Models(models))
            }
            "stats" => Ok(Response::Stats(v.req("stats")?.clone())),
            "hello" => Ok(Response::Hello(HelloReply {
                binary_frames: v.opt_bool("binary_frames", false),
                trace: opt_trace(v),
            })),
            "segment" => {
                let mut layers = Vec::new();
                for l in v.req_arr("layers")? {
                    layers.push(LayerBlob {
                        layer: l.req_usize("layer")?,
                        bits: l.req_u64("bits")? as u8,
                        w_dims: usize_arr(l, "w_dims")?,
                        w_qmin: l.req_f64("w_qmin")? as f32,
                        w_step: l.req_f64("w_step")? as f32,
                        w_packed: bytes_field(l, "w_packed")?,
                        b_qmin: l.req_f64("b_qmin")? as f32,
                        b_step: l.req_f64("b_step")? as f32,
                        b_len: l.req_usize("b_len")?,
                        b_packed: bytes_field(l, "b_packed")?,
                    });
                }
                Ok(Response::Segment(InferReply {
                    session: v.req_u64("session")?,
                    trace: opt_trace(v),
                    degraded: v.opt_bool("degraded", false),
                    model: v.req_str("model")?.to_string(),
                    pattern: PatternInfo::from_json(v.req("pattern")?)?,
                    segment: SegmentBlob { layers },
                }))
            }
            "result" => Ok(Response::Result(ResultReply {
                session: v.req_u64("session")?,
                trace: opt_trace(v),
                prediction: v.req_f64("prediction")? as i32,
                logits: v.req_f64_arr("logits")?,
                costs: v.get("costs").cloned(),
                server_us: v.opt_f64("server_us", 0.0) as u64,
            })),
            "error" => Ok(Response::Error(ErrorReply {
                code: v.req_str("code")?.to_string(),
                message: v.req_str("message")?.to_string(),
            })),
            other => Err(Error::schema("type", format!("unknown response '{other}'"))),
        }
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_line(line: &str) -> Result<Response> {
        Response::from_json(&parse(line)?)
    }

    /// Decode a frame of either kind (binary frames carry segment replies).
    pub fn from_frame(frame: &Frame) -> Result<Response> {
        match frame {
            Frame::Json(line) => Response::from_line(line),
            Frame::Binary(BinaryFrame { header, blob }) => {
                Ok(Response::Segment(InferReply::from_binary(header, blob)?))
            }
        }
    }
}

impl PatternInfo {
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("partition", self.partition.into()),
            (
                "weight_bits",
                Value::Arr(self.weight_bits.iter().map(|&b| (b as u64).into()).collect()),
            ),
            ("activation_bits", (self.activation_bits as u64).into()),
            ("accuracy_level", self.accuracy_level.into()),
            ("predicted_degradation", self.predicted_degradation.into()),
            ("objective", self.objective.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<PatternInfo> {
        Ok(PatternInfo {
            partition: v.req_usize("partition")?,
            weight_bits: v
                .req_arr("weight_bits")?
                .iter()
                .map(|b| {
                    b.as_i64()
                        .and_then(|x| u8::try_from(x).ok())
                        .ok_or_else(|| Error::schema("weight_bits", "expected bytes"))
                })
                .collect::<Result<_>>()?,
            activation_bits: v.req_u64("activation_bits")? as u8,
            accuracy_level: v.req_f64("accuracy_level")?,
            predicted_degradation: v.opt_f64("predicted_degradation", 0.0),
            objective: v.opt_f64("objective", f64::NAN),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frame::{read_any_frame, write_binary_frame, write_frame, Frame};
    use qpart_core::rng::Rng;
    use std::io::BufReader;

    fn infer_req() -> InferRequest {
        InferRequest {
            model: "mlp6".into(),
            accuracy_budget: 0.01,
            channel_capacity_bps: 200e6,
            tx_power_w: 1.0,
            clock_hz: 200e6,
            cycles_per_mac: 5.0,
            kappa: 3e-27,
            memory_bits: 1 << 31,
            weights: Some((1.0, 1.0, 1.0)),
            deadline_ms: None,
        }
    }

    fn sample_reply() -> InferReply {
        InferReply {
            session: 7,
            trace: None,
            degraded: false,
            model: "mlp6".into(),
            pattern: PatternInfo {
                partition: 3,
                weight_bits: vec![4, 5, 6],
                activation_bits: 7,
                accuracy_level: 0.01,
                predicted_degradation: 0.009,
                objective: 0.123,
            },
            segment: SegmentBlob {
                layers: vec![LayerBlob {
                    layer: 1,
                    bits: 4,
                    w_dims: vec![784, 512],
                    w_qmin: -0.3,
                    w_step: 0.004,
                    w_packed: vec![0xDE, 0xAD],
                    b_qmin: -0.1,
                    b_step: 0.002,
                    b_len: 512,
                    b_packed: vec![0xBE, 0xEF],
                }],
            },
        }
    }

    /// A pseudo-random reply with `n_layers` layers of varying sizes.
    fn random_reply(rng: &mut Rng, n_layers: usize) -> InferReply {
        let layers = (1..=n_layers)
            .map(|l| {
                let rows = rng.range_usize(1, 64);
                let cols = rng.range_usize(1, 64);
                let w_packed: Vec<u8> =
                    (0..rng.range_usize(0, 512)).map(|_| rng.below(256) as u8).collect();
                let b_packed: Vec<u8> =
                    (0..rng.range_usize(0, 64)).map(|_| rng.below(256) as u8).collect();
                LayerBlob {
                    layer: l,
                    bits: rng.range_usize(2, 16) as u8,
                    w_dims: vec![rows, cols],
                    w_qmin: rng.range_f64(-2.0, 0.0) as f32,
                    w_step: rng.range_f64(1e-4, 1e-2) as f32,
                    w_packed,
                    b_qmin: rng.range_f64(-1.0, 0.0) as f32,
                    b_step: rng.range_f64(1e-4, 1e-2) as f32,
                    b_len: cols,
                    b_packed,
                }
            })
            .collect();
        InferReply {
            session: rng.below(1 << 40),
            trace: None,
            degraded: false,
            model: format!("model-{}", rng.below(100)),
            pattern: PatternInfo {
                partition: n_layers,
                weight_bits: (0..n_layers).map(|_| rng.range_usize(2, 16) as u8).collect(),
                activation_bits: rng.range_usize(2, 16) as u8,
                accuracy_level: rng.range_f64(0.001, 0.05),
                predicted_degradation: rng.range_f64(0.0, 0.05),
                objective: rng.range_f64(0.0, 10.0),
            },
            segment: SegmentBlob { layers },
        }
    }

    #[test]
    fn request_roundtrips() {
        for req in [
            Request::Ping,
            Request::ListModels,
            Request::Stats,
            Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() }),
            Request::Hello(HelloRequest { trace: true, ..HelloRequest::default() }),
            Request::Hello(HelloRequest { weight: 0.25, ..HelloRequest::default() }),
            Request::Hello(HelloRequest { class: "sensor".into(), ..HelloRequest::default() }),
            Request::Infer(infer_req()),
            Request::Infer(InferRequest { deadline_ms: Some(250), ..infer_req() }),
            Request::Activation(ActivationUpload {
                session: 42,
                bits: 6,
                qmin: -1.5,
                step: 0.01,
                dims: vec![1, 128],
                packed: vec![1, 2, 3, 255],
            }),
            Request::Simulate(SimulateRequest {
                req: infer_req(),
                input: vec![0.5, -0.25, 1e-3],
                input_dims: vec![1, 3],
            }),
        ] {
            let line = req.to_line();
            assert!(!line.contains('\n'));
            let back = Request::from_line(&line).unwrap();
            assert_eq!(back, req, "line: {line}");
        }
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Pong,
            Response::Hello(HelloReply { binary_frames: false, trace: None }),
            Response::Hello(HelloReply { binary_frames: true, trace: Some(42) }),
            Response::Segment(sample_reply()),
            Response::Segment(InferReply { trace: Some(17), ..sample_reply() }),
            Response::Segment(InferReply { degraded: true, ..sample_reply() }),
            Response::Segment(InferReply {
                trace: Some(3),
                degraded: true,
                ..sample_reply()
            }),
            Response::Result(ResultReply {
                session: 7,
                trace: None,
                prediction: 3,
                logits: vec![0.1, 0.9],
                costs: Some(Value::obj([("objective", 1.5.into())])),
                server_us: 1234,
            }),
            Response::Error(ErrorReply { code: "infeasible".into(), message: "x".into() }),
            Response::Models(vec![ModelInfo {
                name: "mlp6".into(),
                arch: "mlp6".into(),
                dataset: "digits".into(),
                layers: 6,
                params: 567434,
                test_accuracy: 0.97,
            }]),
        ] {
            let line = resp.to_line();
            let back = Response::from_line(&line).unwrap();
            assert_eq!(back, resp, "line: {line}");
        }
    }

    #[test]
    fn f32_b64_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(b64_to_f32s(&f32s_to_b64(&xs)).unwrap(), xs);
        assert!(b64_to_f32s("AAA").is_err()); // 2 bytes
    }

    #[test]
    fn unknown_types_rejected() {
        assert!(Request::from_line(r#"{"type":"warp"}"#).is_err());
        assert!(Response::from_line(r#"{"type":"warp"}"#).is_err());
        assert!(Request::from_line("not json").is_err());
    }

    #[test]
    fn binary_segment_roundtrip_property() {
        // property test: random segments survive the binary encoding
        // exactly, through the frame layer, across many shapes and sizes
        let mut rng = Rng::new(0xB15E6);
        for trial in 0..50 {
            let reply = random_reply(&mut rng, 1 + trial % 5);
            let (header, blob) = reply.to_binary();
            let back = InferReply::from_binary(&header, &blob).unwrap();
            assert_eq!(back, reply, "trial {trial}");

            // through write_binary_frame / read_any_frame
            let mut wire = Vec::new();
            write_binary_frame(&mut wire, &header, &blob).unwrap();
            let mut r = BufReader::new(&wire[..]);
            match Response::from_frame(&read_any_frame(&mut r).unwrap()).unwrap() {
                Response::Segment(s) => assert_eq!(s, reply, "trial {trial}"),
                other => panic!("trial {trial}: unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn binary_rejects_out_of_range_offsets() {
        let reply = sample_reply();
        let (header, blob) = reply.to_binary();
        // truncating the blob must fail cleanly, not panic
        assert!(InferReply::from_binary(&header, &blob[..1]).is_err());
        assert!(InferReply::from_binary(&header, &[]).is_err());
    }

    #[test]
    fn encoded_body_json_line_matches_full_serialization() {
        let reply = sample_reply();
        let body = EncodedSegmentBody::new(
            &reply.model,
            reply.pattern.clone(),
            reply.segment.clone(),
        );
        // byte-identical to the one-shot serialization path
        let line = body.json_line(reply.session, reply.pattern.objective);
        assert_eq!(line, Response::Segment(reply.clone()).to_line());
        // and a fresh session/objective stamps without re-encoding
        let line9 = body.json_line(9, 0.5);
        match Response::from_line(&line9).unwrap() {
            Response::Segment(s) => {
                assert_eq!(s.session, 9);
                assert_eq!(s.pattern.objective, 0.5);
                assert_eq!(s.segment, reply.segment);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(body.wire_bytes(), 4, "2 weight + 2 bias bytes");
    }

    #[test]
    fn traced_splices_match_full_serialization() {
        let reply = sample_reply();
        let body = EncodedSegmentBody::new(
            &reply.model,
            reply.pattern.clone(),
            reply.segment.clone(),
        );
        // None is byte-identical to the untraced stampers
        assert_eq!(
            body.json_line_traced(7, 0.123, None),
            body.json_line(7, 0.123),
        );
        assert_eq!(
            body.binary_header_traced(7, 0.123, None),
            body.binary_header(7, 0.123),
        );
        // Some(id) matches the one-shot serialization paths byte-for-byte
        let traced = InferReply { trace: Some(99), ..reply.clone() };
        assert_eq!(
            body.json_line_traced(7, 0.123, Some(99)),
            Response::Segment(traced.clone()).to_line(),
        );
        let (direct_header, direct_blob) = traced.to_binary();
        assert_eq!(body.binary_header_traced(7, 0.123, Some(99)), direct_header);
        assert_eq!(body.blob(), &direct_blob[..]);
        // and the traced line parses back with the id intact
        match Response::from_line(&body.json_line_traced(7, 0.123, Some(99))).unwrap() {
            Response::Segment(s) => assert_eq!(s.trace, Some(99)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn trace_field_compat_with_old_peers() {
        // an untraced hello serializes exactly as before the field existed
        let line =
            Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() })
                .to_line();
        assert!(!line.contains("trace"));
        // old-peer bytes (no trace field) parse as trace=false / None
        match Request::from_line(r#"{"type":"hello","binary_frames":true}"#).unwrap() {
            Request::Hello(h) => assert!(!h.trace),
            other => panic!("unexpected {other:?}"),
        }
        match Response::from_line(r#"{"type":"hello","binary_frames":true}"#).unwrap() {
            Response::Hello(h) => assert_eq!(h.trace, None),
            other => panic!("unexpected {other:?}"),
        }
        // ungranted replies never carry the field
        let line =
            Response::Hello(HelloReply { binary_frames: true, trace: None }).to_line();
        assert!(!line.contains("trace"));
        let line = Response::Segment(sample_reply()).to_line();
        assert!(!line.contains("\"trace\""));
    }

    #[test]
    fn weight_field_compat_with_old_peers() {
        // a default-weight hello serializes exactly as before the field
        // existed, so old servers never see it
        let line = Request::Hello(HelloRequest::default()).to_line();
        assert!(!line.contains("weight"));
        // old-peer bytes (no weight field) parse as the base class
        match Request::from_line(r#"{"type":"hello","binary_frames":true}"#).unwrap() {
            Request::Hello(h) => assert_eq!(h.weight, 1.0),
            other => panic!("unexpected {other:?}"),
        }
        // a non-default weight round-trips
        let req = Request::Hello(HelloRequest { weight: 0.4, ..HelloRequest::default() });
        match Request::from_line(&req.to_line()).unwrap() {
            Request::Hello(h) => assert_eq!(h.weight, 0.4),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn deadline_field_compat_with_old_peers() {
        // a deadline-less infer serializes exactly as before the field
        // existed, so old servers never see it
        let line = Request::Infer(infer_req()).to_line();
        assert!(!line.contains("deadline"));
        // old-peer bytes (no deadline field) parse as None
        match Request::from_line(&line).unwrap() {
            Request::Infer(r) => assert_eq!(r.deadline_ms, None),
            other => panic!("unexpected {other:?}"),
        }
        // a set deadline round-trips
        let req = Request::Infer(InferRequest { deadline_ms: Some(75), ..infer_req() });
        match Request::from_line(&req.to_line()).unwrap() {
            Request::Infer(r) => assert_eq!(r.deadline_ms, Some(75)),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn class_field_compat_with_old_peers() {
        // an unlabeled hello serializes exactly as before the field existed
        let line = Request::Hello(HelloRequest::default()).to_line();
        assert!(!line.contains("class"));
        match Request::from_line(r#"{"type":"hello","binary_frames":true}"#).unwrap() {
            Request::Hello(h) => assert!(h.class.is_empty()),
            other => panic!("unexpected {other:?}"),
        }
        let req = Request::Hello(HelloRequest { class: "phone".into(), ..HelloRequest::default() });
        match Request::from_line(&req.to_line()).unwrap() {
            Request::Hello(h) => assert_eq!(h.class, "phone"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn degraded_field_compat_with_old_peers() {
        // non-degraded replies never carry the field — byte-identical to
        // pre-brownout peers on both framings
        let line = Response::Segment(sample_reply()).to_line();
        assert!(!line.contains("degraded"));
        let (header, _) = sample_reply().to_binary();
        assert!(!header.contains("degraded"));
        // a degraded reply round-trips over both framings
        let marked = InferReply { degraded: true, ..sample_reply() };
        match Response::from_line(&Response::Segment(marked.clone()).to_line()).unwrap() {
            Response::Segment(s) => assert!(s.degraded),
            other => panic!("unexpected {other:?}"),
        }
        let (header, blob) = marked.to_binary();
        assert!(InferReply::from_binary(&header, &blob).unwrap().degraded);
    }

    #[test]
    fn degraded_splices_match_full_serialization() {
        let reply = sample_reply();
        let body = EncodedSegmentBody::new(
            &reply.model,
            reply.pattern.clone(),
            reply.segment.clone(),
        );
        // false is byte-identical to the unmarked stampers
        assert_eq!(
            body.json_line_stamped(7, 0.123, None, false),
            body.json_line(7, 0.123),
        );
        assert_eq!(
            body.binary_header_stamped(7, 0.123, Some(4), false),
            body.binary_header_traced(7, 0.123, Some(4)),
        );
        // true matches the one-shot serialization paths byte-for-byte,
        // with and without a trace id
        for trace in [None, Some(99u64)] {
            let marked = InferReply { trace, degraded: true, ..reply.clone() };
            assert_eq!(
                body.json_line_stamped(7, 0.123, trace, true),
                Response::Segment(marked.clone()).to_line(),
            );
            let (direct_header, _) = marked.to_binary();
            assert_eq!(body.binary_header_stamped(7, 0.123, trace, true), direct_header);

            // frame-head splices concatenate to the whole-frame writes
            let mut whole = Vec::new();
            write_frame(&mut whole, &body.json_line_stamped(7, 0.123, trace, true)).unwrap();
            let mut parts = body.json_frame_head_stamped(7, 0.123, trace, true);
            parts.extend_from_slice(&body.layers_json_shared());
            parts.extend_from_slice(JSON_FRAME_TAIL);
            assert_eq!(parts, whole);

            let mut whole = Vec::new();
            write_binary_frame(
                &mut whole,
                &body.binary_header_stamped(7, 0.123, trace, true),
                body.blob(),
            )
            .unwrap();
            let mut parts = body.binary_frame_head_stamped(7, 0.123, trace, true).unwrap();
            parts.extend_from_slice(&body.blob_shared());
            assert_eq!(parts, whole);
        }
    }

    #[test]
    fn splice_parts_match_whole_frame_writes() {
        // the zero-copy egress contract: head + shared body (+ tail)
        // concatenate to exactly the bytes the whole-frame writers emit
        let mut rng = Rng::new(0x5EC5);
        for trial in 0..20 {
            let reply = random_reply(&mut rng, 1 + trial % 4);
            let body = EncodedSegmentBody::new(
                &reply.model,
                reply.pattern.clone(),
                reply.segment.clone(),
            );
            for trace in [None, Some(7u64)] {
                // JSON framing
                let mut whole = Vec::new();
                write_frame(
                    &mut whole,
                    &body.json_line_traced(reply.session, 0.25, trace),
                )
                .unwrap();
                let mut parts = body.json_frame_head(reply.session, 0.25, trace);
                parts.extend_from_slice(&body.layers_json_shared());
                parts.extend_from_slice(JSON_FRAME_TAIL);
                assert_eq!(parts, whole, "trial {trial} trace {trace:?} (json)");

                // binary framing
                let mut whole = Vec::new();
                write_binary_frame(
                    &mut whole,
                    &body.binary_header_traced(reply.session, 0.25, trace),
                    body.blob(),
                )
                .unwrap();
                let mut parts =
                    body.binary_frame_head(reply.session, 0.25, trace).unwrap();
                parts.extend_from_slice(&body.blob_shared());
                assert_eq!(parts, whole, "trial {trial} trace {trace:?} (binary)");
            }
        }
    }

    #[test]
    fn encoded_body_binary_header_matches_to_binary() {
        let reply = sample_reply();
        let body = EncodedSegmentBody::new(
            &reply.model,
            reply.pattern.clone(),
            reply.segment.clone(),
        );
        let header = body.binary_header(reply.session, reply.pattern.objective);
        let (direct_header, direct_blob) = reply.to_binary();
        assert_eq!(header, direct_header);
        assert_eq!(body.blob(), &direct_blob[..]);
        let back = InferReply::from_binary(&header, body.blob()).unwrap();
        assert_eq!(back, reply);
    }

    #[test]
    fn binary_and_json_framings_agree_on_payload_bytes() {
        // the acceptance contract: the same reply shipped over both
        // framings decodes to byte-identical packed payloads
        let mut rng = Rng::new(42);
        let reply = random_reply(&mut rng, 3);
        let (header, blob) = reply.to_binary();
        let via_binary = InferReply::from_binary(&header, &blob).unwrap();
        let via_json = match Response::from_line(&Response::Segment(reply.clone()).to_line())
            .unwrap()
        {
            Response::Segment(s) => s,
            other => panic!("unexpected {other:?}"),
        };
        for (a, b) in via_binary.segment.layers.iter().zip(&via_json.segment.layers) {
            assert_eq!(a.w_packed, b.w_packed);
            assert_eq!(a.b_packed, b.b_packed);
        }
        assert_eq!(via_binary.segment, via_json.segment);
    }

    /// A pseudo-random activation upload (varying dims and payload).
    fn random_upload(rng: &mut Rng) -> ActivationUpload {
        let cols = rng.range_usize(1, 512);
        ActivationUpload {
            session: rng.below(1 << 40),
            bits: rng.range_usize(2, 16) as u8,
            qmin: rng.range_f64(-2.0, 0.0) as f32,
            step: rng.range_f64(1e-4, 1e-2) as f32,
            dims: vec![1, cols],
            packed: (0..rng.range_usize(0, 1024)).map(|_| rng.below(256) as u8).collect(),
        }
    }

    #[test]
    fn binary_activation_roundtrip_property() {
        // the uplink sibling of the segment-frame property test: random
        // uploads survive the binary encoding exactly, through the frame
        // layer, and byte-identical to the JSON path
        let mut rng = Rng::new(0xACC);
        for trial in 0..50 {
            let a = random_upload(&mut rng);
            let (header, blob) = a.to_binary();
            let back = ActivationUpload::from_binary(&header, &blob).unwrap();
            assert_eq!(back, a, "trial {trial}");

            // through write_binary_frame / read_any_frame / from_frame
            let mut wire = Vec::new();
            write_binary_frame(&mut wire, &header, &blob).unwrap();
            let mut r = BufReader::new(&wire[..]);
            match Request::from_frame(&read_any_frame(&mut r).unwrap()).unwrap() {
                Request::Activation(b) => assert_eq!(b, a, "trial {trial}"),
                other => panic!("trial {trial}: unexpected {other:?}"),
            }

            // byte identity vs the JSON path: same packed payload bytes
            match Request::from_line(&Request::Activation(a.clone()).to_line()).unwrap() {
                Request::Activation(j) => {
                    assert_eq!(j.packed, a.packed, "trial {trial}");
                    assert_eq!(j, a, "trial {trial}");
                }
                other => panic!("trial {trial}: unexpected {other:?}"),
            }

            // the binary envelope beats base64-in-JSON once payloads are
            // non-trivial: raw bytes vs 4/3 expansion + field name
            let json_bytes = Request::Activation(a.clone()).to_line().len() + 1;
            let bin_bytes = 1 + 4 + 4 + header.len() + blob.len();
            if a.packed.len() > 256 {
                assert!(bin_bytes < json_bytes, "trial {trial}: {bin_bytes} vs {json_bytes}");
            }
        }
    }

    #[test]
    fn binary_activation_rejects_bad_frames() {
        let a = ActivationUpload {
            session: 1,
            bits: 8,
            qmin: 0.0,
            step: 0.1,
            dims: vec![1, 4],
            packed: vec![1, 2, 3, 4],
        };
        let (header, blob) = a.to_binary();
        // truncated blob fails cleanly
        assert!(ActivationUpload::from_binary(&header, &blob[..2]).is_err());
        // a segment header is not an activation
        let (seg_header, seg_blob) = sample_reply().to_binary();
        assert!(ActivationUpload::from_binary(&seg_header, &seg_blob).is_err());
        // ...and Request::from_frame refuses it too
        let frame = Frame::Binary(BinaryFrame { header: seg_header, blob: seg_blob });
        assert!(Request::from_frame(&frame).is_err());
        // json frames still dispatch through from_frame
        match Request::from_frame(&Frame::Json(Request::Ping.to_line())).unwrap() {
            Request::Ping => {}
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn hello_request_over_json_frame() {
        let mut wire = Vec::new();
        let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
        write_frame(&mut wire, &hello.to_line()).unwrap();
        let mut r = BufReader::new(&wire[..]);
        match read_any_frame(&mut r).unwrap() {
            Frame::Json(line) => match Request::from_line(&line).unwrap() {
                Request::Hello(h) => assert!(h.binary_frames),
                other => panic!("unexpected {other:?}"),
            },
            other => panic!("unexpected {other:?}"),
        }
    }
}
