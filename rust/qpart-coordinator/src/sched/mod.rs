//! The serving dataplane: batch-aware scheduling between the accept loop
//! and the executor pool.
//!
//! QPART's reply to an `infer` request is a pure function of
//! `(model, accuracy level, partition)` — everything per-request
//! (channel, clocks, budget) is consumed by the Algorithm 2 *decision*,
//! after which identical decisions produce identical multi-megabyte
//! segment payloads. Under fleet load the same few patterns dominate, so
//! re-quantizing and re-serializing per connection is almost pure waste.
//! This module removes that waste in two layers:
//!
//! * [`batch`] — workers drain the shared queue into **batches**
//!   ([`drain_batch`]), the service groups the batch's infer requests by
//!   coalescing key, and one plan/encode fans out to every waiting
//!   connection as a shared [`WireReply`]. The same drain feeds the
//!   phase-2 half of the plane: `activation` uploads group by
//!   `(model, partition)` and row-stack into batched server-segment
//!   executions of up to `EVAL_BATCH` rows each. An optional coalescing
//!   window (`--batch-window`) holds the first request briefly so
//!   concurrent same-key requests land in one group; `queue_wait`
//!   metrics expose the latency this buys throughput with.
//! * [`cache`] — the [`EncodedReplyCache`] keeps fully serialized reply
//!   bodies (`qpart_proto::messages::EncodedSegmentBody`) across batches,
//!   LRU-evicted under a byte budget (`--cache-bytes`), so steady-state
//!   serving re-encodes only on pattern churn.
//! * [`fair`] — per-connection fair queuing: a token-bucket rate limiter
//!   ([`FairQueue`], `--fair-rate`) applied before enqueue so one hot
//!   device can't starve the rest of the fleet; refusals are surfaced as
//!   `sched_throttled_total` and a `throttled` error reply.
//!
//! Connection threads stamp the shared body with the per-request session
//! id and objective in whichever framing the session negotiated (JSON
//! lines or binary frames) — the payload bytes are encoded exactly once
//! per key, regardless of fan-out or framing.

pub mod batch;
pub mod cache;
pub mod fair;

pub use batch::{
    drain_batch, BatchPolicy, DrainOutcome, Job, ReplyRouter, ReplySink, SegmentReply,
    StampedReply, WireReply,
};
pub use cache::{EncodedReplyCache, SegmentKey};
pub use fair::FairQueue;
