//! Integration tests over the real artifact bundle (requires
//! `make artifacts`). Exercises: bundle loading, PJRT execution, split
//! inference vs full inference, the accuracy-degradation contract, the
//! baselines, and the TCP serving stack end to end.

use qpart::coordinator::client::paper_request;
use qpart::prelude::*;
use std::sync::Arc;

fn artifacts_dir() -> Option<&'static str> {
    for dir in ["artifacts", "../artifacts", "../../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(dir);
        }
    }
    None
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
                return;
            }
        }
    };
}

fn load_bundle() -> Arc<Bundle> {
    Arc::new(Bundle::load(artifacts_dir().unwrap()).expect("bundle loads"))
}

#[test]
fn bundle_loads_and_is_complete() {
    require_artifacts!();
    let b = load_bundle();
    assert!(b.models.iter().any(|m| m.name == "mlp6"));
    assert_eq!(b.levels.len(), 5);
    for m in &b.models {
        let arch = b.arch(&m.arch).unwrap();
        let w = b.weights(&m.name).unwrap();
        assert_eq!(w.layers.len(), arch.num_layers());
        let c = b.calibration(&m.name).unwrap();
        c.validate(arch).unwrap();
    }
}

#[test]
fn full_inference_matches_manifest_accuracy() {
    require_artifacts!();
    let b = load_bundle();
    let entry = b.model("mlp6").unwrap().clone();
    let (x, y) = b.dataset(&entry.dataset).unwrap();
    let x = HostTensor::from(x);
    let mut ex = Executor::new(Arc::clone(&b)).unwrap();
    let acc = ex
        .eval_accuracy(&x, &y, |ex, chunk| ex.run_full("mlp6", chunk))
        .unwrap();
    assert!(
        (acc - entry.test_accuracy).abs() < 0.01,
        "runtime accuracy {acc} vs build-time {}",
        entry.test_accuracy
    );
}

#[test]
fn split_at_high_bits_matches_full() {
    require_artifacts!();
    let b = load_bundle();
    let arch = b.arch("mlp6").unwrap().clone();
    let mut ex = Executor::new(Arc::clone(&b)).unwrap();
    let (x, _) = b.dataset("digits").unwrap();
    let x = HostTensor::from(x);
    let input = x.slice_rows_padded(0, 1, 1);
    let full = ex.run_full_f32_reference(&arch, "mlp6", input.clone());
    for p in [0usize, 2, 4, 6] {
        let pattern = QuantPattern {
            partition: p,
            weight_bits: vec![16; p],
            activation_bits: 16,
            accuracy_level: 1.0,
            predicted_degradation: 0.0,
        };
        let outcome = ex.run_split("mlp6", &pattern, input.clone()).unwrap();
        let diff: f32 = full
            .data
            .iter()
            .zip(&outcome.logits.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 0.35, "p={p}: 16-bit split deviates by {diff} in logits");
        // same argmax
        let argmax = |v: &[f32]| {
            v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        assert_eq!(argmax(&full.data), argmax(&outcome.logits.data), "p={p}");
    }
}

// Give Executor a reference helper for the test above.
trait RefRun {
    fn run_full_f32_reference(
        &mut self,
        arch: &ModelSpec,
        model: &str,
        x: HostTensor,
    ) -> HostTensor;
}
impl RefRun for Executor {
    fn run_full_f32_reference(
        &mut self,
        arch: &ModelSpec,
        model: &str,
        x: HostTensor,
    ) -> HostTensor {
        let weights = self.weights(model).unwrap();
        self.run_server_segment(arch, &weights, x, 0).unwrap()
    }
}

#[test]
fn split_accuracy_respects_degradation_budget() {
    require_artifacts!();
    let b = load_bundle();
    let arch = b.arch("mlp6").unwrap().clone();
    let calib = b.calibration("mlp6").unwrap();
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    let entry = b.model("mlp6").unwrap().clone();
    let (x, y) = b.dataset(&entry.dataset).unwrap();
    let x = HostTensor::from(x);
    let mut ex = Executor::new(Arc::clone(&b)).unwrap();

    // level index 2 = 1% budget; check a few partitions
    let k = 2usize;
    let budget = patterns.levels[k];
    for &p in &[0usize, 3, 6] {
        let pat = patterns
            .get(qpart::core::quant::PatternKey { level_idx: k, partition: p })
            .unwrap()
            .clone();
        let acc = ex
            .eval_accuracy(&x, &y, |ex, chunk| {
                Ok(ex.run_split("mlp6", &pat, chunk)?.logits)
            })
            .unwrap();
        let degradation = entry.test_accuracy - acc;
        // the noise model is calibrated, not exact: allow 3× headroom + eval noise
        assert!(
            degradation <= budget * 3.0 + 0.01,
            "p={p}: degradation {degradation:.4} exceeds 3×budget {budget}"
        );
    }
}

#[test]
fn segment_payload_matches_pattern_accounting() {
    require_artifacts!();
    let b = load_bundle();
    let arch = b.arch("mlp6").unwrap().clone();
    let calib = b.calibration("mlp6").unwrap();
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    let mut ex = Executor::new(Arc::clone(&b)).unwrap();
    let pat = patterns
        .get(qpart::core::quant::PatternKey { level_idx: 2, partition: 4 })
        .unwrap()
        .clone();
    let seg = ex.quantize_segment("mlp6", &pat).unwrap();
    // Eq. 14 weight part: Σ b_l · z_w(l) (z_w includes bias)
    let expected: u64 = (1..=4)
        .map(|l| (pat.weight_bits[l - 1] as u64) * arch.weight_params(l))
        .sum();
    assert_eq!(seg.weight_payload_bits(), expected);
}

#[test]
fn baselines_run_and_rank_accuracy() {
    require_artifacts!();
    let b = load_bundle();
    let entry = b.model("mlp6").unwrap().clone();
    let (x, y) = b.dataset(&entry.dataset).unwrap();
    let x = HostTensor::from(x);
    // subset for speed
    let n = 320.min(x.batch());
    let xs = x.slice_rows(0, n);
    let ys = &y[..n];
    let mut ex = Executor::new(Arc::clone(&b)).unwrap();

    let p = 3usize;
    let acc_noopt = ex
        .eval_accuracy(&xs, ys, |ex, c| Ok(ex.run_split_f32("mlp6", p, c)?.logits))
        .unwrap();
    let acc_prune = ex
        .eval_accuracy(&xs, ys, |ex, c| {
            Ok(ex.run_split_pruned("mlp6", p, 0.3, c)?.logits)
        })
        .unwrap();
    let acc_ae = ex
        .eval_accuracy(&xs, ys, |ex, c| Ok(ex.run_split_ae("mlp6", p, c)?.logits))
        .unwrap();
    // No-opt is exact → top accuracy; pruning/AE lose something
    assert!(acc_noopt >= acc_prune - 1e-9, "noopt {acc_noopt} vs prune {acc_prune}");
    assert!(acc_noopt >= acc_ae - 0.02, "noopt {acc_noopt} vs ae {acc_ae}");
    assert!(acc_prune > 0.3 && acc_ae > 0.3, "baselines should still work");
}

#[test]
fn conv_model_split_runs() {
    require_artifacts!();
    let b = load_bundle();
    let entry = b.model("tinyresnet").unwrap().clone();
    let arch = b.arch(&entry.arch).unwrap().clone();
    let (x, _) = b.dataset(&entry.dataset).unwrap();
    let x = HostTensor::from(x);
    let input = x.slice_rows_padded(0, 1, 1);
    let mut ex = Executor::new(Arc::clone(&b)).unwrap();
    for &p in &arch.partition_points.clone() {
        let pattern = QuantPattern {
            partition: p,
            weight_bits: vec![12; p],
            activation_bits: 12,
            accuracy_level: 1.0,
            predicted_degradation: 0.0,
        };
        let out = ex.run_split("tinyresnet", &pattern, input.clone()).unwrap();
        assert_eq!(out.logits.dims, vec![1, 10], "p={p}");
        assert!(out.logits.data.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn server_two_phase_roundtrip() {
    require_artifacts!();
    let dir = artifacts_dir().unwrap();
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 64,
        session_capacity: 128,
        artifacts_dir: dir.into(),
        ..ServerConfig::default()
    })
    .expect("server starts");
    let addr = handle.addr.to_string();

    let b = load_bundle();
    let mut client = DeviceClient::connect(&addr, Arc::clone(&b)).unwrap();
    assert!(client.ping().unwrap());

    let entry = b.model("mlp6").unwrap().clone();
    let (x, y) = b.dataset(&entry.dataset).unwrap();
    let x = HostTensor::from(x);

    let mut correct = 0;
    let n = 12;
    for i in 0..n {
        let input = x.slice_rows_padded(i, i + 1, 1);
        let (pred, logits, partition) =
            client.infer(paper_request("mlp6", 0.01), input).unwrap();
        assert_eq!(logits.len(), 10);
        assert!(partition <= 6);
        if pred == y[i] {
            correct += 1;
        }
    }
    // ~97% model, 12 samples: at least 9 correct
    assert!(correct >= 9, "two-phase accuracy too low: {correct}/{n}");

    let snap = handle.snapshot();
    assert!(snap.requests_total >= (2 * n + 1) as u64);
    assert_eq!(snap.errors_total, 0);
    assert_eq!(snap.sessions_opened, n as u64);
    handle.shutdown();
}

#[test]
fn server_rejects_garbage_and_unknown_sessions() {
    require_artifacts!();
    let dir = artifacts_dir().unwrap();
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 8,
        session_capacity: 8,
        artifacts_dir: dir.into(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    use qpart::proto::frame::{read_frame, write_frame};
    use std::io::BufReader;
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    // not JSON
    write_frame(&mut writer, "this is not json").unwrap();
    let resp = qpart::proto::messages::Response::from_line(&read_frame(&mut reader).unwrap())
        .unwrap();
    match resp {
        qpart::proto::messages::Response::Error(e) => assert_eq!(e.code, "bad_request"),
        other => panic!("expected error, got {other:?}"),
    }

    // unknown session
    let act = qpart::proto::messages::Request::Activation(
        qpart::proto::messages::ActivationUpload {
            session: 999_999,
            bits: 8,
            qmin: 0.0,
            step: 0.1,
            dims: vec![1, 10],
            packed: vec![0; 10],
        },
    );
    write_frame(&mut writer, &act.to_line()).unwrap();
    let resp = qpart::proto::messages::Response::from_line(&read_frame(&mut reader).unwrap())
        .unwrap();
    match resp {
        qpart::proto::messages::Response::Error(e) => assert_eq!(e.code, "unknown_session"),
        other => panic!("expected error, got {other:?}"),
    }

    // unknown model
    let inf = qpart::proto::messages::Request::Infer(paper_request("nope", 0.01));
    write_frame(&mut writer, &inf.to_line()).unwrap();
    let resp = qpart::proto::messages::Response::from_line(&read_frame(&mut reader).unwrap())
        .unwrap();
    match resp {
        qpart::proto::messages::Response::Error(e) => assert_eq!(e.code, "unknown_model"),
        other => panic!("expected error, got {other:?}"),
    }

    handle.shutdown();
}

#[test]
fn corrupted_bundle_rejected() {
    require_artifacts!();
    // copy manifest into a temp dir with a missing file reference
    let dir = std::env::temp_dir().join("qpart-corrupt-bundle");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = std::fs::read_to_string(
        std::path::Path::new(artifacts_dir().unwrap()).join("manifest.json"),
    )
    .unwrap();
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    // referenced files don't exist in the temp dir
    assert!(Bundle::load(&dir).is_err());
}
