//! **§Perf** — coordinator-side costs: Algorithm 2 decisions, offline
//! Algorithm 1 regeneration, session table ops, protocol encode/decode.
//!
//! Target (DESIGN.md §8): the decision path must be negligible next to
//! PJRT execution (µs, not ms).

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::{black_box, fmt_ns, quick, Table};

fn main() {
    let setup = mlp6_setup();
    banner("perf — coordinator decision/bookkeeping paths", setup.calibrated);
    let arch = &setup.arch;
    let req = RequestParams { cost: CostModel::paper_default(), accuracy_budget: 0.01 };

    let mut table = Table::new("coordinator ops", &["op", "mean", "p99", "ops/s"]);

    let s = quick(|| {
        black_box(serve_request(arch, &setup.patterns, &req).unwrap());
    });
    table.row(vec![
        "Algorithm 2 decision".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    // the serving path's variant: no per-request diagnostics vector
    let s = quick(|| {
        black_box(serve_request_fast(arch, &setup.patterns, &req).unwrap());
    });
    table.row(vec![
        "Algorithm 2 decision (fast)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    // a decision-cache hit: what repeat profiles pay instead of planning
    {
        use qpart::coordinator::{DecisionCache, ProfileBucket};
        use std::sync::Arc;
        let cache = DecisionCache::new();
        let d = Arc::new(serve_request_fast(arch, &setup.patterns, &req).unwrap());
        let key = ("mlp6".to_string(), d.level_idx, ProfileBucket::of(&req.cost));
        cache.insert(key.clone(), d);
        let s = quick(|| {
            black_box(cache.get(black_box(&key)).unwrap());
        });
        table.row(vec![
            "decision cache hit".into(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(1.0)),
        ]);
    }

    let s = quick(|| {
        black_box(offline_quantize(arch, &setup.calib, OfflineConfig::default()).unwrap());
    });
    table.row(vec![
        "Algorithm 1 (full table)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    // session table open/take at depth 1024
    let pat = setup
        .patterns
        .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: 3 })
        .unwrap()
        .clone();
    let mut sessions = qpart::coordinator::SessionTable::new(4096);
    for _ in 0..1024 {
        sessions.open("mlp6", pat.clone(), vec![1, 128]);
    }
    let s = quick(|| {
        let id = sessions.open("mlp6", pat.clone(), vec![1, 128]);
        black_box(sessions.take(id));
    });
    table.row(vec![
        "session open+take @1k".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    // protocol encode/decode of a phase-1 reply with a real-sized segment
    use qpart::proto::messages::{
        InferReply, LayerBlob, PatternInfo, Response, SegmentBlob,
    };
    let blob = LayerBlob {
        layer: 1,
        bits: 4,
        w_dims: vec![784, 512],
        w_qmin: -0.4,
        w_step: 0.004,
        w_packed: vec![0xA5; 784 * 512 / 2],
        b_qmin: -0.1,
        b_step: 0.001,
        b_len: 512,
        b_packed: vec![0x5A; 512 / 2],
    };
    let reply = Response::Segment(InferReply {
        session: 1,
        model: "mlp6".into(),
        pattern: PatternInfo {
            partition: 1,
            weight_bits: vec![4],
            activation_bits: 8,
            accuracy_level: 0.01,
            predicted_degradation: 0.005,
            objective: 0.1,
        },
        segment: SegmentBlob { layers: vec![blob] },
    });
    let s = quick(|| {
        black_box(reply.to_line());
    });
    let line = reply.to_line();
    table.row(vec![
        format!("encode segment reply ({} KiB)", line.len() / 1024),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);
    let s = quick(|| {
        black_box(Response::from_line(black_box(&line)).unwrap());
    });
    table.row(vec![
        "decode segment reply".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    // the dataplane's cached-reply splice vs. the full encode above: the
    // body is serialized once, each per-session reply is a string stamp
    use qpart::proto::messages::EncodedSegmentBody;
    let (inner_pattern, inner_segment) = match &reply {
        Response::Segment(r) => (r.pattern.clone(), r.segment.clone()),
        _ => unreachable!(),
    };
    let body = EncodedSegmentBody::new("mlp6", inner_pattern, inner_segment);
    let s = quick(|| {
        black_box(body.json_line(black_box(7), black_box(0.1)));
    });
    table.row(vec![
        "stamp cached reply (JSON)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);
    let s = quick(|| {
        black_box(body.binary_header(black_box(7), black_box(0.1)));
    });
    table.row(vec![
        "stamp cached reply (binary header)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    // the traced splice with tracing disabled (`trace: None`) is the
    // serving fast path when --trace-sample is 0: same bytes out, and it
    // must stay in the untraced stamp's cost envelope
    let s = quick(|| {
        black_box(body.json_line_traced(black_box(7), black_box(0.1), black_box(None)));
    });
    table.row(vec![
        "stamp cached reply (traced off)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
        format!("{:.0}", s.per_second(1.0)),
    ]);

    table.print();

    // CI guard: with sampling disabled the traced splice must stay
    // within 5% of the untraced stamp. Measured back-to-back (best of 3
    // attempts) so shared-runner noise doesn't fail a healthy build.
    if std::env::args().any(|a| a == "--check-traced-overhead") {
        let mut ratio = f64::INFINITY;
        for _ in 0..3 {
            let plain = quick(|| {
                black_box(body.json_line(black_box(7), black_box(0.1)));
            });
            let traced = quick(|| {
                black_box(body.json_line_traced(black_box(7), black_box(0.1), black_box(None)));
            });
            ratio = ratio.min(traced.mean_ns / plain.mean_ns);
            if ratio <= 1.05 {
                break;
            }
        }
        println!("traced-off overhead: {ratio:.3}x the untraced stamp (limit 1.05x)");
        if ratio > 1.05 {
            eprintln!("traced-off stamp regressed more than 5% vs the untraced fast path");
            std::process::exit(1);
        }
    }
}
