//! The durable terminal layer: an append-only segment log of store
//! records, mirrored in memory.

use super::mem::MemLayer;
use super::{Column, Layer, ReadLayer, WriteLayer};
use qpart_proto::frame::{encode_record, split_record, RecordSplit, RECORD_DELETE, RECORD_PUT};
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

/// The log file name inside `--store-dir`.
pub const LOG_FILE: &str = "store.log";

/// Compaction triggers when the file holds more than this many times the
/// live entry count in records (i.e. most of the file is dead weight)...
const COMPACT_RECORD_FACTOR: u64 = 2;

/// ...and is at least this large (tiny logs aren't worth rewriting).
const COMPACT_MIN_BYTES: u64 = 1 << 20;

/// An append-only log of CRC-guarded store records
/// ([`qpart_proto::frame::StoreRecord`]) plus an in-memory [`MemLayer`]
/// mirror of the live state — the durable terminal of the store stack
/// (`Base = MemLayer`).
///
/// * **Reads** answer from the mirror: the disk is never on a serving
///   path.
/// * **Writes** append one record, then update the mirror. Writing a
///   value identical to the live one is a no-op (no record), so periodic
///   cache flushes don't grow the file.
/// * **Open** replays the file into the mirror: CRC-corrupt records are
///   skipped and counted ([`SegmentLog::corrupt_records`]), a torn final
///   record (crash mid-append) truncates the recovered tail, and a
///   mangled envelope (bad magic / forged length) stops replay at the
///   last good record — everything before it survives.
/// * **Compaction** ([`SegmentLog::compact`]) rewrites exactly the live
///   key set, sorted, into a fresh file and atomically renames it over
///   the log.
///
/// I/O errors after open are counted ([`SegmentLog::io_errors`]) rather
/// than propagated: the store is an accelerator for the next restart, and
/// a full disk must degrade durability, not serving.
pub struct SegmentLog {
    path: PathBuf,
    file: Option<File>,
    mem: MemLayer,
    /// Records currently in the file (live + superseded + tombstones).
    records: u64,
    /// Bytes currently in the file.
    total_bytes: u64,
    corrupt_records: u64,
    dropped_tail_bytes: u64,
    io_errors: u64,
    compactions: u64,
}

impl SegmentLog {
    /// Open (creating `dir` if needed) and replay `dir/store.log`.
    pub fn open(dir: &Path) -> std::io::Result<SegmentLog> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(LOG_FILE);
        let mut log = SegmentLog {
            path,
            file: None,
            mem: MemLayer::new(),
            records: 0,
            total_bytes: 0,
            corrupt_records: 0,
            dropped_tail_bytes: 0,
            io_errors: 0,
            compactions: 0,
        };
        log.replay()?;
        log.file = Some(OpenOptions::new().create(true).append(true).open(&log.path)?);
        Ok(log)
    }

    /// Replay the file into the mirror, truncating any unrecoverable
    /// tail so the next append starts on a clean record boundary.
    fn replay(&mut self) -> std::io::Result<()> {
        let buf = match std::fs::read(&self.path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
            Err(e) => return Err(e),
        };
        let mut at = 0usize;
        loop {
            match split_record(&buf[at..]) {
                Ok(Some((RecordSplit::Record(rec), consumed))) => {
                    // a CRC-valid record with an unknown column code is
                    // from a newer build: preserve-by-skip, don't drop it
                    if let Some(col) = Column::from_code(rec.column) {
                        match rec.op {
                            RECORD_PUT => self.mem.put(col, &rec.key, &rec.value),
                            RECORD_DELETE => self.mem.delete(col, &rec.key),
                            _ => {}
                        }
                    }
                    self.records += 1;
                    at += consumed;
                }
                Ok(Some((RecordSplit::Corrupt, consumed))) => {
                    // bit-rot at rest: never replays as state, never
                    // hides the records after it
                    self.corrupt_records += 1;
                    at += consumed;
                }
                Ok(None) => {
                    // torn final append (crash mid-write): drop the tail
                    break;
                }
                Err(_) => {
                    // mangled envelope — no record boundary to resync on;
                    // everything from here on is unrecoverable
                    self.corrupt_records += 1;
                    break;
                }
            }
        }
        if at < buf.len() {
            self.dropped_tail_bytes = (buf.len() - at) as u64;
            let f = OpenOptions::new().write(true).open(&self.path)?;
            f.set_len(at as u64)?;
        }
        self.total_bytes = at as u64;
        Ok(())
    }

    fn append(&mut self, op: u8, col: Column, key: &[u8], value: &[u8]) {
        let Ok(rec) = encode_record(op, col.code(), key, value) else {
            // oversized record (a >16 MiB value): skip durability for
            // this entry rather than poison the file
            self.io_errors += 1;
            return;
        };
        let Some(file) = self.file.as_mut() else {
            self.io_errors += 1;
            return;
        };
        match file.write_all(&rec) {
            Ok(()) => {
                self.records += 1;
                self.total_bytes += rec.len() as u64;
            }
            Err(_) => self.io_errors += 1,
        }
    }

    /// Live entries of `col`, sorted by key (warm replay, compaction).
    pub fn entries(&self, col: Column) -> Vec<(Vec<u8>, Vec<u8>)> {
        self.mem.sorted_entries(col)
    }

    /// Total live entries across all columns.
    pub fn live_len(&self) -> u64 {
        Column::ALL.iter().map(|c| self.mem.len(*c) as u64).sum()
    }

    /// Rewrite exactly the live key set (sorted per column) into a fresh
    /// file and atomically rename it over the log.
    pub fn compact(&mut self) -> std::io::Result<()> {
        let tmp = self.path.with_extension("log.tmp");
        let mut out = Vec::new();
        let mut records = 0u64;
        for col in Column::ALL {
            for (key, value) in self.mem.sorted_entries(col) {
                if let Ok(rec) = encode_record(RECORD_PUT, col.code(), &key, &value) {
                    out.extend_from_slice(&rec);
                    records += 1;
                }
            }
        }
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&out)?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, &self.path)?;
        self.file = Some(OpenOptions::new().append(true).open(&self.path)?);
        self.records = records;
        self.total_bytes = out.len() as u64;
        self.compactions += 1;
        Ok(())
    }

    /// Compact when most of the file is superseded records/tombstones
    /// and it is big enough to matter. Errors count as I/O errors.
    pub fn maybe_compact(&mut self) -> bool {
        let live = self.live_len();
        if self.total_bytes < COMPACT_MIN_BYTES || self.records <= COMPACT_RECORD_FACTOR * live {
            return false;
        }
        match self.compact() {
            Ok(()) => true,
            Err(_) => {
                self.io_errors += 1;
                false
            }
        }
    }

    /// Push appended records to stable storage.
    pub fn flush(&mut self) {
        if let Some(f) = self.file.as_mut() {
            if f.sync_data().is_err() {
                self.io_errors += 1;
            }
        }
    }

    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn corrupt_records(&self) -> u64 {
        self.corrupt_records
    }

    pub fn dropped_tail_bytes(&self) -> u64 {
        self.dropped_tail_bytes
    }

    pub fn io_errors(&self) -> u64 {
        self.io_errors
    }

    pub fn compactions(&self) -> u64 {
        self.compactions
    }
}

impl Layer for SegmentLog {
    type Base = MemLayer;
}

impl ReadLayer for SegmentLog {
    fn has(&self, col: Column, key: &[u8]) -> bool {
        self.mem.has(col, key)
    }

    fn get(&self, col: Column, key: &[u8]) -> Option<Vec<u8>> {
        self.mem.get(col, key)
    }

    fn for_each(&self, col: Column, f: &mut dyn FnMut(&[u8], &[u8]) -> bool) {
        self.mem.for_each(col, f)
    }

    fn len(&self, col: Column) -> usize {
        self.mem.len(col)
    }
}

impl WriteLayer for SegmentLog {
    fn put(&mut self, col: Column, key: &[u8], value: &[u8]) {
        if self.mem.get(col, key).as_deref() == Some(value) {
            return; // identical live value: re-flushing a cache is free
        }
        self.append(RECORD_PUT, col, key, value);
        self.mem.put(col, key, value);
    }

    fn delete(&mut self, col: Column, key: &[u8]) {
        if !self.mem.has(col, key) {
            return;
        }
        self.append(RECORD_DELETE, col, key, &[]);
        self.mem.delete(col, key);
    }
}

#[cfg(test)]
mod tests {
    use super::super::mem::tests::exercise_layer;
    use super::*;

    /// Fresh per-test store dir under the system temp dir (same pattern
    /// as `testing::synthetic_bundle`).
    fn store_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qpart-store-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn segment_log_satisfies_the_stack_contract() {
        let dir = store_dir("contract");
        let mut log = SegmentLog::open(&dir).unwrap();
        exercise_layer(&mut log);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn state_survives_reopen() {
        let dir = store_dir("reopen");
        {
            let mut log = SegmentLog::open(&dir).unwrap();
            log.put(Column::Decision, b"d1", b"v1");
            log.put(Column::Reply, b"r1", b"body");
            log.put(Column::Decision, b"d1", b"v2"); // supersede
            log.put(Column::Decision, b"gone", b"x");
            log.delete(Column::Decision, b"gone");
            log.flush();
        }
        let log = SegmentLog::open(&dir).unwrap();
        assert_eq!(log.get(Column::Decision, b"d1"), Some(b"v2".to_vec()));
        assert_eq!(log.get(Column::Reply, b"r1"), Some(b"body".to_vec()));
        assert!(!log.has(Column::Decision, b"gone"));
        assert_eq!(log.corrupt_records(), 0);
        assert_eq!(log.dropped_tail_bytes(), 0);
        assert_eq!(log.records(), 5, "replay saw every append, live state is the net");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn identical_reput_appends_nothing() {
        let dir = store_dir("dedup");
        let mut log = SegmentLog::open(&dir).unwrap();
        log.put(Column::Plan, b"k", b"v");
        let after_first = log.total_bytes();
        log.put(Column::Plan, b"k", b"v");
        log.delete(Column::Plan, b"absent");
        assert_eq!(log.total_bytes(), after_first);
        assert_eq!(log.records(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_dropped_and_earlier_records_survive() {
        let dir = store_dir("torn");
        {
            let mut log = SegmentLog::open(&dir).unwrap();
            log.put(Column::Decision, b"a", b"1");
            log.put(Column::Decision, b"b", b"2");
            log.flush();
        }
        // simulate a crash mid-append: half a record at the tail
        let path = dir.join(LOG_FILE);
        let full = encode_record(RECORD_PUT, Column::Decision.code(), b"c", b"3").unwrap();
        let clean_len = std::fs::metadata(&path).unwrap().len();
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&full[..full.len() / 2]).unwrap();
        }
        let log = SegmentLog::open(&dir).unwrap();
        assert_eq!(log.get(Column::Decision, b"a"), Some(b"1".to_vec()));
        assert_eq!(log.get(Column::Decision, b"b"), Some(b"2".to_vec()));
        assert!(!log.has(Column::Decision, b"c"), "torn record never replays");
        assert_eq!(log.dropped_tail_bytes(), (full.len() / 2) as u64);
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            clean_len,
            "file truncated back to the last good boundary"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crc_corruption_is_skipped_and_counted() {
        let dir = store_dir("crc");
        {
            let mut log = SegmentLog::open(&dir).unwrap();
            log.put(Column::Decision, b"a", b"1");
            log.put(Column::Decision, b"bad", b"xxxx");
            log.put(Column::Decision, b"z", b"9");
            log.flush();
        }
        // flip one payload byte inside the middle record
        let path = dir.join(LOG_FILE);
        let mut bytes = std::fs::read(&path).unwrap();
        let first = encode_record(RECORD_PUT, Column::Decision.code(), b"a", b"1").unwrap();
        let at = first.len() + 20; // inside record 2's key/value region
        bytes[at] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let log = SegmentLog::open(&dir).unwrap();
        assert_eq!(log.corrupt_records(), 1);
        assert!(!log.has(Column::Decision, b"bad"), "corrupt record never replays");
        assert_eq!(log.get(Column::Decision, b"a"), Some(b"1".to_vec()));
        assert_eq!(log.get(Column::Decision, b"z"), Some(b"9".to_vec()), "later records survive");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_preserves_exactly_the_live_key_set() {
        let dir = store_dir("compact");
        let mut log = SegmentLog::open(&dir).unwrap();
        for i in 0..50u32 {
            log.put(Column::Decision, &i.to_le_bytes(), b"old");
            log.put(Column::Decision, &i.to_le_bytes(), &i.to_le_bytes());
        }
        for i in 0..25u32 {
            log.delete(Column::Decision, &i.to_le_bytes());
        }
        log.put(Column::Reply, b"r", b"body");
        let live_before: Vec<_> =
            Column::ALL.iter().map(|c| log.entries(*c)).collect();
        let bytes_before = log.total_bytes();
        log.compact().unwrap();
        assert_eq!(log.compactions(), 1);
        assert!(log.total_bytes() < bytes_before);
        assert_eq!(log.records(), log.live_len(), "compacted file is all live records");
        let live_after: Vec<_> = Column::ALL.iter().map(|c| log.entries(*c)).collect();
        assert_eq!(live_after, live_before);
        drop(log);
        // and the compacted file replays to the same state
        let reopened = SegmentLog::open(&dir).unwrap();
        let live_reopened: Vec<_> =
            Column::ALL.iter().map(|c| reopened.entries(*c)).collect();
        assert_eq!(live_reopened, live_before);
        assert_eq!(reopened.corrupt_records(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn maybe_compact_waits_for_dead_weight() {
        let dir = store_dir("maybe");
        let mut log = SegmentLog::open(&dir).unwrap();
        log.put(Column::Decision, b"k", b"v");
        assert!(!log.maybe_compact(), "tiny log never compacts");
        // grow the file past the floor with superseded versions of one key
        let big = vec![0xA5u8; 64 * 1024];
        for i in 0..40u32 {
            let mut v = big.clone();
            v[0..4].copy_from_slice(&i.to_le_bytes());
            log.put(Column::Reply, b"hot", &v);
        }
        assert!(log.total_bytes() > COMPACT_MIN_BYTES);
        assert!(log.maybe_compact(), "mostly-dead file compacts");
        assert_eq!(log.records(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
