//! Metrics registry: thread-safe counters and fixed-bucket latency
//! histograms, surfaced through the wire protocol's `stats` request.
//!
//! Pool topology: every inference worker owns its own [`Metrics`] (no
//! cross-worker cache-line bouncing on the hot path) and the connection
//! front-end owns one more (shed / bad-frame counters). A [`MetricsHub`]
//! holds them all — plus the server-wide encoded-reply cache — and
//! aggregates into a single [`MetricsSnapshot`] / stats-JSON document on
//! demand, so observers see one logical server regardless of how many
//! workers are running.
//!
//! Dataplane metrics: `queue_wait` measures enqueue→dequeue time per
//! request (the latency cost of batching), `batches_total` /
//! `coalesced_total` / `encodes_total` make coalescing observable
//! (encodes < requests ⇔ the dataplane is amortizing work), and the
//! `segment_cache` section carries the cache's hit/miss/bytes-saved
//! counters.
//!
//! Execution-plane metrics: `phase2_execs_total` counts server-segment
//! executions and `phase2_rows_total` the activation rows they carried —
//! their ratio is the **batch occupancy** (rows per execution; N
//! coalesced same-key uploads should run as ⌈N/EVAL_BATCH⌉ executions,
//! not N). `phase2_padded_rows_total` counts the zero rows the batch
//! ladder padded onto those executions (0 when every chunk hit a ladder
//! rung exactly — the waste the `[1, 8, 32]` ladder exists to cut).
//! `warmed_total` counts `--warm-cache` startup warms, the
//! `compile_cache` section carries the pool-wide compile cache's
//! once-per-key counters, and the `decision_cache` section the
//! Algorithm-2 memoization counters.

use crate::decision::DecisionCache;
use crate::obs::TraceSink;
use crate::sched::EncodedReplyCache;
use crate::store::{CacheStats, StoreTier};
use qpart_core::json::Value;
use qpart_runtime::CompileCache;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Log-spaced latency buckets in microseconds (upper bounds). The
/// Prometheus exposition renders these as cumulative `le` buckets plus a
/// `+Inf` overflow bucket.
pub const BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 1_000_000];

/// A fixed-bucket histogram.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; 12],
    overflow: AtomicU64,
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn observe_us(&self, us: u64) {
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        for (i, &ub) in BUCKETS_US.iter().enumerate() {
            if us <= ub {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        self.overflow.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        self.summary().mean_us()
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_us(&self, q: f64) -> f64 {
        self.summary().quantile_us(q)
    }

    /// Point-in-time plain-number copy (mergeable across workers).
    pub fn summary(&self) -> HistogramSummary {
        let mut buckets = [0u64; 12];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistogramSummary {
            buckets,
            overflow: self.overflow.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }

    pub fn to_json(&self) -> Value {
        self.summary().to_json()
    }
}

/// Plain-number histogram snapshot; the additive unit the hub merges.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HistogramSummary {
    buckets: [u64; 12],
    overflow: u64,
    sum_us: u64,
    count: u64,
}

impl HistogramSummary {
    /// Add another worker's observations into this summary.
    pub fn merge(&mut self, other: &HistogramSummary) {
        for (dst, src) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *dst += src;
        }
        self.overflow += other.overflow;
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// Approximate quantile from bucket boundaries. Quantiles landing in
    /// the overflow bucket clamp to the last finite bound rather than
    /// reporting `inf` — an unplottable, JSON-hostile value for what is
    /// really just ">1s".
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            seen += b;
            if seen >= target {
                return BUCKETS_US[i] as f64;
            }
        }
        BUCKETS_US[BUCKETS_US.len() - 1] as f64
    }

    /// Per-bucket counts (non-cumulative), aligned with [`BUCKETS_US`].
    pub fn bucket_counts(&self) -> [u64; 12] {
        self.buckets
    }

    /// Observations above the last finite bucket bound.
    pub fn overflow(&self) -> u64 {
        self.overflow
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("count", self.count.into()),
            ("mean_us", self.mean_us().into()),
            ("p50_us", self.quantile_us(0.5).into()),
            ("p99_us", self.quantile_us(0.99).into()),
        ])
    }
}

/// All metrics of one worker (or of the connection front-end).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub errors_total: AtomicU64,
    pub shed_total: AtomicU64,
    /// Requests refused by the per-connection fair-queue token bucket
    /// (`--fair-rate`); the client sees a `throttled` error reply.
    pub sched_throttled_total: AtomicU64,
    /// Requests whose `deadline_ms` elapsed while queued — shed at drain
    /// time with a `deadline_exceeded` error reply instead of planned.
    pub deadline_shed_total: AtomicU64,
    /// Requests served at a coarser-than-nominal accuracy level under
    /// brownout (always still within the request's accuracy budget).
    pub degraded_total: AtomicU64,
    /// Current brownout degradation-ladder level (gauge; 0 = no brownout).
    pub brownout_level: AtomicU64,
    /// Brownout entries (level left 0) over the server's lifetime.
    pub brownout_enters_total: AtomicU64,
    /// Brownout exits (level returned to 0) over the server's lifetime.
    pub brownout_exits_total: AtomicU64,
    /// Workers respawned by the supervisor after a death it could not
    /// attribute to shutdown (e.g. a panic that escaped the job guard).
    pub worker_restarts_total: AtomicU64,
    /// Batch executions that overran the `--job-timeout-ms` soft
    /// watchdog (flagged once per stuck episode, not per tick).
    pub job_timeouts_total: AtomicU64,
    /// Live protocol connections (front-end gauge; the reactor makes
    /// this independent of any thread count).
    pub conns_open: AtomicU64,
    /// High-water mark of `conns_open` — the front-end scaling figure
    /// `bench-serve` and the CI fleet-soak read.
    pub conns_open_peak: AtomicU64,
    /// Protocol connections accepted over the server's lifetime.
    pub conns_accepted_total: AtomicU64,
    /// Connections refused at the `--max-conns` accept gate.
    pub conns_rejected_total: AtomicU64,
    /// Connections closed by the idle/slow-client timeout
    /// (`--conn-idle-secs`): slow-loris and half-open peers.
    pub conns_timed_out: AtomicU64,
    /// Bytes currently queued across connection outboxes (gauge) — the
    /// reactor's write-backpressure depth.
    pub outbox_bytes: AtomicU64,
    /// High-water mark of `outbox_bytes`.
    pub outbox_bytes_peak: AtomicU64,
    /// Bytes written to sockets straight out of shared (`Arc`) reply
    /// bodies — egress that skipped the per-connection copy entirely
    /// (counter).
    pub outbox_zero_copy_bytes_total: AtomicU64,
    pub sessions_opened: AtomicU64,
    pub sessions_expired: AtomicU64,
    pub bytes_out: AtomicU64,
    pub bytes_in: AtomicU64,
    /// Batches this worker drained (≥ 1 job each).
    pub batches_total: AtomicU64,
    /// Requests answered from a batch group beyond the group's first —
    /// the requests whose encode was amortized away.
    pub coalesced_total: AtomicU64,
    /// Segment encodes actually performed (quantize + pack + serialize).
    /// Coalescing + caching make this < infer requests under shared keys.
    pub encodes_total: AtomicU64,
    /// Phase-2 server-segment executions (each carries up to EVAL_BATCH
    /// coalesced activation rows).
    pub phase2_execs_total: AtomicU64,
    /// Activation rows executed by phase-2 runs. `rows / execs` is the
    /// batch occupancy the coalescing window buys.
    pub phase2_rows_total: AtomicU64,
    /// Zero rows padded onto phase-2 executions to reach the chosen
    /// batch-ladder rung (a single-row upload at rung 1 pads nothing).
    pub phase2_padded_rows_total: AtomicU64,
    /// Reply keys warmed at startup (`--warm-cache`).
    pub warmed_total: AtomicU64,
    /// End-to-end request handling (decision + quantize + execute).
    pub handle_latency: Histogram,
    /// Algorithm 2 decision time.
    pub decide_latency: Histogram,
    /// Segment quantization + packing time.
    pub quantize_latency: Histogram,
    /// PJRT execution time.
    pub execute_latency: Histogram,
    /// Enqueue → dequeue time per request (batching's latency cost).
    pub queue_wait: Histogram,
}

/// A point-in-time copy (plain numbers) for assertions and reports.
/// For a pooled server this is the **aggregate over all workers** plus the
/// connection front-end — one logical snapshot, per the serving contract.
/// `cache_*` fields come from the server-wide encoded-reply cache and are
/// zero in per-worker snapshots (the cache is shared, not per-worker).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests_total: u64,
    pub errors_total: u64,
    pub shed_total: u64,
    pub sched_throttled_total: u64,
    pub deadline_shed_total: u64,
    pub degraded_total: u64,
    pub brownout_level: u64,
    pub brownout_enters_total: u64,
    pub brownout_exits_total: u64,
    pub worker_restarts_total: u64,
    pub job_timeouts_total: u64,
    pub conns_open: u64,
    pub conns_open_peak: u64,
    pub conns_accepted_total: u64,
    pub conns_rejected_total: u64,
    pub conns_timed_out: u64,
    pub outbox_bytes: u64,
    pub outbox_bytes_peak: u64,
    pub outbox_zero_copy_bytes_total: u64,
    pub sessions_opened: u64,
    pub batches_total: u64,
    pub coalesced_total: u64,
    pub encodes_total: u64,
    pub phase2_execs_total: u64,
    pub phase2_rows_total: u64,
    pub phase2_padded_rows_total: u64,
    pub warmed_total: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Algorithm-2 decision-cache counters (0 in per-worker snapshots;
    /// the cache is shared, not per-worker).
    pub decision_hits: u64,
    pub decision_misses: u64,
    /// Pool-wide compile-cache builds (0 in per-worker snapshots; the
    /// cache is shared, not per-worker).
    pub compilations_total: u64,
    pub handle_count: u64,
    pub handle_mean_us: f64,
    /// Per-stage means (µs): Algorithm-2 planning, segment encode
    /// (quantize+pack+serialize), phase-2 execution — the bench-serve
    /// stage breakdown reads these.
    pub decide_count: u64,
    pub decide_mean_us: f64,
    pub quantize_count: u64,
    pub quantize_mean_us: f64,
    pub execute_count: u64,
    pub execute_mean_us: f64,
    pub queue_wait_count: u64,
    pub queue_wait_mean_us: f64,
}

impl MetricsSnapshot {
    /// Mean activation rows per phase-2 execution (NaN before the first).
    pub fn batch_occupancy_mean(&self) -> f64 {
        self.phase2_rows_total as f64 / self.phase2_execs_total as f64
    }

    /// Fraction of executed phase-2 rows that were ladder padding
    /// (NaN before the first execution). 0.0 ⇔ every chunk hit a rung.
    pub fn padding_waste(&self) -> f64 {
        self.phase2_padded_rows_total as f64
            / (self.phase2_rows_total + self.phase2_padded_rows_total) as f64
    }
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    /// Set a gauge to an absolute value.
    pub fn set(gauge: &AtomicU64, v: u64) {
        gauge.store(v, Ordering::Relaxed);
    }

    /// Increment a gauge, returning the new value (for peak tracking).
    pub fn gauge_inc(gauge: &AtomicU64) -> u64 {
        gauge.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Decrement a gauge (callers pair this with a prior `gauge_inc`).
    pub fn gauge_dec(gauge: &AtomicU64) {
        gauge.fetch_sub(1, Ordering::Relaxed);
    }

    /// Raise a high-water mark to at least `v`.
    pub fn observe_peak(peak: &AtomicU64, v: u64) {
        peak.fetch_max(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_total: self.requests_total.load(Ordering::Relaxed),
            errors_total: self.errors_total.load(Ordering::Relaxed),
            shed_total: self.shed_total.load(Ordering::Relaxed),
            sched_throttled_total: self.sched_throttled_total.load(Ordering::Relaxed),
            deadline_shed_total: self.deadline_shed_total.load(Ordering::Relaxed),
            degraded_total: self.degraded_total.load(Ordering::Relaxed),
            brownout_level: self.brownout_level.load(Ordering::Relaxed),
            brownout_enters_total: self.brownout_enters_total.load(Ordering::Relaxed),
            brownout_exits_total: self.brownout_exits_total.load(Ordering::Relaxed),
            worker_restarts_total: self.worker_restarts_total.load(Ordering::Relaxed),
            job_timeouts_total: self.job_timeouts_total.load(Ordering::Relaxed),
            conns_open: self.conns_open.load(Ordering::Relaxed),
            conns_open_peak: self.conns_open_peak.load(Ordering::Relaxed),
            conns_accepted_total: self.conns_accepted_total.load(Ordering::Relaxed),
            conns_rejected_total: self.conns_rejected_total.load(Ordering::Relaxed),
            conns_timed_out: self.conns_timed_out.load(Ordering::Relaxed),
            outbox_bytes: self.outbox_bytes.load(Ordering::Relaxed),
            outbox_bytes_peak: self.outbox_bytes_peak.load(Ordering::Relaxed),
            outbox_zero_copy_bytes_total: self
                .outbox_zero_copy_bytes_total
                .load(Ordering::Relaxed),
            sessions_opened: self.sessions_opened.load(Ordering::Relaxed),
            batches_total: self.batches_total.load(Ordering::Relaxed),
            coalesced_total: self.coalesced_total.load(Ordering::Relaxed),
            encodes_total: self.encodes_total.load(Ordering::Relaxed),
            phase2_execs_total: self.phase2_execs_total.load(Ordering::Relaxed),
            phase2_rows_total: self.phase2_rows_total.load(Ordering::Relaxed),
            phase2_padded_rows_total: self.phase2_padded_rows_total.load(Ordering::Relaxed),
            warmed_total: self.warmed_total.load(Ordering::Relaxed),
            cache_hits: 0,
            cache_misses: 0,
            decision_hits: 0,
            decision_misses: 0,
            compilations_total: 0,
            handle_count: self.handle_latency.count(),
            handle_mean_us: self.handle_latency.mean_us(),
            decide_count: self.decide_latency.count(),
            decide_mean_us: self.decide_latency.mean_us(),
            quantize_count: self.quantize_latency.count(),
            quantize_mean_us: self.quantize_latency.mean_us(),
            execute_count: self.execute_latency.count(),
            execute_mean_us: self.execute_latency.mean_us(),
            queue_wait_count: self.queue_wait.count(),
            queue_wait_mean_us: self.queue_wait.mean_us(),
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("requests_total", self.requests_total.load(Ordering::Relaxed).into()),
            ("errors_total", self.errors_total.load(Ordering::Relaxed).into()),
            ("shed_total", self.shed_total.load(Ordering::Relaxed).into()),
            (
                "sched_throttled_total",
                self.sched_throttled_total.load(Ordering::Relaxed).into(),
            ),
            (
                "deadline_shed_total",
                self.deadline_shed_total.load(Ordering::Relaxed).into(),
            ),
            ("degraded_total", self.degraded_total.load(Ordering::Relaxed).into()),
            ("brownout_level", self.brownout_level.load(Ordering::Relaxed).into()),
            (
                "brownout_enters_total",
                self.brownout_enters_total.load(Ordering::Relaxed).into(),
            ),
            (
                "brownout_exits_total",
                self.brownout_exits_total.load(Ordering::Relaxed).into(),
            ),
            (
                "worker_restarts_total",
                self.worker_restarts_total.load(Ordering::Relaxed).into(),
            ),
            (
                "job_timeouts_total",
                self.job_timeouts_total.load(Ordering::Relaxed).into(),
            ),
            ("conns_open", self.conns_open.load(Ordering::Relaxed).into()),
            ("conns_open_peak", self.conns_open_peak.load(Ordering::Relaxed).into()),
            (
                "conns_accepted_total",
                self.conns_accepted_total.load(Ordering::Relaxed).into(),
            ),
            (
                "conns_rejected_total",
                self.conns_rejected_total.load(Ordering::Relaxed).into(),
            ),
            ("conns_timed_out", self.conns_timed_out.load(Ordering::Relaxed).into()),
            ("outbox_bytes", self.outbox_bytes.load(Ordering::Relaxed).into()),
            (
                "outbox_bytes_peak",
                self.outbox_bytes_peak.load(Ordering::Relaxed).into(),
            ),
            (
                "outbox_zero_copy_bytes_total",
                self.outbox_zero_copy_bytes_total.load(Ordering::Relaxed).into(),
            ),
            ("sessions_opened", self.sessions_opened.load(Ordering::Relaxed).into()),
            ("sessions_expired", self.sessions_expired.load(Ordering::Relaxed).into()),
            ("bytes_out", self.bytes_out.load(Ordering::Relaxed).into()),
            ("bytes_in", self.bytes_in.load(Ordering::Relaxed).into()),
            ("batches_total", self.batches_total.load(Ordering::Relaxed).into()),
            ("coalesced_total", self.coalesced_total.load(Ordering::Relaxed).into()),
            ("encodes_total", self.encodes_total.load(Ordering::Relaxed).into()),
            (
                "phase2_execs_total",
                self.phase2_execs_total.load(Ordering::Relaxed).into(),
            ),
            ("phase2_rows_total", self.phase2_rows_total.load(Ordering::Relaxed).into()),
            (
                "phase2_padded_rows_total",
                self.phase2_padded_rows_total.load(Ordering::Relaxed).into(),
            ),
            ("warmed_total", self.warmed_total.load(Ordering::Relaxed).into()),
            ("handle", self.handle_latency.to_json()),
            ("decide", self.decide_latency.to_json()),
            ("quantize", self.quantize_latency.to_json()),
            ("execute", self.execute_latency.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
        ])
    }
}

/// Additive plain-number copy of one [`Metrics`]' counters — the unit the
/// hub sums across workers (named fields, so counters can't be shuffled
/// under each other's keys).
#[derive(Debug, Clone, Copy, Default)]
struct CounterTotals {
    requests_total: u64,
    errors_total: u64,
    shed_total: u64,
    sched_throttled_total: u64,
    deadline_shed_total: u64,
    degraded_total: u64,
    brownout_level: u64,
    brownout_enters_total: u64,
    brownout_exits_total: u64,
    worker_restarts_total: u64,
    job_timeouts_total: u64,
    conns_open: u64,
    conns_open_peak: u64,
    conns_accepted_total: u64,
    conns_rejected_total: u64,
    conns_timed_out: u64,
    outbox_bytes: u64,
    outbox_bytes_peak: u64,
    outbox_zero_copy_bytes_total: u64,
    sessions_opened: u64,
    sessions_expired: u64,
    bytes_out: u64,
    bytes_in: u64,
    batches_total: u64,
    coalesced_total: u64,
    encodes_total: u64,
    phase2_execs_total: u64,
    phase2_rows_total: u64,
    phase2_padded_rows_total: u64,
    warmed_total: u64,
}

impl CounterTotals {
    fn of(m: &Metrics) -> CounterTotals {
        CounterTotals {
            requests_total: m.requests_total.load(Ordering::Relaxed),
            errors_total: m.errors_total.load(Ordering::Relaxed),
            shed_total: m.shed_total.load(Ordering::Relaxed),
            sched_throttled_total: m.sched_throttled_total.load(Ordering::Relaxed),
            deadline_shed_total: m.deadline_shed_total.load(Ordering::Relaxed),
            degraded_total: m.degraded_total.load(Ordering::Relaxed),
            brownout_level: m.brownout_level.load(Ordering::Relaxed),
            brownout_enters_total: m.brownout_enters_total.load(Ordering::Relaxed),
            brownout_exits_total: m.brownout_exits_total.load(Ordering::Relaxed),
            worker_restarts_total: m.worker_restarts_total.load(Ordering::Relaxed),
            job_timeouts_total: m.job_timeouts_total.load(Ordering::Relaxed),
            conns_open: m.conns_open.load(Ordering::Relaxed),
            conns_open_peak: m.conns_open_peak.load(Ordering::Relaxed),
            conns_accepted_total: m.conns_accepted_total.load(Ordering::Relaxed),
            conns_rejected_total: m.conns_rejected_total.load(Ordering::Relaxed),
            conns_timed_out: m.conns_timed_out.load(Ordering::Relaxed),
            outbox_bytes: m.outbox_bytes.load(Ordering::Relaxed),
            outbox_bytes_peak: m.outbox_bytes_peak.load(Ordering::Relaxed),
            outbox_zero_copy_bytes_total: m.outbox_zero_copy_bytes_total.load(Ordering::Relaxed),
            sessions_opened: m.sessions_opened.load(Ordering::Relaxed),
            sessions_expired: m.sessions_expired.load(Ordering::Relaxed),
            bytes_out: m.bytes_out.load(Ordering::Relaxed),
            bytes_in: m.bytes_in.load(Ordering::Relaxed),
            batches_total: m.batches_total.load(Ordering::Relaxed),
            coalesced_total: m.coalesced_total.load(Ordering::Relaxed),
            encodes_total: m.encodes_total.load(Ordering::Relaxed),
            phase2_execs_total: m.phase2_execs_total.load(Ordering::Relaxed),
            phase2_rows_total: m.phase2_rows_total.load(Ordering::Relaxed),
            phase2_padded_rows_total: m.phase2_padded_rows_total.load(Ordering::Relaxed),
            warmed_total: m.warmed_total.load(Ordering::Relaxed),
        }
    }

    fn add(&mut self, other: &CounterTotals) {
        self.requests_total += other.requests_total;
        self.errors_total += other.errors_total;
        self.shed_total += other.shed_total;
        self.sched_throttled_total += other.sched_throttled_total;
        self.deadline_shed_total += other.deadline_shed_total;
        self.degraded_total += other.degraded_total;
        // brownout/supervision counters live on the front-end's Metrics
        // only (the controller and supervisor are server-wide), so
        // summing is the identity for workers
        self.brownout_level += other.brownout_level;
        self.brownout_enters_total += other.brownout_enters_total;
        self.brownout_exits_total += other.brownout_exits_total;
        self.worker_restarts_total += other.worker_restarts_total;
        self.job_timeouts_total += other.job_timeouts_total;
        // connection counters live on the front-end's Metrics only, so
        // summing is the identity for workers
        self.conns_open += other.conns_open;
        self.conns_open_peak += other.conns_open_peak;
        self.conns_accepted_total += other.conns_accepted_total;
        self.conns_rejected_total += other.conns_rejected_total;
        self.conns_timed_out += other.conns_timed_out;
        self.outbox_bytes += other.outbox_bytes;
        self.outbox_bytes_peak += other.outbox_bytes_peak;
        self.outbox_zero_copy_bytes_total += other.outbox_zero_copy_bytes_total;
        self.sessions_opened += other.sessions_opened;
        self.sessions_expired += other.sessions_expired;
        self.bytes_out += other.bytes_out;
        self.bytes_in += other.bytes_in;
        self.batches_total += other.batches_total;
        self.coalesced_total += other.coalesced_total;
        self.encodes_total += other.encodes_total;
        self.phase2_execs_total += other.phase2_execs_total;
        self.phase2_rows_total += other.phase2_rows_total;
        self.phase2_padded_rows_total += other.phase2_padded_rows_total;
        self.warmed_total += other.warmed_total;
    }
}

/// Per-device-class overload counters. Connections resolve their
/// hello-declared class label to one of these once (via
/// [`ClassRegistry::class`]) and jobs carry the `Arc` along, so the hot
/// path bumps counters without any map lookups.
#[derive(Debug, Default)]
pub struct ClassCounts {
    /// Fair-queue throttles attributed to this class.
    pub sched_throttled_total: AtomicU64,
    /// Deadline sheds attributed to this class.
    pub deadline_shed_total: AtomicU64,
    /// Brownout degradations attributed to this class.
    pub degraded_total: AtomicU64,
}

impl ClassCounts {
    fn to_json(&self) -> Value {
        Value::obj([
            (
                "sched_throttled_total",
                self.sched_throttled_total.load(Ordering::Relaxed).into(),
            ),
            // alias matching the scrape's `qpart_class_throttled_total`
            // (the ROADMAP follow-up name; both spellings are served)
            (
                "throttled_total",
                self.sched_throttled_total.load(Ordering::Relaxed).into(),
            ),
            (
                "deadline_shed_total",
                self.deadline_shed_total.load(Ordering::Relaxed).into(),
            ),
            ("degraded_total", self.degraded_total.load(Ordering::Relaxed).into()),
        ])
    }
}

/// Registry of per-class counters, keyed by the hello `class` label.
/// Unlabeled connections are not registered — their events only appear in
/// the aggregate counters.
#[derive(Debug, Default)]
pub struct ClassRegistry {
    map: Mutex<HashMap<String, Arc<ClassCounts>>>,
}

impl ClassRegistry {
    /// The counters for `class`, created on first sight.
    pub fn class(&self, class: &str) -> Arc<ClassCounts> {
        let mut map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        Arc::clone(map.entry(class.to_string()).or_default())
    }

    /// All registered classes with their counters, sorted by name (for
    /// deterministic stats documents and scrapes).
    pub fn entries(&self) -> Vec<(String, Arc<ClassCounts>)> {
        let map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        let mut v: Vec<_> = map.iter().map(|(k, c)| (k.clone(), Arc::clone(c))).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }
}

/// A class label safe to embed in a Prometheus label value: Prometheus
/// label escaping is not implemented here, so anything outside
/// `[A-Za-z0-9_.-]` is replaced with `_` (and the scrape's two-token line
/// format survives hostile hello strings).
fn safe_label(class: &str) -> String {
    class
        .chars()
        .map(|ch| if ch.is_ascii_alphanumeric() || "_.-".contains(ch) { ch } else { '_' })
        .collect()
}

/// Result of one aggregation walk over the hub (see [`MetricsHub::snapshot`]
/// and [`MetricsHub::to_json`]).
struct Aggregate {
    totals: CounterTotals,
    handle: HistogramSummary,
    decide: HistogramSummary,
    quantize: HistogramSummary,
    execute: HistogramSummary,
    queue_wait: HistogramSummary,
    per_worker: Vec<Value>,
}

/// Registry for the executor pool: one [`Metrics`] per worker plus one for
/// the connection front-end, aggregated on demand. The server-wide
/// [`EncodedReplyCache`] registers here too, so the `stats` document and
/// snapshot carry its counters alongside the workers'.
#[derive(Debug, Default)]
pub struct MetricsHub {
    front: Arc<Metrics>,
    workers: Mutex<Vec<Arc<Metrics>>>,
    classes: Arc<ClassRegistry>,
    segment_cache: Mutex<Option<Arc<EncodedReplyCache>>>,
    compile_cache: Mutex<Option<Arc<CompileCache>>>,
    decision_cache: Mutex<Option<Arc<DecisionCache>>>,
    store: Mutex<Option<Arc<StoreTier>>>,
    trace: Mutex<Option<Arc<TraceSink>>>,
}

impl MetricsHub {
    pub fn new() -> MetricsHub {
        MetricsHub::default()
    }

    /// The connection front-end's metrics (shed / bad-frame counters).
    pub fn front(&self) -> Arc<Metrics> {
        Arc::clone(&self.front)
    }

    /// The per-device-class counter registry (hello `class` labels).
    pub fn classes(&self) -> Arc<ClassRegistry> {
        Arc::clone(&self.classes)
    }

    /// Allocate and register a fresh per-worker [`Metrics`].
    pub fn register_worker(&self) -> Arc<Metrics> {
        let m = Arc::new(Metrics::default());
        self.workers.lock().unwrap().push(Arc::clone(&m));
        m
    }

    /// Register the server-wide encoded-reply cache so its counters are
    /// surfaced in snapshots and the stats document.
    pub fn register_segment_cache(&self, cache: Arc<EncodedReplyCache>) {
        *self.segment_cache.lock().unwrap() = Some(cache);
    }

    /// The registered encoded-reply cache, if any.
    pub fn segment_cache(&self) -> Option<Arc<EncodedReplyCache>> {
        self.segment_cache.lock().unwrap().clone()
    }

    /// Register the pool-wide compile cache so its once-per-key counters
    /// are surfaced in snapshots and the stats document.
    pub fn register_compile_cache(&self, cache: Arc<CompileCache>) {
        *self.compile_cache.lock().unwrap() = Some(cache);
    }

    /// The registered compile cache, if any.
    pub fn compile_cache(&self) -> Option<Arc<CompileCache>> {
        self.compile_cache.lock().unwrap().clone()
    }

    /// Register the server-wide Algorithm-2 decision cache so its
    /// hit/miss/entry counters surface in snapshots and the stats
    /// document's `decision_cache` section.
    pub fn register_decision_cache(&self, cache: Arc<DecisionCache>) {
        *self.decision_cache.lock().unwrap() = Some(cache);
    }

    /// The registered decision cache, if any.
    pub fn decision_cache(&self) -> Option<Arc<DecisionCache>> {
        self.decision_cache.lock().unwrap().clone()
    }

    /// Register the durable store tier (`--store-dir`) so the stats
    /// document carries a `store` section and the scrape the
    /// `qpart_store_*` series.
    pub fn register_store(&self, tier: Arc<StoreTier>) {
        *self.store.lock().unwrap() = Some(tier);
    }

    /// The registered store tier, if any.
    pub fn store(&self) -> Option<Arc<StoreTier>> {
        self.store.lock().unwrap().clone()
    }

    /// Register the server-wide trace sink so the metrics listener can
    /// serve `/trace` endpoints and the scrape can expose trace gauges.
    pub fn register_trace_sink(&self, sink: Arc<TraceSink>) {
        *self.trace.lock().unwrap() = Some(sink);
    }

    /// The registered trace sink, if any.
    pub fn trace_sink(&self) -> Option<Arc<TraceSink>> {
        self.trace.lock().unwrap().clone()
    }

    pub fn num_workers(&self) -> usize {
        self.workers.lock().unwrap().len()
    }

    /// Per-worker snapshots (diagnostics; ordering = registration order).
    pub fn worker_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.workers.lock().unwrap().iter().map(|m| m.snapshot()).collect()
    }

    /// Single lock-and-merge walk over the front-end and every worker —
    /// the one place the aggregate view is computed, shared by
    /// [`MetricsHub::snapshot`] and [`MetricsHub::to_json`]. Returns the
    /// per-worker stats documents too when `with_worker_json` is set (one
    /// walk, one lock).
    fn aggregate(&self, with_worker_json: bool) -> Aggregate {
        let workers = self.workers.lock().unwrap();
        let mut agg = Aggregate {
            totals: CounterTotals::of(&self.front),
            handle: self.front.handle_latency.summary(),
            decide: self.front.decide_latency.summary(),
            quantize: self.front.quantize_latency.summary(),
            execute: self.front.execute_latency.summary(),
            queue_wait: self.front.queue_wait.summary(),
            per_worker: Vec::with_capacity(if with_worker_json { workers.len() } else { 0 }),
        };
        for m in workers.iter() {
            agg.totals.add(&CounterTotals::of(m));
            agg.handle.merge(&m.handle_latency.summary());
            agg.decide.merge(&m.decide_latency.summary());
            agg.quantize.merge(&m.quantize_latency.summary());
            agg.execute.merge(&m.execute_latency.summary());
            agg.queue_wait.merge(&m.queue_wait.summary());
            if with_worker_json {
                agg.per_worker.push(m.to_json());
            }
        }
        agg
    }

    /// Aggregated summary of one named pipeline histogram — `"handle"`,
    /// `"decide"`, `"quantize"`, `"execute"`, or `"queue_wait"` — for
    /// tests and tooling that need bucket-level access.
    pub fn histogram_summary(&self, name: &str) -> Option<HistogramSummary> {
        let agg = self.aggregate(false);
        match name {
            "handle" => Some(agg.handle),
            "decide" => Some(agg.decide),
            "quantize" => Some(agg.quantize),
            "execute" => Some(agg.execute),
            "queue_wait" => Some(agg.queue_wait),
            _ => None,
        }
    }

    /// One aggregated snapshot over the front-end and every worker.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let agg = self.aggregate(false);
        let (cache_hits, cache_misses) = match self.segment_cache() {
            Some(c) => (c.hits(), c.misses()),
            None => (0, 0),
        };
        let (decision_hits, decision_misses) = match self.decision_cache() {
            Some(c) => (c.hits(), c.misses()),
            None => (0, 0),
        };
        let compilations_total =
            self.compile_cache().map(|c| c.compilations()).unwrap_or(0);
        MetricsSnapshot {
            requests_total: agg.totals.requests_total,
            errors_total: agg.totals.errors_total,
            shed_total: agg.totals.shed_total,
            sched_throttled_total: agg.totals.sched_throttled_total,
            deadline_shed_total: agg.totals.deadline_shed_total,
            degraded_total: agg.totals.degraded_total,
            brownout_level: agg.totals.brownout_level,
            brownout_enters_total: agg.totals.brownout_enters_total,
            brownout_exits_total: agg.totals.brownout_exits_total,
            worker_restarts_total: agg.totals.worker_restarts_total,
            job_timeouts_total: agg.totals.job_timeouts_total,
            conns_open: agg.totals.conns_open,
            conns_open_peak: agg.totals.conns_open_peak,
            conns_accepted_total: agg.totals.conns_accepted_total,
            conns_rejected_total: agg.totals.conns_rejected_total,
            conns_timed_out: agg.totals.conns_timed_out,
            outbox_bytes: agg.totals.outbox_bytes,
            outbox_bytes_peak: agg.totals.outbox_bytes_peak,
            outbox_zero_copy_bytes_total: agg.totals.outbox_zero_copy_bytes_total,
            sessions_opened: agg.totals.sessions_opened,
            batches_total: agg.totals.batches_total,
            coalesced_total: agg.totals.coalesced_total,
            encodes_total: agg.totals.encodes_total,
            phase2_execs_total: agg.totals.phase2_execs_total,
            phase2_rows_total: agg.totals.phase2_rows_total,
            phase2_padded_rows_total: agg.totals.phase2_padded_rows_total,
            warmed_total: agg.totals.warmed_total,
            cache_hits,
            cache_misses,
            decision_hits,
            decision_misses,
            compilations_total,
            handle_count: agg.handle.count(),
            handle_mean_us: agg.handle.mean_us(),
            decide_count: agg.decide.count(),
            decide_mean_us: agg.decide.mean_us(),
            quantize_count: agg.quantize.count(),
            quantize_mean_us: agg.quantize.mean_us(),
            execute_count: agg.execute.count(),
            execute_mean_us: agg.execute.mean_us(),
            queue_wait_count: agg.queue_wait.count(),
            queue_wait_mean_us: agg.queue_wait.mean_us(),
        }
    }

    /// Aggregated stats document: one logical server view plus a
    /// `workers` array with each worker's own counters and the
    /// encoded-reply cache's `segment_cache` section.
    pub fn to_json(&self) -> Value {
        let agg = self.aggregate(true);
        let mut v = Value::obj([
            ("requests_total", agg.totals.requests_total.into()),
            ("errors_total", agg.totals.errors_total.into()),
            ("shed_total", agg.totals.shed_total.into()),
            ("sched_throttled_total", agg.totals.sched_throttled_total.into()),
            ("deadline_shed_total", agg.totals.deadline_shed_total.into()),
            ("degraded_total", agg.totals.degraded_total.into()),
            ("brownout_level", agg.totals.brownout_level.into()),
            ("brownout_enters_total", agg.totals.brownout_enters_total.into()),
            ("brownout_exits_total", agg.totals.brownout_exits_total.into()),
            ("worker_restarts_total", agg.totals.worker_restarts_total.into()),
            ("job_timeouts_total", agg.totals.job_timeouts_total.into()),
            ("conns_open", agg.totals.conns_open.into()),
            ("conns_open_peak", agg.totals.conns_open_peak.into()),
            ("conns_accepted_total", agg.totals.conns_accepted_total.into()),
            ("conns_rejected_total", agg.totals.conns_rejected_total.into()),
            ("conns_timed_out", agg.totals.conns_timed_out.into()),
            ("outbox_bytes", agg.totals.outbox_bytes.into()),
            ("outbox_bytes_peak", agg.totals.outbox_bytes_peak.into()),
            (
                "outbox_zero_copy_bytes_total",
                agg.totals.outbox_zero_copy_bytes_total.into(),
            ),
            ("sessions_opened", agg.totals.sessions_opened.into()),
            ("sessions_expired", agg.totals.sessions_expired.into()),
            ("bytes_out", agg.totals.bytes_out.into()),
            ("bytes_in", agg.totals.bytes_in.into()),
            ("batches_total", agg.totals.batches_total.into()),
            ("coalesced_total", agg.totals.coalesced_total.into()),
            ("encodes_total", agg.totals.encodes_total.into()),
            ("phase2_execs_total", agg.totals.phase2_execs_total.into()),
            ("phase2_rows_total", agg.totals.phase2_rows_total.into()),
            ("phase2_padded_rows_total", agg.totals.phase2_padded_rows_total.into()),
            (
                "batch_occupancy_mean",
                (agg.totals.phase2_rows_total as f64 / agg.totals.phase2_execs_total as f64)
                    .into(),
            ),
            ("warmed_total", agg.totals.warmed_total.into()),
            ("handle", agg.handle.to_json()),
            ("decide", agg.decide.to_json()),
            ("quantize", agg.quantize.to_json()),
            ("execute", agg.execute.to_json()),
            ("queue_wait", agg.queue_wait.to_json()),
            ("workers", Value::Arr(agg.per_worker)),
        ]);
        let classes = self.classes.entries();
        if !classes.is_empty() {
            v.set(
                "per_class",
                Value::Obj(classes.into_iter().map(|(name, c)| (name, c.to_json())).collect()),
            );
        }
        if let Some(cache) = self.segment_cache() {
            v.set("segment_cache", cache.to_json());
        }
        if let Some(cache) = self.compile_cache() {
            v.set("compile_cache", cache.to_json());
        }
        if let Some(cache) = self.decision_cache() {
            v.set("decision_cache", cache.to_json());
        }
        // the unified cache-stats section: one [`CacheStats`] shape per
        // cache, keyed by the scrape's `cache=` label values (the
        // per-cache sections above are legacy aliases, kept one release)
        let caches: Vec<(String, Value)> = self
            .cache_stats()
            .into_iter()
            .map(|(label, stats)| (label.to_string(), stats.to_json()))
            .collect();
        if !caches.is_empty() {
            v.set("caches", Value::Obj(caches));
        }
        if let Some(tier) = self.store() {
            v.set("store", tier.to_json());
        }
        v
    }

    /// The unified [`CacheStats`] of every registered cache, labelled as
    /// the scrape and the stats document's `caches` section key them.
    /// The compile cache has no byte accounting or eviction (compiled
    /// artifacts live for the server's lifetime), so those read 0.
    fn cache_stats(&self) -> Vec<(&'static str, CacheStats)> {
        let mut out = Vec::new();
        if let Some(cache) = self.segment_cache() {
            out.push(("reply", cache.stats()));
        }
        if let Some(cache) = self.decision_cache() {
            out.push(("decision", cache.stats()));
        }
        if let Some(cache) = self.compile_cache() {
            out.push((
                "compile",
                CacheStats {
                    hits: cache.hits(),
                    misses: cache.misses(),
                    entries: (cache.exec_len() + cache.prepared_len() + cache.plan_len())
                        as u64,
                    bytes: 0,
                    evictions: 0,
                },
            ));
        }
        out
    }

    /// The plaintext scrape document for the `--metrics-listen` endpoint,
    /// Prometheus exposition format: `# HELP` / `# TYPE` comments per
    /// metric, `qpart_<name> <value>` sample lines, and full cumulative
    /// `le`-labelled `_bucket` series (overflow rendered as `+Inf`) plus
    /// `_sum` / `_count` for every latency histogram. Non-finite derived
    /// values (means before the first sample) are omitted rather than
    /// printed as `NaN`. Slow-request exemplars behind the histograms are
    /// served at `/trace/slow` on the same listener.
    pub fn render_prometheus(&self) -> String {
        fn put(out: &mut String, name: &str, typ: &str, help: &str, v: f64) {
            use std::fmt::Write as _;
            if v.is_finite() {
                let _ = writeln!(out, "# HELP qpart_{name} {help}");
                let _ = writeln!(out, "# TYPE qpart_{name} {typ}");
                let _ = writeln!(out, "qpart_{name} {v}");
            }
        }
        fn put_hist(out: &mut String, name: &str, help: &str, h: &HistogramSummary) {
            use std::fmt::Write as _;
            let _ = writeln!(out, "# HELP qpart_{name}_us {help}");
            let _ = writeln!(out, "# TYPE qpart_{name}_us histogram");
            let mut cum = 0u64;
            for (i, &ub) in BUCKETS_US.iter().enumerate() {
                cum += h.bucket_counts()[i];
                let _ = writeln!(out, "qpart_{name}_us_bucket{{le=\"{ub}\"}} {cum}");
            }
            let _ = writeln!(out, "qpart_{name}_us_bucket{{le=\"+Inf\"}} {}", h.count());
            let _ = writeln!(out, "qpart_{name}_us_sum {}", h.sum_us());
            let _ = writeln!(out, "qpart_{name}_us_count {}", h.count());
        }
        let agg = self.aggregate(false);
        let t = &agg.totals;
        let (cache_hits, cache_misses) = match self.segment_cache() {
            Some(c) => (c.hits(), c.misses()),
            None => (0, 0),
        };
        let (decision_hits, decision_misses) = match self.decision_cache() {
            Some(c) => (c.hits(), c.misses()),
            None => (0, 0),
        };
        let compilations_total = self.compile_cache().map(|c| c.compilations()).unwrap_or(0);
        let mut out = String::with_capacity(8192);
        let c = "counter";
        let g = "gauge";
        put(&mut out, "requests_total", c, "Requests handled", t.requests_total as f64);
        put(&mut out, "errors_total", c, "Error replies sent", t.errors_total as f64);
        put(&mut out, "shed_total", c, "Requests shed by admission control", t.shed_total as f64);
        put(
            &mut out,
            "sched_throttled_total",
            c,
            "Requests refused by the per-connection fair-queue rate limit",
            t.sched_throttled_total as f64,
        );
        put(
            &mut out,
            "deadline_shed_total",
            c,
            "Requests dropped at drain time because their deadline had already passed",
            t.deadline_shed_total as f64,
        );
        put(
            &mut out,
            "degraded_total",
            c,
            "Requests served at a brownout-coarsened quantization level (within budget)",
            t.degraded_total as f64,
        );
        put(
            &mut out,
            "brownout_level",
            g,
            "Current brownout degradation-ladder level (0 = nominal)",
            t.brownout_level as f64,
        );
        put(
            &mut out,
            "brownout_enters_total",
            c,
            "Brownout ladder step-ups",
            t.brownout_enters_total as f64,
        );
        put(
            &mut out,
            "brownout_exits_total",
            c,
            "Brownout ladder step-downs",
            t.brownout_exits_total as f64,
        );
        put(
            &mut out,
            "worker_restarts_total",
            c,
            "Worker threads respawned by the supervisor after a panic",
            t.worker_restarts_total as f64,
        );
        put(
            &mut out,
            "job_timeouts_total",
            c,
            "Stuck-job episodes flagged by the soft watchdog",
            t.job_timeouts_total as f64,
        );
        {
            use std::fmt::Write as _;
            let classes = self.classes.entries();
            if !classes.is_empty() {
                for (metric, help, pick) in [
                    (
                        "class_sched_throttled_total",
                        "Fair-queue throttles by device class",
                        0usize,
                    ),
                    // the ROADMAP follow-up name (PR 6): same counter,
                    // both spellings served
                    (
                        "class_throttled_total",
                        "Fair-queue throttles by device class (alias)",
                        0,
                    ),
                    ("class_deadline_shed_total", "Deadline sheds by device class", 1),
                    ("class_degraded_total", "Brownout degradations by device class", 2),
                ] {
                    let _ = writeln!(out, "# HELP qpart_{metric} {help}");
                    let _ = writeln!(out, "# TYPE qpart_{metric} counter");
                    for (name, counts) in &classes {
                        let v = match pick {
                            0 => counts.sched_throttled_total.load(Ordering::Relaxed),
                            1 => counts.deadline_shed_total.load(Ordering::Relaxed),
                            _ => counts.degraded_total.load(Ordering::Relaxed),
                        };
                        let _ = writeln!(
                            out,
                            "qpart_{metric}{{class=\"{}\"}} {v}",
                            safe_label(name)
                        );
                    }
                }
            }
        }
        put(&mut out, "conns_open", g, "Live protocol connections", t.conns_open as f64);
        put(
            &mut out,
            "conns_open_peak",
            g,
            "High-water mark of open connections",
            t.conns_open_peak as f64,
        );
        put(
            &mut out,
            "conns_accepted_total",
            c,
            "Protocol connections accepted",
            t.conns_accepted_total as f64,
        );
        put(
            &mut out,
            "conns_rejected_total",
            c,
            "Connections refused at the max-conns accept gate",
            t.conns_rejected_total as f64,
        );
        put(
            &mut out,
            "conns_timed_out",
            c,
            "Connections closed by the idle/slow-client timeout",
            t.conns_timed_out as f64,
        );
        put(
            &mut out,
            "outbox_bytes",
            g,
            "Bytes queued across connection outboxes",
            t.outbox_bytes as f64,
        );
        put(
            &mut out,
            "outbox_bytes_peak",
            g,
            "High-water mark of queued outbox bytes",
            t.outbox_bytes_peak as f64,
        );
        put(
            &mut out,
            "outbox_zero_copy_bytes_total",
            c,
            "Bytes written to sockets straight from shared reply bodies (no per-connection copy)",
            t.outbox_zero_copy_bytes_total as f64,
        );
        put(&mut out, "sessions_opened", c, "Two-phase sessions opened", t.sessions_opened as f64);
        put(
            &mut out,
            "sessions_expired",
            c,
            "Sessions expired by the TTL sweep",
            t.sessions_expired as f64,
        );
        put(&mut out, "bytes_in", c, "Payload bytes received", t.bytes_in as f64);
        put(&mut out, "bytes_out", c, "Payload bytes sent", t.bytes_out as f64);
        put(&mut out, "batches_total", c, "Batches drained by workers", t.batches_total as f64);
        put(
            &mut out,
            "coalesced_total",
            c,
            "Requests answered from a batch group beyond its first",
            t.coalesced_total as f64,
        );
        put(&mut out, "encodes_total", c, "Segment encodes performed", t.encodes_total as f64);
        put(
            &mut out,
            "phase2_execs_total",
            c,
            "Phase-2 server-segment executions",
            t.phase2_execs_total as f64,
        );
        put(
            &mut out,
            "phase2_rows_total",
            c,
            "Activation rows executed by phase-2 runs",
            t.phase2_rows_total as f64,
        );
        put(
            &mut out,
            "phase2_padded_rows_total",
            c,
            "Zero rows padded onto phase-2 executions by the batch ladder",
            t.phase2_padded_rows_total as f64,
        );
        put(
            &mut out,
            "batch_occupancy_mean",
            g,
            "Mean activation rows per phase-2 execution",
            t.phase2_rows_total as f64 / t.phase2_execs_total as f64,
        );
        put(
            &mut out,
            "padding_waste",
            g,
            "Fraction of executed phase-2 rows that were ladder padding",
            t.phase2_padded_rows_total as f64
                / (t.phase2_rows_total + t.phase2_padded_rows_total) as f64,
        );
        put(&mut out, "warmed_total", c, "Reply keys warmed at startup", t.warmed_total as f64);
        put(&mut out, "segment_cache_hits", c, "Encoded-reply cache hits", cache_hits as f64);
        put(&mut out, "segment_cache_misses", c, "Encoded-reply cache misses", cache_misses as f64);
        put(
            &mut out,
            "decision_cache_hits",
            c,
            "Algorithm-2 decision cache hits",
            decision_hits as f64,
        );
        put(
            &mut out,
            "decision_cache_misses",
            c,
            "Algorithm-2 decision cache misses",
            decision_misses as f64,
        );
        put(
            &mut out,
            "compilations_total",
            c,
            "Pool-wide compile-cache builds",
            compilations_total as f64,
        );
        {
            // the unified labelled cache series (one set of names, a
            // `cache=` label per cache — the per-cache spellings above
            // are legacy aliases)
            use std::fmt::Write as _;
            let caches = self.cache_stats();
            if !caches.is_empty() {
                for (metric, typ, help, pick) in [
                    ("cache_hits_total", c, "Cache hits by cache", 0usize),
                    ("cache_misses_total", c, "Cache misses by cache", 1),
                    ("cache_entries", g, "Resident cache entries by cache", 2),
                    ("cache_bytes", g, "Resident cache bytes by cache", 3),
                    ("cache_evictions_total", c, "Cache evictions by cache", 4),
                ] {
                    let _ = writeln!(out, "# HELP qpart_{metric} {help}");
                    let _ = writeln!(out, "# TYPE qpart_{metric} {typ}");
                    for (label, s) in &caches {
                        let v = match pick {
                            0 => s.hits,
                            1 => s.misses,
                            2 => s.entries,
                            3 => s.bytes,
                            _ => s.evictions,
                        };
                        let _ = writeln!(out, "qpart_{metric}{{cache=\"{label}\"}} {v}");
                    }
                }
            }
        }
        if let Some(tier) = self.store() {
            let (records, log_bytes, live, corrupt, io_errors, compactions, flushes) =
                tier.counters();
            put(
                &mut out,
                "store_records_total",
                c,
                "Records appended to the segment log",
                records as f64,
            );
            put(&mut out, "store_log_bytes", g, "Segment log size on disk", log_bytes as f64);
            put(
                &mut out,
                "store_live_entries",
                g,
                "Live keys in the segment log",
                live as f64,
            );
            put(
                &mut out,
                "store_corrupt_records_total",
                c,
                "CRC-corrupt records skipped at log replay",
                corrupt as f64,
            );
            put(
                &mut out,
                "store_io_errors_total",
                c,
                "Segment-log append/encode failures",
                io_errors as f64,
            );
            put(
                &mut out,
                "store_compactions_total",
                c,
                "Live-key rewrites of the segment log",
                compactions as f64,
            );
            put(
                &mut out,
                "store_flushes_total",
                c,
                "Staged-op flushes into the segment log",
                flushes as f64,
            );
        }
        if let Some(sink) = self.trace_sink() {
            put(
                &mut out,
                "traces_stored",
                g,
                "Trace timelines held in the bounded trace store",
                sink.stored() as f64,
            );
            put(
                &mut out,
                "trace_spans_dropped_total",
                c,
                "Spans dropped at full ring buffers or store eviction",
                sink.spans_dropped() as f64,
            );
        }
        put_hist(
            &mut out,
            "handle_latency",
            "End-to-end request handling time (slow exemplars: /trace/slow)",
            &agg.handle,
        );
        put_hist(&mut out, "decide_latency", "Algorithm 2 decision time", &agg.decide);
        put_hist(
            &mut out,
            "quantize_latency",
            "Segment quantization + packing time",
            &agg.quantize,
        );
        put_hist(&mut out, "execute_latency", "PJRT execution time", &agg.execute);
        put_hist(
            &mut out,
            "queue_wait",
            "Enqueue-to-dequeue wait per request (slow exemplars: /trace/slow)",
            &agg.queue_wait,
        );
        out
    }

    /// [`MetricsHub::render_prometheus`] plus the session gauge, framed
    /// as a minimal HTTP/1.0 response — the single source of truth for
    /// the `--metrics-listen` scrape, shared by the reactor and the
    /// thread-per-connection fallback so their output cannot diverge.
    pub fn scrape_http_response(&self, open_sessions: usize) -> Vec<u8> {
        let mut body = self.render_prometheus();
        body.push_str(&format!("qpart_open_sessions {open_sessions}\n"));
        http_frame("200 OK", "text/plain", body.as_bytes())
    }

    /// Route one metrics-listener request to its response: `/trace` (the
    /// stored-timeline index), `/trace?id=<id>` (one JSON timeline),
    /// `/trace/slow` (the slow-request exemplars), anything else → the
    /// Prometheus scrape. Trace paths answer `404` when no [`TraceSink`]
    /// is registered or the id is unknown, so scrapers can tell "tracing
    /// off" from "empty".
    pub fn http_response(&self, path: &str, open_sessions: usize) -> Vec<u8> {
        let Some(rest) = path.strip_prefix("/trace") else {
            return self.scrape_http_response(open_sessions);
        };
        let Some(sink) = self.trace_sink() else {
            let body: &[u8] = b"{\"error\":\"tracing disabled\"}";
            return http_frame("404 Not Found", "application/json", body);
        };
        match rest {
            "" => http_frame("200 OK", "application/json", sink.list_json().as_bytes()),
            "/slow" => http_frame("200 OK", "application/json", sink.slow_json().as_bytes()),
            _ => {
                let id = rest.strip_prefix("?id=").and_then(|q| q.parse::<u64>().ok());
                match id.and_then(|id| sink.trace_json(id)) {
                    Some(doc) => http_frame("200 OK", "application/json", doc.as_bytes()),
                    None => http_frame(
                        "404 Not Found",
                        "application/json",
                        b"{\"error\":\"unknown trace\"}",
                    ),
                }
            }
        }
    }
}

/// Minimal HTTP/1.0 framing shared by the scrape and `/trace` endpoints.
fn http_frame(status: &str, content_type: &str, body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + 128);
    out.extend_from_slice(b"HTTP/1.0 ");
    out.extend_from_slice(status.as_bytes());
    out.extend_from_slice(b"\r\nContent-Type: ");
    out.extend_from_slice(content_type.as_bytes());
    out.extend_from_slice(b"; charset=utf-8\r\nConnection: close\r\nContent-Length: ");
    out.extend_from_slice(body.len().to_string().as_bytes());
    out.extend_from_slice(b"\r\n\r\n");
    out.extend_from_slice(body);
    out
}

/// Extract the request path from an HTTP request head (`GET /x HTTP/1.0`),
/// defaulting to `/metrics` when the head is absent or malformed — the
/// pre-trace scrape behavior, so bare probes keep working.
pub fn request_path(head: &str) -> &str {
    head.lines()
        .next()
        .and_then(|line| line.split_whitespace().nth(1))
        .unwrap_or("/metrics")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        for us in [10u64, 60, 300, 300, 700, 2_000_000] {
            h.observe_us(us);
        }
        assert_eq!(h.count(), 6);
        assert!((h.mean_us() - (10. + 60. + 300. + 300. + 700. + 2e6) / 6.0).abs() < 1e-6);
        // p50 lands in the 250 or 500 bucket
        let p50 = h.quantile_us(0.5);
        assert!(p50 <= 500.0, "{p50}");
        // overflow-bucket quantiles clamp to the last finite bound
        assert_eq!(h.quantile_us(0.999), 1_000_000.0, "overflow bucket");
    }

    #[test]
    fn snapshot_counts() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_total);
        Metrics::inc(&m.requests_total);
        Metrics::inc(&m.errors_total);
        m.handle_latency.observe_us(100);
        m.queue_wait.observe_us(40);
        let s = m.snapshot();
        assert_eq!(s.requests_total, 2);
        assert_eq!(s.errors_total, 1);
        assert_eq!(s.handle_count, 1);
        assert_eq!(s.queue_wait_count, 1);
        assert!((s.queue_wait_mean_us - 40.0).abs() < 1e-9);
    }

    #[test]
    fn json_has_all_sections() {
        let m = Metrics::default();
        let v = m.to_json();
        for key in
            ["requests_total", "handle", "decide", "quantize", "execute", "queue_wait",
             "batches_total", "coalesced_total", "encodes_total", "phase2_execs_total",
             "phase2_rows_total", "warmed_total"]
        {
            assert!(v.get(key).is_some(), "{key}");
        }
    }

    #[test]
    fn phase2_counters_aggregate_and_expose_occupancy() {
        let hub = MetricsHub::new();
        let w1 = hub.register_worker();
        let w2 = hub.register_worker();
        Metrics::inc(&w1.phase2_execs_total);
        Metrics::add(&w1.phase2_rows_total, 32);
        Metrics::inc(&w2.phase2_execs_total);
        Metrics::add(&w2.phase2_rows_total, 8);
        Metrics::inc(&w2.warmed_total);
        let snap = hub.snapshot();
        assert_eq!(snap.phase2_execs_total, 2);
        assert_eq!(snap.phase2_rows_total, 40);
        assert_eq!(snap.warmed_total, 1);
        assert!((snap.batch_occupancy_mean() - 20.0).abs() < 1e-9);
        let v = hub.to_json();
        assert_eq!(v.req_f64("phase2_rows_total").unwrap() as u64, 40);
        assert_eq!(v.req_f64("batch_occupancy_mean").unwrap(), 20.0);
    }

    #[test]
    fn padded_rows_aggregate_and_expose_waste() {
        let hub = MetricsHub::new();
        let w1 = hub.register_worker();
        let w2 = hub.register_worker();
        Metrics::inc(&w1.phase2_execs_total);
        Metrics::add(&w1.phase2_rows_total, 7);
        Metrics::add(&w1.phase2_padded_rows_total, 1); // 7 rows @ rung 8
        Metrics::inc(&w2.phase2_execs_total);
        Metrics::add(&w2.phase2_rows_total, 1); // 1 row @ rung 1, no pad
        let snap = hub.snapshot();
        assert_eq!(snap.phase2_padded_rows_total, 1);
        assert!((snap.padding_waste() - 1.0 / 9.0).abs() < 1e-12);
        let v = hub.to_json();
        assert_eq!(v.req_f64("phase2_padded_rows_total").unwrap() as u64, 1);
    }

    #[test]
    fn snapshot_carries_stage_means() {
        let hub = MetricsHub::new();
        let w = hub.register_worker();
        w.decide_latency.observe_us(10);
        w.decide_latency.observe_us(30);
        w.quantize_latency.observe_us(500);
        w.execute_latency.observe_us(2000);
        let snap = hub.snapshot();
        assert_eq!(snap.decide_count, 2);
        assert!((snap.decide_mean_us - 20.0).abs() < 1e-9);
        assert_eq!(snap.quantize_count, 1);
        assert!((snap.quantize_mean_us - 500.0).abs() < 1e-9);
        assert_eq!(snap.execute_count, 1);
        assert!((snap.execute_mean_us - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn hub_surfaces_registered_decision_cache() {
        let hub = MetricsHub::new();
        assert!(hub.to_json().get("decision_cache").is_none(), "absent until registered");
        assert_eq!(hub.snapshot().decision_hits, 0);
        use crate::decision::ProfileBucket;
        use qpart_core::cost::CostModel;
        let cache = Arc::new(DecisionCache::new());
        hub.register_decision_cache(Arc::clone(&cache));
        let key = ("m".to_string(), 0, ProfileBucket::of(&CostModel::paper_default()));
        let _ = cache.get(&key); // one miss
        let snap = hub.snapshot();
        assert_eq!(snap.decision_misses, 1);
        assert_eq!(snap.decision_hits, 0);
        let v = hub.to_json();
        assert_eq!(v.req("decision_cache").unwrap().req_f64("misses").unwrap(), 1.0);
    }

    #[test]
    fn hub_surfaces_registered_compile_cache() {
        let hub = MetricsHub::new();
        assert!(hub.to_json().get("compile_cache").is_none(), "absent until registered");
        assert_eq!(hub.snapshot().compilations_total, 0);
        let cache = Arc::new(CompileCache::new());
        hub.register_compile_cache(Arc::clone(&cache));
        let v = hub.to_json();
        let section = v.req("compile_cache").unwrap();
        assert_eq!(section.req_f64("compilations").unwrap(), 0.0);
        assert_eq!(section.req_f64("max_compiles_per_key").unwrap(), 0.0);
    }

    #[test]
    fn unified_caches_section_and_labelled_scrape() {
        let hub = MetricsHub::new();
        assert!(hub.to_json().get("caches").is_none(), "absent until a cache registers");
        let reply = Arc::new(EncodedReplyCache::new(1 << 20));
        let decision = Arc::new(DecisionCache::new());
        hub.register_segment_cache(Arc::clone(&reply));
        hub.register_decision_cache(Arc::clone(&decision));
        let _ = reply.get(&("m".to_string(), 0, 1)); // one reply miss
        let v = hub.to_json();
        let caches = v.req("caches").unwrap();
        for label in ["reply", "decision"] {
            let section = caches.req(label).unwrap();
            for k in ["hits", "misses", "entries", "bytes", "evictions"] {
                assert!(section.get(k).is_some(), "{label}.{k}");
            }
        }
        assert_eq!(caches.req("reply").unwrap().req_f64("misses").unwrap(), 1.0);
        // legacy alias sections still served
        assert!(v.get("segment_cache").is_some());
        assert!(v.get("decision_cache").is_some());
        let body = hub.render_prometheus();
        assert!(body.contains("qpart_cache_misses_total{cache=\"reply\"} 1\n"), "{body}");
        assert!(body.contains("qpart_cache_hits_total{cache=\"decision\"} 0\n"), "{body}");
        assert!(body.contains("qpart_cache_entries{cache=\"reply\"} 0\n"), "{body}");
    }

    #[test]
    fn store_section_and_scrape_series() {
        let dir = std::env::temp_dir()
            .join(format!("qpart-metrics-{}-store", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let hub = MetricsHub::new();
        assert!(hub.to_json().get("store").is_none(), "absent until registered");
        let tier = StoreTier::open(&dir).unwrap();
        tier.stage_put(crate::store::Column::Plan, b"p".to_vec(), Vec::new());
        tier.flush();
        hub.register_store(Arc::clone(&tier));
        let v = hub.to_json();
        assert_eq!(v.req("store").unwrap().req_f64("records").unwrap(), 1.0);
        let body = hub.render_prometheus();
        assert!(body.contains("qpart_store_records_total 1\n"), "{body}");
        assert!(body.contains("qpart_store_corrupt_records_total 0\n"), "{body}");
        assert!(body.contains("qpart_store_live_entries 1\n"), "{body}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn class_throttled_alias_served_in_json_and_scrape() {
        let hub = MetricsHub::new();
        let counts = hub.classes().class("sensor");
        Metrics::inc(&counts.sched_throttled_total);
        let v = hub.to_json();
        let class = v.req("per_class").unwrap().req("sensor").unwrap();
        assert_eq!(class.req_f64("sched_throttled_total").unwrap(), 1.0);
        assert_eq!(class.req_f64("throttled_total").unwrap(), 1.0, "alias");
        let body = hub.render_prometheus();
        assert!(body.contains("qpart_class_sched_throttled_total{class=\"sensor\"} 1\n"));
        assert!(body.contains("qpart_class_throttled_total{class=\"sensor\"} 1\n"), "{body}");
    }

    #[test]
    fn conn_gauges_and_peaks_track_the_front_end() {
        let hub = MetricsHub::new();
        let front = hub.front();
        for _ in 0..3 {
            Metrics::inc(&front.conns_accepted_total);
            let open = Metrics::gauge_inc(&front.conns_open);
            Metrics::observe_peak(&front.conns_open_peak, open);
        }
        Metrics::gauge_dec(&front.conns_open);
        Metrics::inc(&front.conns_timed_out);
        Metrics::inc(&front.conns_rejected_total);
        Metrics::set(&front.outbox_bytes, 512);
        Metrics::observe_peak(&front.outbox_bytes_peak, 512);
        Metrics::set(&front.outbox_bytes, 0);
        let snap = hub.snapshot();
        assert_eq!(snap.conns_accepted_total, 3);
        assert_eq!(snap.conns_open, 2);
        assert_eq!(snap.conns_open_peak, 3, "peak survives the close");
        assert_eq!(snap.conns_timed_out, 1);
        assert_eq!(snap.conns_rejected_total, 1);
        assert_eq!(snap.outbox_bytes, 0);
        assert_eq!(snap.outbox_bytes_peak, 512);
        let v = hub.to_json();
        assert_eq!(v.req_f64("conns_open").unwrap() as u64, 2);
        assert_eq!(v.req_f64("conns_open_peak").unwrap() as u64, 3);
        assert_eq!(v.req_f64("conns_timed_out").unwrap() as u64, 1);
    }

    #[test]
    fn prometheus_rendering_is_scrapable() {
        let hub = MetricsHub::new();
        let w = hub.register_worker();
        Metrics::inc(&w.requests_total);
        w.handle_latency.observe_us(250);
        let front = hub.front();
        Metrics::inc(&front.conns_accepted_total);
        let body = hub.render_prometheus();
        assert!(body.contains("qpart_requests_total 1\n"), "{body}");
        assert!(body.contains("qpart_conns_accepted_total 1\n"), "{body}");
        assert!(body.contains("qpart_handle_latency_us_count 1\n"), "{body}");
        assert!(body.contains("qpart_handle_latency_us_sum 250\n"), "{body}");
        // every sample line has HELP and TYPE comments
        assert!(body.contains("# HELP qpart_requests_total "), "{body}");
        assert!(body.contains("# TYPE qpart_requests_total counter\n"), "{body}");
        assert!(body.contains("# TYPE qpart_handle_latency_us histogram\n"), "{body}");
        // empty histograms render zero sums; NaN-valued derived metrics
        // (no phase-2 runs yet) are omitted entirely
        assert!(body.contains("qpart_queue_wait_us_sum 0\n"), "{body}");
        assert!(!body.contains("NaN"), "{body}");
        for line in body.lines() {
            if line.starts_with('#') {
                assert!(
                    line.starts_with("# HELP qpart_") || line.starts_with("# TYPE qpart_"),
                    "{line}"
                );
                continue;
            }
            let mut parts = line.split(' ');
            let name = parts.next().unwrap();
            assert!(name.starts_with("qpart_"), "{line}");
            let value = parts.next().expect("value present");
            assert!(value.parse::<f64>().is_ok(), "{line}");
            assert!(parts.next().is_none(), "{line}");
        }
    }

    #[test]
    fn prometheus_buckets_are_cumulative_with_inf() {
        let hub = MetricsHub::new();
        let w = hub.register_worker();
        for us in [10u64, 60, 300, 2_000_000] {
            w.handle_latency.observe_us(us);
        }
        let body = hub.render_prometheus();
        // cumulative per-bound counts: ≤50 → 1, ≤100 → 2, ≤250 → 2, ≤500 → 3 …
        assert!(body.contains("qpart_handle_latency_us_bucket{le=\"50\"} 1\n"), "{body}");
        assert!(body.contains("qpart_handle_latency_us_bucket{le=\"100\"} 2\n"), "{body}");
        assert!(body.contains("qpart_handle_latency_us_bucket{le=\"500\"} 3\n"), "{body}");
        // the 2s observation only lands in +Inf, which equals the count
        assert!(body.contains("qpart_handle_latency_us_bucket{le=\"1000000\"} 3\n"), "{body}");
        assert!(body.contains("qpart_handle_latency_us_bucket{le=\"+Inf\"} 4\n"), "{body}");
        assert!(body.contains("qpart_handle_latency_us_count 4\n"), "{body}");
        // series is monotonically nondecreasing across the whole ladder
        let mut last = 0u64;
        let mut buckets = 0;
        for line in body.lines() {
            if let Some(rest) = line.strip_prefix("qpart_handle_latency_us_bucket{le=\"") {
                let count: u64 = rest.split("} ").nth(1).unwrap().parse().unwrap();
                assert!(count >= last, "{line}");
                last = count;
                buckets += 1;
            }
        }
        assert_eq!(buckets, BUCKETS_US.len() + 1, "12 bounds + +Inf");
    }

    #[test]
    fn http_response_routes_trace_endpoints() {
        let hub = MetricsHub::new();
        // without a registered sink, /trace is 404 and the default path scrapes
        let resp = String::from_utf8(hub.http_response("/trace", 0)).unwrap();
        assert!(resp.starts_with("HTTP/1.0 404 Not Found\r\n"), "{resp}");
        let resp = String::from_utf8(hub.http_response("/metrics", 3)).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("qpart_open_sessions 3\n"), "{resp}");

        let sink = TraceSink::new(0.0, 0, 4, 64);
        let trace = sink.grant();
        let tracer = sink.tracer(0);
        tracer.span(trace, crate::obs::Stage::Plan, 10, 20);
        sink.drain();
        hub.register_trace_sink(Arc::clone(&sink));
        let resp = String::from_utf8(hub.http_response("/trace", 0)).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("Content-Type: application/json"), "{resp}");
        assert!(resp.contains("\"traces\":"), "{resp}");
        let resp =
            String::from_utf8(hub.http_response(&format!("/trace?id={}", trace.id), 0)).unwrap();
        assert!(resp.contains("\"plan\""), "{resp}");
        let resp = String::from_utf8(hub.http_response("/trace?id=999999", 0)).unwrap();
        assert!(resp.starts_with("HTTP/1.0 404 Not Found\r\n"), "{resp}");
        let resp = String::from_utf8(hub.http_response("/trace/slow", 0)).unwrap();
        assert!(resp.contains("\"slow\""), "{resp}");
        // scrape now carries the trace gauges
        let body = hub.render_prometheus();
        assert!(body.contains("qpart_traces_stored 1\n"), "{body}");
    }

    #[test]
    fn request_path_parses_http_heads() {
        assert_eq!(request_path("GET /trace?id=7 HTTP/1.0\r\n\r\n"), "/trace?id=7");
        assert_eq!(request_path("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"), "/metrics");
        assert_eq!(request_path(""), "/metrics");
        assert_eq!(request_path("GET\r\n"), "/metrics");
    }

    #[test]
    fn summary_merge_is_additive() {
        let a = Histogram::default();
        let b = Histogram::default();
        for us in [10u64, 300, 700] {
            a.observe_us(us);
        }
        for us in [60u64, 2_000_000] {
            b.observe_us(us);
        }
        let mut merged = a.summary();
        merged.merge(&b.summary());
        assert_eq!(merged.count(), 5);
        assert_eq!(merged.sum_us(), 10 + 300 + 700 + 60 + 2_000_000);
        assert_eq!(merged.overflow(), 1, "overflow carried over");
        assert_eq!(merged.quantile_us(0.999), 1_000_000.0, "clamped, not inf");
    }

    #[test]
    fn hub_aggregates_to_one_snapshot() {
        let hub = MetricsHub::new();
        let w1 = hub.register_worker();
        let w2 = hub.register_worker();
        let front = hub.front();
        Metrics::inc(&w1.requests_total);
        Metrics::inc(&w2.requests_total);
        Metrics::inc(&w2.requests_total);
        Metrics::inc(&front.shed_total);
        Metrics::add(&front.sched_throttled_total, 4);
        Metrics::inc(&w1.batches_total);
        Metrics::add(&w1.coalesced_total, 2);
        Metrics::inc(&w2.encodes_total);
        w1.handle_latency.observe_us(100);
        w2.handle_latency.observe_us(300);
        w1.queue_wait.observe_us(10);
        w2.queue_wait.observe_us(30);
        let snap = hub.snapshot();
        assert_eq!(snap.requests_total, 3);
        assert_eq!(snap.shed_total, 1);
        assert_eq!(snap.sched_throttled_total, 4);
        assert_eq!(snap.batches_total, 1);
        assert_eq!(snap.coalesced_total, 2);
        assert_eq!(snap.encodes_total, 1);
        assert_eq!(snap.handle_count, 2);
        assert!((snap.handle_mean_us - 200.0).abs() < 1e-9);
        assert_eq!(snap.queue_wait_count, 2);
        assert!((snap.queue_wait_mean_us - 20.0).abs() < 1e-9);
        assert_eq!(hub.worker_snapshots().len(), 2);
        assert_eq!(hub.num_workers(), 2);
    }

    #[test]
    fn hub_json_has_aggregate_and_workers() {
        let hub = MetricsHub::new();
        let w = hub.register_worker();
        Metrics::inc(&w.requests_total);
        let v = hub.to_json();
        assert_eq!(v.req_f64("requests_total").unwrap(), 1.0);
        assert_eq!(v.req_arr("workers").unwrap().len(), 1);
        assert!(v.get("handle").is_some());
        assert!(v.get("queue_wait").is_some());
        assert!(v.get("segment_cache").is_none(), "absent until registered");
    }

    #[test]
    fn class_registry_breaks_out_overload_counters() {
        let hub = MetricsHub::new();
        let reg = hub.classes();
        let phone = reg.class("phone");
        let same = reg.class("phone");
        Metrics::inc(&phone.sched_throttled_total);
        Metrics::add(&same.degraded_total, 2);
        Metrics::inc(&reg.class("mcu/low power").deadline_shed_total);
        // interior mutation through either Arc lands on the same counters
        let entries = reg.entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].0, "mcu/low power", "sorted by class name");
        assert_eq!(entries[1].1.sched_throttled_total.load(Ordering::Relaxed), 1);
        assert_eq!(entries[1].1.degraded_total.load(Ordering::Relaxed), 2);
        let v = hub.to_json();
        let pc = v.req("per_class").unwrap();
        assert_eq!(
            pc.req("phone").unwrap().req_f64("degraded_total").unwrap(),
            2.0
        );
        assert_eq!(
            pc.req("mcu/low power").unwrap().req_f64("deadline_shed_total").unwrap(),
            1.0
        );
        // the scrape sanitizes hostile label characters and stays two-token
        let body = hub.render_prometheus();
        assert!(
            body.contains("qpart_class_degraded_total{class=\"phone\"} 2\n"),
            "{body}"
        );
        assert!(
            body.contains("qpart_class_deadline_shed_total{class=\"mcu_low_power\"} 1\n"),
            "{body}"
        );
        for line in body.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split(' ').count(), 2, "bad sample line: {line}");
        }
    }

    #[test]
    fn new_overload_counters_flow_through_hub() {
        let hub = MetricsHub::new();
        let front = hub.front();
        Metrics::inc(&front.deadline_shed_total);
        Metrics::add(&front.degraded_total, 3);
        front.brownout_level.store(2, Ordering::Relaxed);
        Metrics::inc(&front.brownout_enters_total);
        Metrics::inc(&front.worker_restarts_total);
        Metrics::inc(&front.job_timeouts_total);
        let snap = hub.snapshot();
        assert_eq!(snap.deadline_shed_total, 1);
        assert_eq!(snap.degraded_total, 3);
        assert_eq!(snap.brownout_level, 2);
        assert_eq!(snap.brownout_enters_total, 1);
        assert_eq!(snap.brownout_exits_total, 0);
        assert_eq!(snap.worker_restarts_total, 1);
        assert_eq!(snap.job_timeouts_total, 1);
        let v = hub.to_json();
        assert_eq!(v.req_f64("degraded_total").unwrap(), 3.0);
        assert_eq!(v.req_f64("brownout_level").unwrap(), 2.0);
        assert!(v.get("per_class").is_none(), "absent until a class registers");
        let body = hub.render_prometheus();
        assert!(body.contains("qpart_brownout_level 2\n"), "{body}");
        assert!(body.contains("qpart_worker_restarts_total 1\n"), "{body}");
    }

    #[test]
    fn hub_surfaces_registered_cache() {
        let hub = MetricsHub::new();
        let cache = Arc::new(EncodedReplyCache::new(1 << 20));
        hub.register_segment_cache(Arc::clone(&cache));
        let _ = cache.get(&("m".into(), 0, 1)); // one miss
        let snap = hub.snapshot();
        assert_eq!(snap.cache_misses, 1);
        assert_eq!(snap.cache_hits, 0);
        let v = hub.to_json();
        assert_eq!(v.req("segment_cache").unwrap().req_f64("misses").unwrap(), 1.0);
    }
}
