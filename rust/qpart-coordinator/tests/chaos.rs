//! Chaos-client integration tests: the reactor must survive misbehaving
//! peers — garbage and truncated binary frames, connections dropped
//! mid-phase-2, and slow-loris fleets — without stalling workers,
//! misrouting replies, or leaking connections/sessions. No PJRT required
//! (synthetic bundle, host-fallback phase 2, raw-socket abuse).

use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, synthetic_upload, tiny_arch, BlockingConn};
use qpart_coordinator::{serve, ServerConfig};
use qpart_core::rng::Rng;
use qpart_proto::frame::{read_frame, write_frame};
use qpart_proto::messages::{Request, Response};
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Poll `f` until it returns true or `deadline` elapses (the reactor
/// notices closes/timeouts on its next tick, not synchronously).
fn wait_until<F: Fn() -> bool>(deadline: Duration, f: F) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

#[test]
fn garbage_and_truncated_frames_get_bad_frame_without_killing_the_reactor() {
    let dir = synthetic_bundle("chaos-garbage");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // a well-behaved connection rides along the whole time
    let mut live = BlockingConn::connect(&addr).unwrap();
    assert!(matches!(live.call(&Request::Ping).unwrap(), Response::Pong));

    // garbage envelope: total_len far past the frame cap — the server
    // must answer bad_frame and close, not crash or hang
    let garbage = TcpStream::connect(&addr).unwrap();
    garbage.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut w = garbage.try_clone().unwrap();
    let mut frame = vec![0xB1u8];
    frame.extend_from_slice(&u32::MAX.to_le_bytes());
    frame.extend_from_slice(&8u32.to_le_bytes());
    w.write_all(&frame).unwrap();
    let mut reader = BufReader::new(garbage);
    let line = read_frame(&mut reader).expect("bad_frame reply before close");
    match Response::from_line(&line).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "bad_frame", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }
    let mut buf = [0u8; 16];
    match reader.read(&mut buf) {
        Ok(0) | Err(_) => {} // closed after the reply
        Ok(n) => panic!("garbage peer got {n} unexpected bytes"),
    }

    // truncated envelope: promise 64 bytes, send 3, hang up — EOF mid
    // frame must be a quiet close, never a routed reply
    let mut trunc = TcpStream::connect(&addr).unwrap();
    let mut frame = vec![0xB1u8];
    frame.extend_from_slice(&64u32.to_le_bytes());
    frame.extend_from_slice(&16u32.to_le_bytes());
    frame.extend_from_slice(&[1, 2, 3]);
    trunc.write_all(&frame).unwrap();
    drop(trunc);

    // a well-formed binary frame before hello: refused with bad_frame
    // but the connection STAYS open — JSON still works on it
    let unheralded = TcpStream::connect(&addr).unwrap();
    unheralded.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut w = unheralded.try_clone().unwrap();
    let mut frame = vec![0xB1u8];
    frame.extend_from_slice(&6u32.to_le_bytes());
    frame.extend_from_slice(&2u32.to_le_bytes());
    frame.extend_from_slice(b"xy");
    w.write_all(&frame).unwrap();
    let mut reader = BufReader::new(unheralded);
    match Response::from_line(&read_frame(&mut reader).unwrap()).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "bad_frame", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }
    write_frame(&mut w, &Request::Ping.to_line()).unwrap();
    match Response::from_line(&read_frame(&mut reader).unwrap()).unwrap() {
        Response::Pong => {}
        other => panic!("conn closed by pre-hello binary frame: {other:?}"),
    }
    drop(reader);
    drop(w);

    // the reactor kept serving throughout
    match live.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    drop(live);
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "chaos connections leaked: conns_open = {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Build one damaged 0xB1 envelope with the corruption offset drawn
/// across the length-prefix / header / body boundary (the same shapes the
/// bench-serve chaos fuzzer sends). Returns the bytes plus whether the
/// envelope is complete: a complete one must be answered with
/// `bad_frame` (no hello was sent, so even an undamaged body is refused
/// at dispatch; length/header damage is refused at the framing layer),
/// while a truncated one is hung up mid-frame and must be a quiet close.
fn corrupt_binary_frame(rng: &mut Rng) -> (Vec<u8>, bool) {
    let header = br#"{"type":"activation","session":1,"blob_len":64}"#;
    let blob = [0xABu8; 64];
    let total = (4 + header.len() + blob.len()) as u32;
    let mut frame = vec![0xB1u8];
    frame.extend_from_slice(&total.to_le_bytes());
    frame.extend_from_slice(&(header.len() as u32).to_le_bytes());
    frame.extend_from_slice(header);
    frame.extend_from_slice(&blob);
    let header_at = 9; // magic + total + header_len
    let blob_at = header_at + header.len();
    match (rng.uniform() * 6.0) as usize {
        0 => {
            // length prefix: total blown far past the 16 MiB frame cap
            let huge = u32::MAX - (rng.uniform() * 1e6) as u32;
            frame[1..5].copy_from_slice(&huge.to_le_bytes());
            (frame, true)
        }
        1 => {
            // length prefix: total too small to hold the header_len field
            let tiny = (rng.uniform() * 4.0) as u32;
            frame[1..5].copy_from_slice(&tiny.to_le_bytes());
            (frame[..5].to_vec(), true)
        }
        2 => {
            // header_len pointing past the end of the payload
            let past = total - 4 + 1 + (rng.uniform() * 100.0) as u32;
            frame[5..9].copy_from_slice(&past.to_le_bytes());
            (frame, true)
        }
        3 => {
            // header bytes: 0xFF is never valid UTF-8, so the JSON header
            // cannot decode no matter where it lands
            let at = header_at + (rng.uniform() * header.len() as f64) as usize;
            frame[at] = 0xFF;
            (frame, true)
        }
        4 => {
            // body bytes: the envelope stays well-formed, so this must
            // reach dispatch and be refused there (no hello was sent)
            let at = blob_at + (rng.uniform() * blob.len() as f64) as usize;
            frame[at] ^= 0xFF;
            (frame, true)
        }
        _ => {
            // truncation at a random offset, anywhere from mid-prefix to
            // one byte short of complete, followed by a hang-up
            let keep = 1 + (rng.uniform() * (frame.len() - 1) as f64) as usize;
            frame.truncate(keep);
            (frame, false)
        }
    }
}

#[test]
fn fuzzed_corruption_across_the_envelope_always_gets_bad_frame() {
    let dir = synthetic_bundle("chaos-fuzz");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // a well-behaved connection rides along the whole time
    let mut live = BlockingConn::connect(&addr).unwrap();
    assert!(matches!(live.call(&Request::Ping).unwrap(), Response::Pong));

    let mut rng = Rng::from_label(0xB1, "chaos/fuzz");
    let mut complete_frames = 0u64;
    for round in 0..60 {
        let (frame, complete) = corrupt_binary_frame(&mut rng);
        let s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut w = s.try_clone().unwrap();
        w.write_all(&frame).unwrap();
        if !complete {
            // hang up mid-frame: a quiet close, nothing to assert here —
            // the leak check below catches a stuck connection
            continue;
        }
        complete_frames += 1;
        let mut reader = BufReader::new(s);
        let line = read_frame(&mut reader).expect("reply to a complete corrupt frame");
        match Response::from_line(&line).expect("reply parses") {
            Response::Error(e) => assert_eq!(e.code, "bad_frame", "round {round}: {}", e.message),
            other => panic!("round {round}: unexpected {other:?}"),
        }
    }
    assert!(
        complete_frames >= 20,
        "rng starved the fuzz of complete envelopes: {complete_frames}/60"
    );

    // the reactor kept serving throughout, and no fuzz connection leaked
    assert!(matches!(live.call(&Request::Ping).unwrap(), Response::Pong));
    drop(live);
    assert!(
        wait_until(Duration::from_secs(10), || handle.snapshot().conns_open == 0),
        "fuzz connections leaked: conns_open = {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dropping_mid_phase2_leaves_no_orphaned_session_or_misrouted_reply() {
    let dir = synthetic_bundle("chaos-drop");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        session_ttl: Duration::from_millis(200),
        host_fallback: true,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let arch = tiny_arch();

    // phase 1 only, then vanish: the opened session must be expired by
    // the TTL sweep, not linger forever
    let mut ghost = BlockingConn::connect(&addr).unwrap();
    match ghost.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    drop(ghost);
    assert_eq!(handle.sessions.len(), 1, "phase-1 session open");
    assert!(
        wait_until(Duration::from_secs(10), || handle.sessions.is_empty()),
        "orphaned session survived the TTL sweep: {} live",
        handle.sessions.len()
    );

    // drop with the phase-2 reply IN FLIGHT: send the upload, hang up
    // immediately, and verify the reply is dropped by the generation
    // check — never delivered to an unrelated connection
    let raw = TcpStream::connect(&addr).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut w = raw.try_clone().unwrap();
    write_frame(&mut w, &Request::Infer(paper_request("tinymlp", 0.02)).to_line()).unwrap();
    let mut reader = BufReader::new(raw);
    let reply = match Response::from_line(&read_frame(&mut reader).unwrap()).unwrap() {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };
    let upload = synthetic_upload(&reply, &arch, 99);
    write_frame(&mut w, &Request::Activation(upload).to_line()).unwrap();
    drop(reader);
    drop(w); // gone before the worker can answer

    // a bystander connected right after must see ONLY its own replies
    let mut bystander = BlockingConn::connect(&addr).unwrap();
    for _ in 0..5 {
        match bystander.call(&Request::Ping).unwrap() {
            Response::Pong => {}
            other => panic!("misrouted reply delivered to bystander: {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    // the upload consumed its session; nothing is orphaned
    assert!(
        wait_until(Duration::from_secs(10), || handle.sessions.is_empty()),
        "session leaked after mid-phase-2 drop: {} live",
        handle.sessions.len()
    );
    drop(bystander);
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "conns_open stuck at {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn in_session_phase2_blob_fuzz_gets_error_replies_without_leaking_sessions() {
    let dir = synthetic_bundle("chaos-blob-fuzz");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        host_fallback: true,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let arch = tiny_arch();
    let mut conn = BlockingConn::connect(&addr).unwrap();
    let infer = |conn: &mut BlockingConn| match conn
        .call(&Request::Infer(paper_request("tinymlp", 0.02)))
        .unwrap()
    {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    };

    // a decodable envelope whose upload targets a session that does not
    // exist: refused per-request, and the REAL session is untouched
    let reply = infer(&mut conn);
    let mut bogus = synthetic_upload(&reply, &arch, 7);
    bogus.session += 1_000_003;
    match conn.call(&Request::Activation(bogus)).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "unknown_session", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }
    // the untouched session then completes phase 2 normally
    match conn.call(&Request::Activation(synthetic_upload(&reply, &arch, 7))).unwrap() {
        Response::Result(_) => {}
        other => panic!("fuzz poisoned a live session: {other:?}"),
    }

    // dims that disagree with the session's negotiated boundary: the
    // upload is refused (consuming its session, by design — a device
    // that corrupted its uplink re-plans from phase 1)
    let reply = infer(&mut conn);
    let mut wrong_dims = synthetic_upload(&reply, &arch, 8);
    wrong_dims.dims = vec![1, 1_000_000];
    match conn.call(&Request::Activation(wrong_dims)).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "bad_activation", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }

    // a packed blob truncated below what dims×bits require: refused at
    // the unpack layer, never executed
    let reply = infer(&mut conn);
    let mut short = synthetic_upload(&reply, &arch, 9);
    let keep = short.packed.len() / 2;
    short.packed.truncate(keep);
    match conn.call(&Request::Activation(short)).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "bad_activation", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }

    // the connection survived every refusal, and a fresh two-phase
    // round trip still works end to end
    assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
    let reply = infer(&mut conn);
    match conn.call(&Request::Activation(synthetic_upload(&reply, &arch, 10))).unwrap() {
        Response::Result(_) => {}
        other => panic!("server stopped serving after blob fuzz: {other:?}"),
    }

    // every fuzzed session was consumed or refused — none linger
    assert!(
        wait_until(Duration::from_secs(10), || handle.sessions.is_empty()),
        "blob fuzz leaked sessions: {} live",
        handle.sessions.len()
    );
    drop(conn);
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "conns_open stuck at {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_loris_fleet_is_reaped_while_a_live_client_keeps_being_served() {
    let dir = synthetic_bundle("chaos-loris");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        conn_idle: Duration::from_millis(200),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // a live client pings continuously — its traffic resets the idle
    // clock, so the sweep must never catch it
    let pinger = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut conn = BlockingConn::connect(&addr).unwrap();
            for _ in 0..40 {
                assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
                std::thread::sleep(Duration::from_millis(50));
            }
        })
    };

    // 32 slow lorises: half a frame each, then silence
    let fleet: Vec<TcpStream> = (0..32)
        .map(|_| {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.write_all(b"{\"type\":\"pi").unwrap();
            s
        })
        .collect();

    assert!(
        wait_until(Duration::from_secs(15), || handle.snapshot().conns_timed_out >= 32),
        "idle sweep reaped only {} of 32 lorises",
        handle.snapshot().conns_timed_out
    );
    // the server really hung up on every one of them
    for mut s in fleet {
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 16];
        match s.read(&mut buf) {
            Ok(0) | Err(_) => {}
            Ok(n) => panic!("loris got {n} unexpected bytes"),
        }
    }
    pinger.join().unwrap();

    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "loris fleet leaked: conns_open = {}",
        handle.snapshot().conns_open
    );
    let snap = handle.snapshot();
    assert!(snap.conns_accepted_total >= 33, "accepted {}", snap.conns_accepted_total);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
