//! PJRT-free synthetic artifact bundles and a minimal protocol client,
//! for tests and the `bench-serve` load harness.
//!
//! [`synthetic_bundle`] writes a loadable bundle (manifest + weights +
//! calibration + dataset, **zero HLO executables**) into a temp
//! directory. The coordinator's phase-1 path — Algorithm 2 decision,
//! segment quantization, bit-packing, encoded-reply caching, session
//! open — is pure Rust, so a real multi-worker server can be driven end
//! to end over TCP in any offline environment. Only phase-2 execution
//! (PJRT) needs `make artifacts`.
//!
//! Helpers panic on I/O errors: they run in tests and the bench harness,
//! where a broken temp dir should abort loudly, not propagate.

use qpart_core::accuracy::CalibrationTable;
use qpart_core::json::Value;
use qpart_core::model::{LayerKind, LayerSpec, ModelSpec};
use qpart_core::tensor::{save_i32, Tensor};
use qpart_proto::frame::{read_any_frame, write_frame};
use qpart_proto::messages::{Request, Response};
use std::io::BufReader;
use std::net::TcpStream;
use std::path::PathBuf;

/// Minimal blocking protocol connection (phase-1 only — no PJRT-backed
/// `DeviceClient` needed): JSON requests out, either framing in. Shared
/// by the coordinator's integration tests and `qpart bench-serve`.
pub struct BlockingConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl BlockingConn {
    pub fn connect(addr: &str) -> Result<BlockingConn, String> {
        let stream = TcpStream::connect(addr).map_err(|e| e.to_string())?;
        stream.set_nodelay(true).map_err(|e| e.to_string())?;
        let writer = stream.try_clone().map_err(|e| e.to_string())?;
        Ok(BlockingConn { reader: BufReader::new(stream), writer })
    }

    /// Send one request and read one response (JSON or binary frame).
    pub fn call(&mut self, req: &Request) -> Result<Response, String> {
        write_frame(&mut self.writer, &req.to_line()).map_err(|e| e.to_string())?;
        let frame = read_any_frame(&mut self.reader).map_err(|e| e.to_string())?;
        Response::from_frame(&frame).map_err(|e| e.to_string())
    }
}

/// Accuracy-degradation levels the synthetic calibration covers.
pub const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

fn lin(name: &str, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
    LayerSpec { name: name.into(), kind: LayerKind::Linear { d_in, d_out }, relu }
}

/// The synthetic bundle's model: a 3-layer MLP named `tinymlp`.
pub fn tiny_arch() -> ModelSpec {
    ModelSpec::new(
        "tinymlp",
        vec![lin("fc1", 256, 512, true), lin("fc2", 512, 256, true), lin("fc3", 256, 10, false)],
        10,
    )
    .unwrap()
}

/// Write a loadable synthetic bundle into a fresh per-process temp
/// directory (`qpart-synth-<pid>-<tag>`) and return its path. The caller
/// owns cleanup (`std::fs::remove_dir_all`).
pub fn synthetic_bundle(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qpart-synth-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for sub in ["weights/tinymlp", "calibration", "data"] {
        std::fs::create_dir_all(dir.join(sub)).unwrap();
    }
    let arch = tiny_arch();

    let mut rng = qpart_core::rng::Rng::new(7);
    for (i, layer) in arch.layers.iter().enumerate() {
        let (d_in, d_out) = match layer.kind {
            LayerKind::Linear { d_in, d_out } => (d_in, d_out),
            _ => unreachable!("tinymlp is linear-only"),
        };
        let w = Tensor::new(
            vec![d_in, d_out],
            (0..d_in * d_out).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect(),
        )
        .unwrap();
        let b = Tensor::new(
            vec![d_out],
            (0..d_out).map(|_| rng.range_f64(-0.1, 0.1) as f32).collect(),
        )
        .unwrap();
        w.save(dir.join(format!("weights/tinymlp/l{}_w.qt", i + 1))).unwrap();
        b.save(dir.join(format!("weights/tinymlp/l{}_b.qt", i + 1))).unwrap();
    }

    let calib = CalibrationTable::synthetic(&arch, &LEVELS, 1);
    std::fs::write(dir.join("calibration/tinymlp.json"), calib.to_json().to_string_pretty())
        .unwrap();

    Tensor::zeros(vec![4, 256]).save(dir.join("data/synth_test_x.qt")).unwrap();
    save_i32(dir.join("data/synth_test_y.qt"), &[4], &[0, 1, 2, 3]).unwrap();

    let manifest = Value::obj([
        ("archs", Value::Arr(vec![arch.to_json()])),
        (
            "models",
            Value::Arr(vec![Value::obj([
                ("name", "tinymlp".into()),
                ("arch", "tinymlp".into()),
                ("dataset", "synth".into()),
                ("weights_dir", "weights/tinymlp".into()),
                ("calibration", "calibration/tinymlp.json".into()),
                ("test_accuracy", 0.9.into()),
            ])]),
        ),
        ("executables", Value::Arr(vec![])),
        (
            "datasets",
            Value::Arr(vec![Value::obj([
                ("name", "synth".into()),
                ("x", "data/synth_test_x.qt".into()),
                ("y", "data/synth_test_y.qt".into()),
                ("n", 4usize.into()),
                ("classes", 10usize.into()),
            ])]),
        ),
        ("levels", Value::num_arr(&LEVELS)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty()).unwrap();
    dir
}
