//! # qpart-coordinator
//!
//! The Layer-3 serving stack — the QPART server an edge fleet talks to:
//!
//! * [`service`] — the request brain: per-model offline pattern tables
//!   (Algorithm 1 at startup), per-request decisions (Algorithm 2),
//!   segment quantization + bit-packing through the encoded-reply cache,
//!   batch handling (group-by-key, encode once, fan out), session state
//!   for the two-phase protocol, and the **batch-aware execution plane**
//!   for phase 2: decoded activation uploads group by
//!   `(model, partition)` and row-stack into server-segment executions
//!   of up to `EVAL_BATCH` rows, over the pool-wide
//!   `qpart_runtime::CompileCache` (each segment compiled once per
//!   server, not once per worker), with optional startup warming
//!   (`--warm-cache`).
//! * [`decision`] — the server-wide **Algorithm-2 decision cache**:
//!   memoized `(model, accuracy level, bucketed device/channel profile)`
//!   → decision, so repeat profiles skip planning entirely (surfaced in
//!   the stats document's `decision_cache` section).
//! * [`sched`] — the **serving dataplane** between the accept loop and
//!   the executor pool: batch draining with an optional coalescing
//!   window, the `(model, accuracy level, partition)`-keyed
//!   [`EncodedReplyCache`] (LRU + byte budget), and the [`sched::WireReply`]
//!   hand-off that lets connection threads stamp pre-encoded segment
//!   bodies into either framing.
//! * [`net`] — the **evented front-end**: a `poll(2)`-based connection
//!   reactor (nonblocking listener, per-connection state machines with
//!   explicit read buffers / outboxes / negotiation state, idle and
//!   slow-client timeouts, a `max_conns` accept gate) that decouples
//!   accepted-device count from OS threads. Replies route back through
//!   the [`sched::ReplyRouter`] completion queue; a plaintext
//!   metrics-scrape listener rides the same loop as a second socket.
//! * [`server`] — server assembly: JSON-lines framing plus negotiated
//!   binary segment frames, a bounded job queue with admission control
//!   (overload sheds with an `overloaded` error), a configurable
//!   **executor pool** (`workers` inference threads over one shared
//!   `Arc<Bundle>`; PJRT clients are single-device and not `Send`)
//!   draining one shared queue in batches, and a session-GC thread. The
//!   front-end is the reactor by default ([`server::Frontend`]), with
//!   the classic thread-per-connection loop kept as a byte-identical
//!   baseline. The `workers` knob mirrors the simulator's
//!   `FleetConfig::server_slots`.
//! * [`brownout`] — **overload brownout**: a queue-wait-EWMA-driven
//!   degradation ladder with hysteresis, and the accuracy-budget gate
//!   ([`brownout::degrade_level`]) that only ever coarsens a request's
//!   quantization level when the offline table's predicted degradation
//!   still fits its budget.
//! * [`client`] — the device side for examples/CLI: sends requests,
//!   optionally negotiates binary frames, executes the received quantized
//!   segment locally through its own PJRT engine, uploads the quantized
//!   boundary activation.
//! * [`obs`] — **request-scoped tracing**: per-stage [`obs::Span`]
//!   timelines (read → admit → queue wait → plan → encode → execute →
//!   route → flush) collected into per-worker ring buffers and a bounded
//!   server-wide [`obs::TraceSink`], exposed via `/trace?id=` and
//!   `/trace/slow` on the metrics listener, slow-request exemplars, and
//!   Chrome trace-event export; plus the [`obs::TrafficRecorder`] that
//!   captures live traffic into the scenario engine's `trace v1` format.
//! * [`store`] — the **store tier** under the caches: a calimero-style
//!   `Layer`/`ReadLayer`/`WriteLayer` trait stack with typed keys, the
//!   one [`store::CacheCore`] eviction engine every cache facade wraps,
//!   and the append-only CRC-guarded segment log (`--store-dir`) whose
//!   replay (`--warm log`) brings the decision/reply caches and phase-2
//!   plans up hot after a restart.
//! * [`metrics`] — per-worker counters + histograms (including
//!   `queue_wait` and the batching/encode counters), aggregated by a
//!   [`MetricsHub`] — together with the encoded-reply cache's
//!   hit/miss/bytes-saved counters — and surfaced via the `stats`
//!   request.
//! * [`session`] — sharded, capacity- and TTL-bounded session table
//!   shared by all workers (phase 1 and phase 2 of a session may be
//!   handled by different workers).
//! * [`testing`] — synthetic PJRT-free artifact bundles for tests and the
//!   `bench-serve` load harness.
//!
//! Python never appears anywhere on these paths.

pub mod brownout;
pub mod client;
pub mod decision;
pub mod metrics;
#[cfg(unix)]
pub mod net;
pub mod obs;
pub mod sched;
pub mod server;
pub mod service;
pub mod session;
pub mod store;
pub mod testing;

pub use brownout::{degrade_level, BrownoutController};
pub use client::DeviceClient;
pub use decision::{DecisionCache, DecisionKey, ProfileBucket};
pub use metrics::{Metrics, MetricsHub, MetricsSnapshot};
pub use obs::{JobTrace, Stage, TraceSink, TraceStamp, Tracer, TrafficRecorder};
pub use sched::{BatchPolicy, EncodedReplyCache, Job, ReplyRouter, ReplySink, WireReply};
pub use server::{serve, Frontend, ServerConfig, ServerHandle, WarmMode};
pub use service::{FaultSpec, Service, ServiceOptions};
pub use session::{Session, SessionTable, SharedSessionTable};
pub use store::{CacheStats, StoreTier};
