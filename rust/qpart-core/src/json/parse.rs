//! Recursive-descent JSON parser (RFC 8259 subset: strict syntax, `f64`
//! numbers, `\uXXXX` escapes incl. surrogate pairs, depth-limited).

use super::Value;
use crate::error::{Error, Result};

/// Maximum nesting depth accepted by [`parse`]; prevents stack overflow on
/// adversarial input (the wire protocol feeds untrusted bytes here).
const MAX_DEPTH: usize = 128;

/// Parse a complete JSON document. Trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, val: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(format!("invalid literal, expected '{word}'")))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair: require \uXXXX low surrogate
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: validate by re-decoding the slice
                    let start = self.pos - 1;
                    let len = utf8_len(c).ok_or_else(|| self.err("invalid utf-8"))?;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part: 0 | [1-9][0-9]*
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(c) if c.is_ascii_digit() => {
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text.parse().map_err(|_| self.err("number out of range"))?;
        if !n.is_finite() {
            return Err(self.err("number out of range"));
        }
        Ok(Value::Num(n))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC2..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF4 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("0").unwrap(), Value::Num(0.0));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("1e-3").unwrap(), Value::Num(0.001));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#" { "a" : [ 1 , { "b" : [ ] } ] } "#).unwrap();
        assert_eq!(v.get("a").unwrap().at(1).unwrap().get("b").unwrap(), &Value::Arr(vec![]));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            parse(r#""a\n\t\"\\Aé""#).unwrap(),
            Value::Str("a\n\t\"\\Aé".into())
        );
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
    }

    #[test]
    fn raw_utf8_passthrough() {
        assert_eq!(parse("\"héllo — 日本\"").unwrap(), Value::Str("héllo — 日本".into()));
    }

    #[test]
    fn malformed() {
        for bad in [
            "", "{", "[", "{\"a\"}", "{\"a\":}", "[1,]", "{,}", "01", "1.", "1e",
            "\"\\x\"", "\"unterminated", "nul", "tru", "[1] extra", "\"\\ud800\"",
            "-", "+1", "NaN", "Infinity", "{\"a\":1,}", "\u{0007}",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn duplicate_keys_first_wins_on_get() {
        let v = parse(r#"{"a":1,"a":2}"#).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
    }
}
