//! # qpart-sim
//!
//! The paper's §V simulation platform, as a library:
//!
//! * [`device`] — the *executing module*: simulated edge devices and the
//!   server, with Table II compute/energy profiles.
//! * [`comm`] — the *communication module*: wireless links with optional
//!   fading, transfer-time/energy accounting.
//! * [`perf`] — the *performance module*: metric collection (histograms,
//!   percentiles, per-request records).
//! * [`workload`] — request generators: Poisson arrivals over a
//!   heterogeneous device fleet.
//! * [`schemes`] — analytic cost models of the four compared offloading
//!   schemes (QPART, no-optimization, 2-step pruning, DeepCOD-style
//!   autoencoder) used by the Fig. 5/7/8/9/10 benches.
//! * [`fleet`] — the discrete-event fleet simulation driving Fig. 5-style
//!   dynamics and the `qpart sim` subcommand.
//! * [`scenario`] — declarative multi-phase workload scenarios (flash
//!   crowds, diurnal load, fading shifts, upload storms) replayable
//!   deterministically and exportable as request traces.

pub mod comm;
pub mod device;
pub mod fleet;
pub mod perf;
pub mod scenario;
pub mod schemes;
pub mod workload;

pub use fleet::{FleetConfig, FleetReport, run_fleet};
pub use perf::{PerfCollector, RequestRecord, Summary};
pub use scenario::{Phase, RatePattern, Scenario, Trace, TraceEvent};
pub use schemes::{scheme_cost, Scheme, SchemeCost};
pub use workload::{DeviceClass, WorkloadConfig, WorkloadGen};
