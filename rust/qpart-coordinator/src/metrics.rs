//! Metrics registry: thread-safe counters and fixed-bucket latency
//! histograms, surfaced through the wire protocol's `stats` request.

use qpart_core::json::Value;
use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency buckets in microseconds (upper bounds).
const BUCKETS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 1_000_000];

/// A fixed-bucket histogram.
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; 12],
    overflow: AtomicU64,
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    pub fn observe_us(&self, us: u64) {
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        for (i, &ub) in BUCKETS_US.iter().enumerate() {
            if us <= ub {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        self.overflow.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let target = (n as f64 * q).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return BUCKETS_US[i] as f64;
            }
        }
        f64::INFINITY
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("count", self.count().into()),
            ("mean_us", self.mean_us().into()),
            ("p50_us", self.quantile_us(0.5).into()),
            ("p99_us", self.quantile_us(0.99).into()),
        ])
    }
}

/// All coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub errors_total: AtomicU64,
    pub shed_total: AtomicU64,
    pub sessions_opened: AtomicU64,
    pub sessions_expired: AtomicU64,
    pub bytes_out: AtomicU64,
    pub bytes_in: AtomicU64,
    /// End-to-end request handling (decision + quantize + execute).
    pub handle_latency: Histogram,
    /// Algorithm 2 decision time.
    pub decide_latency: Histogram,
    /// Segment quantization + packing time.
    pub quantize_latency: Histogram,
    /// PJRT execution time.
    pub execute_latency: Histogram,
}

/// A point-in-time copy (plain numbers) for assertions and reports.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests_total: u64,
    pub errors_total: u64,
    pub shed_total: u64,
    pub sessions_opened: u64,
    pub handle_count: u64,
    pub handle_mean_us: f64,
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_total: self.requests_total.load(Ordering::Relaxed),
            errors_total: self.errors_total.load(Ordering::Relaxed),
            shed_total: self.shed_total.load(Ordering::Relaxed),
            sessions_opened: self.sessions_opened.load(Ordering::Relaxed),
            handle_count: self.handle_latency.count(),
            handle_mean_us: self.handle_latency.mean_us(),
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("requests_total", self.requests_total.load(Ordering::Relaxed).into()),
            ("errors_total", self.errors_total.load(Ordering::Relaxed).into()),
            ("shed_total", self.shed_total.load(Ordering::Relaxed).into()),
            ("sessions_opened", self.sessions_opened.load(Ordering::Relaxed).into()),
            ("sessions_expired", self.sessions_expired.load(Ordering::Relaxed).into()),
            ("bytes_out", self.bytes_out.load(Ordering::Relaxed).into()),
            ("bytes_in", self.bytes_in.load(Ordering::Relaxed).into()),
            ("handle", self.handle_latency.to_json()),
            ("decide", self.decide_latency.to_json()),
            ("quantize", self.quantize_latency.to_json()),
            ("execute", self.execute_latency.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = Histogram::default();
        for us in [10u64, 60, 300, 300, 700, 2_000_000] {
            h.observe_us(us);
        }
        assert_eq!(h.count(), 6);
        assert!((h.mean_us() - (10. + 60. + 300. + 300. + 700. + 2e6) / 6.0).abs() < 1e-6);
        // p50 lands in the 250 or 500 bucket
        let p50 = h.quantile_us(0.5);
        assert!(p50 <= 500.0, "{p50}");
        assert!(h.quantile_us(0.999).is_infinite(), "overflow bucket");
    }

    #[test]
    fn snapshot_counts() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_total);
        Metrics::inc(&m.requests_total);
        Metrics::inc(&m.errors_total);
        m.handle_latency.observe_us(100);
        let s = m.snapshot();
        assert_eq!(s.requests_total, 2);
        assert_eq!(s.errors_total, 1);
        assert_eq!(s.handle_count, 1);
    }

    #[test]
    fn json_has_all_sections() {
        let m = Metrics::default();
        let v = m.to_json();
        for key in ["requests_total", "handle", "decide", "quantize", "execute"] {
            assert!(v.get(key).is_some(), "{key}");
        }
    }
}
