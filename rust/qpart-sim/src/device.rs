//! Executing module: simulated edge devices and the shared server.
//!
//! Device compute time/energy follow paper Eq. 5–6, server time/cost
//! Eq. 7–8 — all delegated to `qpart_core::cost`, which keeps the
//! simulator and the optimizer on exactly the same model (a mismatch
//! there would make the online algorithm's choices look artificially
//! good or bad).

use qpart_core::cost::{DeviceProfile, ServerProfile};

/// A simulated edge device: profile + availability time.
#[derive(Debug, Clone)]
pub struct DeviceSim {
    pub id: usize,
    pub profile: DeviceProfile,
    /// Simulation time (s) when the device is next free.
    pub busy_until: f64,
    /// Cumulative energy drawn from the battery (J).
    pub energy_j: f64,
}

impl DeviceSim {
    pub fn new(id: usize, profile: DeviceProfile) -> DeviceSim {
        DeviceSim { id, profile, busy_until: 0.0, energy_j: 0.0 }
    }

    /// Run `macs` locally starting at `now`; returns the finish time.
    pub fn compute(&mut self, now: f64, macs: u64) -> f64 {
        let start = now.max(self.busy_until);
        let dt = self.profile.compute_time_s(macs);
        self.busy_until = start + dt;
        self.energy_j += self.profile.compute_energy_j(macs);
        self.busy_until
    }
}

/// The shared server: a single FIFO compute resource (the paper's MEC
/// server; multi-server extensions hang off `ServerSim::with_slots`).
#[derive(Debug, Clone)]
pub struct ServerSim {
    pub profile: ServerProfile,
    /// Next-free time per execution slot.
    slots: Vec<f64>,
    /// Cumulative billed cost (Eq. 8).
    pub billed_cost: f64,
}

impl ServerSim {
    pub fn new(profile: ServerProfile) -> ServerSim {
        ServerSim { profile, slots: vec![0.0], billed_cost: 0.0 }
    }

    /// Multiple parallel execution slots.
    pub fn with_slots(profile: ServerProfile, n: usize) -> ServerSim {
        assert!(n > 0);
        ServerSim { profile, slots: vec![0.0; n], billed_cost: 0.0 }
    }

    /// Schedule `macs` at the earliest-free slot from `now`; returns finish.
    pub fn compute(&mut self, now: f64, macs: u64) -> f64 {
        let (idx, _) = self
            .slots
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let start = now.max(self.slots[idx]);
        let dt = self.profile.compute_time_s(macs);
        self.slots[idx] = start + dt;
        self.billed_cost += self.profile.compute_cost(macs);
        self.slots[idx]
    }

    /// Current queueing delay if work arrived at `now`.
    pub fn queue_delay(&self, now: f64) -> f64 {
        let earliest = self.slots.iter().cloned().fold(f64::INFINITY, f64::min);
        (earliest - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_serializes_work() {
        let mut d = DeviceSim::new(0, DeviceProfile::paper_default());
        let t1 = d.compute(0.0, 1_000_000); // 25 ms
        assert!((t1 - 0.025).abs() < 1e-12);
        // second job queued behind the first
        let t2 = d.compute(0.0, 1_000_000);
        assert!((t2 - 0.050).abs() < 1e-12);
        // energy accumulates (Eq. 6: 6e-4 J per 1e6 MACs at defaults)
        assert!((d.energy_j - 1.2e-3).abs() < 1e-9);
    }

    #[test]
    fn server_picks_earliest_slot() {
        let mut s = ServerSim::with_slots(ServerProfile::paper_default(), 2);
        let a = s.compute(0.0, 3_000_000_000); // 1.25 s on slot 0
        let b = s.compute(0.0, 3_000_000_000); // slot 1, parallel
        assert!((a - 1.25).abs() < 1e-9);
        assert!((b - 1.25).abs() < 1e-9);
        let c = s.compute(0.0, 3_000_000_000); // queues
        assert!((c - 2.5).abs() < 1e-9);
        assert!(s.billed_cost > 0.0);
    }

    #[test]
    fn queue_delay_reporting() {
        let mut s = ServerSim::new(ServerProfile::paper_default());
        assert_eq!(s.queue_delay(0.0), 0.0);
        s.compute(0.0, 3_000_000_000);
        assert!((s.queue_delay(0.0) - 1.25).abs() < 1e-9);
        assert_eq!(s.queue_delay(10.0), 0.0);
    }
}
