//! Quantization patterns — the `(b_a^p, p)` tuples of paper Algorithm 1.
//!
//! A [`QuantPattern`] fixes the partition point and the per-layer bit-widths
//! of the device segment (plus the boundary-activation bit-width).
//! A [`PatternSet`] is the offline-computed table `{(b_a^p, p)}_θ`, indexed
//! by accuracy level and partition point, that the online algorithm
//! (Algorithm 2) searches at request time.

use crate::error::{Error, Result};
use crate::json::Value;
use crate::model::ModelSpec;

/// One quantization + partitioning decision.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPattern {
    /// Partition point `p ∈ 0..=L`: device runs layers `1..=p`.
    pub partition: usize,
    /// Weight bit-widths for layers `1..=p` (`bits.len() == partition`).
    pub weight_bits: Vec<u8>,
    /// Bit-width of the boundary activation `z_x(p)` sent uplink.
    pub activation_bits: u8,
    /// The accuracy-degradation level this pattern was solved for
    /// (fraction, e.g. 0.01 = 1%).
    pub accuracy_level: f64,
    /// Predicted degradation from the noise model (≤ accuracy_level when
    /// the solve is feasible).
    pub predicted_degradation: f64,
}

impl QuantPattern {
    /// Communication payload in bits under Eq. 14 for `model`.
    pub fn payload_bits(&self, model: &ModelSpec) -> u64 {
        model.payload_bits(self.partition, &self.weight_bits, self.activation_bits)
    }

    /// Device-segment memory footprint in bits: `Σ_{l ≤ p} b_l · z_w(l)`
    /// (weights + bias at each layer's bit-width) — the quantity the
    /// §III memory-feasibility constraint compares against device
    /// capacity. A pure function of the pattern, so [`PatternSet`]
    /// precomputes it offline (Algorithm 1) instead of re-summing on
    /// every request.
    pub fn segment_bits(&self, model: &ModelSpec) -> u64 {
        self.weight_bits
            .iter()
            .enumerate()
            .map(|(i, &b)| (b as u64) * model.weight_params(i + 1))
            .sum()
    }

    /// Payload of the *unquantized* scheme at the same partition (f32
    /// weights + f32 boundary activation) — the "No Optimization" baseline.
    pub fn payload_bits_f32(&self, model: &ModelSpec) -> u64 {
        let bits32 = vec![32u8; self.partition];
        model.payload_bits(self.partition, &bits32, 32)
    }

    /// Structural validity against a model.
    pub fn validate(&self, model: &ModelSpec) -> Result<()> {
        if self.partition > model.num_layers() {
            return Err(Error::InvalidArg(format!(
                "partition {} > L={}",
                self.partition,
                model.num_layers()
            )));
        }
        if self.weight_bits.len() != self.partition {
            return Err(Error::InvalidArg(format!(
                "pattern has {} bit-widths for partition {}",
                self.weight_bits.len(),
                self.partition
            )));
        }
        for (i, &b) in self.weight_bits.iter().enumerate() {
            if !(1..=32).contains(&b) {
                return Err(Error::InvalidArg(format!("layer {} bits {b} out of range", i + 1)));
            }
        }
        if !(1..=32).contains(&self.activation_bits) {
            return Err(Error::InvalidArg(format!(
                "activation bits {} out of range",
                self.activation_bits
            )));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("partition", self.partition.into()),
            (
                "weight_bits",
                Value::Arr(self.weight_bits.iter().map(|&b| (b as u64).into()).collect()),
            ),
            ("activation_bits", (self.activation_bits as u64).into()),
            ("accuracy_level", self.accuracy_level.into()),
            ("predicted_degradation", self.predicted_degradation.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<QuantPattern> {
        let weight_bits = v
            .req_arr("weight_bits")?
            .iter()
            .map(|b| {
                b.as_i64()
                    .and_then(|x| u8::try_from(x).ok())
                    .ok_or_else(|| Error::schema("weight_bits", "expected small integer"))
            })
            .collect::<Result<Vec<u8>>>()?;
        Ok(QuantPattern {
            partition: v.req_usize("partition")?,
            weight_bits,
            activation_bits: v.req_u64("activation_bits")? as u8,
            accuracy_level: v.req_f64("accuracy_level")?,
            predicted_degradation: v.opt_f64("predicted_degradation", 0.0),
        })
    }
}

/// Key for a pattern: (accuracy-level index, partition point).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PatternKey {
    pub level_idx: usize,
    pub partition: usize,
}

/// The offline table `{(b_a^p, p)}_θ` for one model.
#[derive(Debug, Clone)]
pub struct PatternSet {
    pub model: String,
    /// Accuracy-degradation levels, ascending (e.g. [0.0025, 0.005, 0.01, 0.02, 0.05]).
    pub levels: Vec<f64>,
    /// `patterns[level_idx][p]` for `p ∈ 0..=L`.
    pub patterns: Vec<Vec<QuantPattern>>,
    /// Precomputed [`QuantPattern::segment_bits`] parallel to `patterns`,
    /// filled by `offline_quantize` (or [`PatternSet::precompute_segment_bits`]).
    /// Empty for sets deserialized without a model in hand — Algorithm 2
    /// falls back to computing per pattern then.
    pub segment_bits: Vec<Vec<u64>>,
    /// Precomputed [`QuantPattern::payload_bits`] (Eq. 14) parallel to
    /// `patterns`, filled by `offline_quantize` (or
    /// [`PatternSet::precompute_payload_bits`]). Like `segment_bits`, a
    /// pure function of the table — precomputing it offline stops
    /// Algorithm 2 from re-summing O(layers) payload terms per partition
    /// on every request. Empty for sets deserialized without a model;
    /// Algorithm 2 falls back to computing per pattern then.
    pub payload_bits: Vec<Vec<u64>>,
}

impl PatternSet {
    /// Fill the `segment_bits` table from `model` (idempotent; Algorithm 1
    /// calls this once at offline time).
    pub fn precompute_segment_bits(&mut self, model: &ModelSpec) {
        self.segment_bits = self
            .patterns
            .iter()
            .map(|row| row.iter().map(|p| p.segment_bits(model)).collect())
            .collect();
    }

    /// Fill the `payload_bits` table from `model` (idempotent; Algorithm 1
    /// calls this once at offline time).
    pub fn precompute_payload_bits(&mut self, model: &ModelSpec) {
        self.payload_bits = self
            .patterns
            .iter()
            .map(|row| row.iter().map(|p| p.payload_bits(model)).collect())
            .collect();
    }

    /// Precomputed segment bits for `patterns[level_idx][pattern_idx]`,
    /// if the offline table was filled.
    pub fn segment_bits_at(&self, level_idx: usize, pattern_idx: usize) -> Option<u64> {
        self.segment_bits.get(level_idx)?.get(pattern_idx).copied()
    }

    /// Precomputed Eq. 14 payload bits for
    /// `patterns[level_idx][pattern_idx]`, if the offline table was
    /// filled (deserialized sets recompute per pattern, like
    /// [`PatternSet::segment_bits_at`]).
    pub fn payload_bits_at(&self, level_idx: usize, pattern_idx: usize) -> Option<u64> {
        self.payload_bits.get(level_idx)?.get(pattern_idx).copied()
    }
    /// All partition points available (0..=L).
    pub fn num_partitions(&self) -> usize {
        self.patterns.first().map(|v| v.len()).unwrap_or(0)
    }

    /// Paper Algorithm 2 line 1: largest level not exceeding the request's
    /// accuracy budget `a`; errors if even the tightest level exceeds `a`.
    pub fn select_level(&self, a: f64) -> Result<usize> {
        let mut best: Option<usize> = None;
        for (i, &lvl) in self.levels.iter().enumerate() {
            if lvl <= a + 1e-12 {
                best = Some(i);
            }
        }
        best.ok_or_else(|| {
            Error::Infeasible(format!(
                "accuracy budget {a} tighter than tightest offline level {}",
                self.levels.first().copied().unwrap_or(f64::NAN)
            ))
        })
    }

    /// Look up the pattern at (level, partition). Partitions may be sparse
    /// (restricted architectures), so this searches by partition value.
    pub fn get(&self, key: PatternKey) -> Option<&QuantPattern> {
        self.patterns
            .get(key.level_idx)?
            .iter()
            .find(|p| p.partition == key.partition)
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("model", self.model.as_str().into()),
            ("levels", Value::num_arr(&self.levels)),
            (
                "patterns",
                Value::Arr(
                    self.patterns
                        .iter()
                        .map(|row| Value::Arr(row.iter().map(QuantPattern::to_json).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<PatternSet> {
        let model = v.req_str("model")?.to_string();
        let levels = v.req_f64_arr("levels")?;
        let mut patterns = Vec::new();
        for row in v.req_arr("patterns")? {
            let row = row
                .as_arr()
                .ok_or_else(|| Error::schema("patterns", "expected array of arrays"))?;
            patterns.push(row.iter().map(QuantPattern::from_json).collect::<Result<Vec<_>>>()?);
        }
        if patterns.len() != levels.len() {
            return Err(Error::schema("patterns", "row count != level count"));
        }
        // the segment/payload tables need the ModelSpec; deserialized sets
        // recompute on demand (or via precompute_* once a model is in hand)
        Ok(PatternSet {
            model,
            levels,
            patterns,
            segment_bits: Vec::new(),
            payload_bits: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp6;

    fn pat(p: usize, bits: u8) -> QuantPattern {
        QuantPattern {
            partition: p,
            weight_bits: vec![bits; p],
            activation_bits: bits,
            accuracy_level: 0.01,
            predicted_degradation: 0.008,
        }
    }

    #[test]
    fn payload_reduction_vs_f32() {
        let m = mlp6();
        let q = pat(3, 8);
        let ratio = q.payload_bits(&m) as f64 / q.payload_bits_f32(&m) as f64;
        assert!((ratio - 0.25).abs() < 1e-9, "8/32 bits → exactly 25%: {ratio}");
    }

    #[test]
    fn validate_catches_mismatches() {
        let m = mlp6();
        assert!(pat(3, 8).validate(&m).is_ok());
        assert!(pat(7, 8).validate(&m).is_err()); // p > L
        let mut bad = pat(3, 8);
        bad.weight_bits.pop();
        assert!(bad.validate(&m).is_err());
        let mut bad2 = pat(2, 8);
        bad2.weight_bits[0] = 0;
        assert!(bad2.validate(&m).is_err());
    }

    #[test]
    fn select_level_picks_max_not_exceeding() {
        let set = PatternSet {
            model: "m".into(),
            levels: vec![0.0025, 0.005, 0.01, 0.02, 0.05],
            patterns: vec![vec![]; 5],
            segment_bits: Vec::new(),
            payload_bits: Vec::new(),
        };
        assert_eq!(set.select_level(0.01).unwrap(), 2);
        assert_eq!(set.select_level(0.012).unwrap(), 2);
        assert_eq!(set.select_level(0.05).unwrap(), 4);
        assert_eq!(set.select_level(1.0).unwrap(), 4);
        assert!(set.select_level(0.001).is_err());
    }

    #[test]
    fn pattern_json_roundtrip() {
        let p = pat(4, 6);
        assert_eq!(QuantPattern::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn pattern_set_json_roundtrip() {
        let mut set = PatternSet {
            model: "mlp6".into(),
            levels: vec![0.01, 0.05],
            patterns: vec![vec![pat(0, 8), pat(1, 8)], vec![pat(0, 4), pat(1, 4)]],
            segment_bits: Vec::new(),
            payload_bits: Vec::new(),
        };
        set.precompute_segment_bits(&mlp6());
        set.precompute_payload_bits(&mlp6());
        let v = set.to_json();
        let back = PatternSet::from_json(&v).unwrap();
        assert_eq!(back.model, set.model);
        assert_eq!(back.levels, set.levels);
        assert_eq!(back.patterns, set.patterns);
        // deserialized sets carry no precomputed tables until a model is
        // supplied; precomputing reproduces the original values
        assert!(back.segment_bits.is_empty());
        assert!(back.payload_bits.is_empty());
        let mut back = back;
        back.precompute_segment_bits(&mlp6());
        back.precompute_payload_bits(&mlp6());
        assert_eq!(back.segment_bits, set.segment_bits);
        assert_eq!(back.payload_bits, set.payload_bits);
    }

    #[test]
    fn precomputed_segment_bits_match_per_pattern_compute() {
        let m = mlp6();
        let mut set = PatternSet {
            model: "mlp6".into(),
            levels: vec![0.01],
            patterns: vec![vec![pat(0, 8), pat(2, 4), pat(3, 6)]],
            segment_bits: Vec::new(),
            payload_bits: Vec::new(),
        };
        set.precompute_segment_bits(&m);
        assert_eq!(set.segment_bits.len(), 1);
        for (i, p) in set.patterns[0].iter().enumerate() {
            assert_eq!(set.segment_bits_at(0, i), Some(p.segment_bits(&m)), "pattern {i}");
        }
        // p=0 ships no weights; deeper partitions cost strictly more
        assert_eq!(set.segment_bits_at(0, 0), Some(0));
        assert!(set.segment_bits_at(0, 2) > set.segment_bits_at(0, 1));
        // out-of-range lookups are None, not a panic
        assert_eq!(set.segment_bits_at(0, 99), None);
        assert_eq!(set.segment_bits_at(9, 0), None);
    }

    #[test]
    fn precomputed_payload_bits_match_per_pattern_compute() {
        let m = mlp6();
        let mut set = PatternSet {
            model: "mlp6".into(),
            levels: vec![0.01],
            patterns: vec![vec![pat(0, 8), pat(2, 4), pat(3, 6)]],
            segment_bits: Vec::new(),
            payload_bits: Vec::new(),
        };
        assert_eq!(set.payload_bits_at(0, 0), None, "empty before precompute");
        set.precompute_payload_bits(&m);
        assert_eq!(set.payload_bits.len(), 1);
        for (i, p) in set.patterns[0].iter().enumerate() {
            assert_eq!(set.payload_bits_at(0, i), Some(p.payload_bits(&m)), "pattern {i}");
        }
        // p=0 still ships the (quantized) input activation — nonzero
        assert!(set.payload_bits_at(0, 0).unwrap() > 0);
        // out-of-range lookups are None, not a panic
        assert_eq!(set.payload_bits_at(0, 99), None);
        assert_eq!(set.payload_bits_at(9, 0), None);
    }
}
