//! TCP front-end: JSON-lines over TCP, bounded job queue, and a
//! configurable **executor pool** of inference workers.
//!
//! Topology: N connection threads (one per accepted socket) parse frames
//! and submit `(Request, reply_tx)` jobs into a **bounded** channel — the
//! admission-control point: when the queue is full the request is shed
//! immediately with an `overloaded` error instead of growing latency
//! unboundedly. `workers` inference threads each own a full [`Service`]
//! (bundle + Algorithm 1 tables + PJRT executor — PJRT clients are
//! single-device and not `Send`, so per-worker ownership is the honest
//! parallelism model) and pull jobs from the shared queue. Sessions live
//! in one sharded [`SharedSessionTable`] so the two protocol phases may be
//! handled by different workers; per-worker metrics are aggregated by a
//! [`MetricsHub`] into one logical [`MetricsSnapshot`].
//!
//! `workers` mirrors the simulator's `FleetConfig::server_slots` knob
//! (qpart-sim), so modeled and live serving share one parallelism model.

use crate::metrics::{Metrics, MetricsHub, MetricsSnapshot};
use crate::service::Service;
use crate::session::SharedSessionTable;
use qpart_proto::frame::{read_frame, write_frame, FrameError};
use qpart_proto::messages::{ErrorReply, Request, Response};
use qpart_runtime::Bundle;
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// Server configuration.
///
/// Knobs and what they control:
///
/// * `listen` — TCP listen address; port `0` binds an ephemeral port
///   (the bound address is reported in [`ServerHandle::addr`]).
/// * `workers` — size of the executor pool: how many inference threads
///   (each owning its own PJRT executor + Algorithm 1 tables) drain the
///   job queue concurrently. `1` reproduces the classic single-inference-
///   thread coordinator; the default (`4`) mirrors the simulator's
///   `FleetConfig::server_slots` default so modeled and live serving agree.
/// * `queue_capacity` — **admission control**: the bounded depth of the
///   shared job queue. When all workers are busy and the queue is full,
///   new requests are shed immediately with an `overloaded` error rather
///   than queuing unboundedly (tail latency stays bounded under overload;
///   sheds are counted in `shed_total`).
/// * `session_capacity` — total capacity of the sharded session table for
///   the two-phase protocol. Oldest sessions are evicted first when a
///   shard fills (devices that never upload their activation must not
///   leak memory).
/// * `artifacts_dir` — artifact bundle directory (`make artifacts`).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub listen: String,
    /// Executor-pool size (inference worker threads, each owning a PJRT
    /// executor). Values < 1 are treated as 1.
    pub workers: usize,
    /// Bounded job-queue depth (admission control).
    pub queue_capacity: usize,
    /// Session-table capacity (total across shards).
    pub session_capacity: usize,
    /// Artifact bundle directory.
    pub artifacts_dir: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            // mirrors FleetConfig::default().server_slots (qpart-sim)
            workers: 4,
            // mirrors the config system's serving.queue_capacity default
            queue_capacity: 1024,
            session_capacity: 4096,
            artifacts_dir: "artifacts".into(),
        }
    }
}

type Job = (Request, SyncSender<Response>);

/// Handle to a running server (for tests/examples).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Aggregated + per-worker metrics.
    pub hub: Arc<MetricsHub>,
    /// The shared session table (observability in tests/examples).
    pub sessions: Arc<SharedSessionTable>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and join the threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor so it re-checks the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }

    /// One aggregated snapshot across the front-end and all workers.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.hub.snapshot()
    }

    /// Per-worker snapshots (diagnostics / load-balance checks).
    pub fn worker_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.hub.worker_snapshots()
    }
}

/// Start the server; returns once the listener is bound and **every**
/// worker's service (bundle + Algorithm 1 tables + PJRT) is initialized.
pub fn serve(cfg: ServerConfig) -> Result<ServerHandle, String> {
    let listener = TcpListener::bind(&cfg.listen).map_err(|e| format!("bind {}: {e}", cfg.listen))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    let workers = cfg.workers.max(1);
    let hub = Arc::new(MetricsHub::new());
    let sessions = Arc::new(SharedSessionTable::new(cfg.session_capacity, workers));
    let stop = Arc::new(AtomicBool::new(false));

    let (job_tx, job_rx): (SyncSender<Job>, Receiver<Job>) = sync_channel(cfg.queue_capacity);
    // Work-stealing hand-off: workers take turns locking the receiver;
    // whoever holds the lock waits for the next job, releases, handles it
    // while the next worker waits. Handling happens outside the lock, so
    // up to `workers` jobs are in flight concurrently.
    let job_rx = Arc::new(Mutex::new(job_rx));

    // Inference workers: each owns a (non-Send) service. Bundle +
    // Algorithm 1 initialization happens inside; readiness is reported
    // via a channel so `serve` fails fast if any worker cannot start.
    let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(workers);
    let mut worker_threads = Vec::with_capacity(workers);
    for w in 0..workers {
        let worker_hub = Arc::clone(&hub);
        let worker_sessions = Arc::clone(&sessions);
        let worker_stop = Arc::clone(&stop);
        let worker_rx = Arc::clone(&job_rx);
        let ready_tx = ready_tx.clone();
        let artifacts_dir = cfg.artifacts_dir.clone();
        let t = std::thread::Builder::new()
            .name(format!("qpart-worker-{w}"))
            .spawn(move || {
                let service = Bundle::load(&artifacts_dir)
                    .map_err(|e| e.to_string())
                    .and_then(|b| {
                        Service::new(Rc::new(b), worker_hub, worker_sessions)
                            .map_err(|e| e.to_string())
                    });
                let mut service = match service {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("worker {w}: {e}")));
                        return;
                    }
                };
                // Drop our readiness sender now: if another worker panics
                // during init (sending nothing), serve()'s readiness loop
                // must observe disconnection instead of hanging on workers
                // that hold their clones for the whole job loop.
                drop(ready_tx);
                while !worker_stop.load(Ordering::SeqCst) {
                    // hold the receiver lock only while waiting for a job
                    let next = {
                        let rx = worker_rx.lock().unwrap();
                        rx.recv_timeout(std::time::Duration::from_millis(100))
                    };
                    match next {
                        Ok((req, reply_tx)) => {
                            let resp = service.handle(req);
                            let _ = reply_tx.send(resp);
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                }
            })
            .map_err(|e| e.to_string())?;
        worker_threads.push(t);
    }
    drop(ready_tx);

    for _ in 0..workers {
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(format!("service init failed: {e}")),
            Err(_) => return Err("a worker thread died during init".into()),
        }
    }

    // Acceptor thread: one connection thread per client.
    let accept_stop = Arc::clone(&stop);
    let accept_metrics = hub.front();
    let accept_thread = std::thread::Builder::new()
        .name("qpart-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                // request/response protocol: Nagle + delayed-ACK adds
                // ~40-200 ms per round trip without this
                let _ = stream.set_nodelay(true);
                let job_tx = job_tx.clone();
                let metrics = Arc::clone(&accept_metrics);
                let conn_stop = Arc::clone(&accept_stop);
                let _ = std::thread::Builder::new()
                    .name("qpart-conn".into())
                    .spawn(move || connection_loop(stream, job_tx, metrics, conn_stop));
            }
        })
        .map_err(|e| e.to_string())?;

    Ok(ServerHandle {
        addr,
        hub,
        sessions,
        stop,
        accept_thread: Some(accept_thread),
        worker_threads,
    })
}

fn connection_loop(
    stream: TcpStream,
    job_tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let line = match read_frame(&mut reader) {
            Ok(l) => l,
            Err(FrameError::Closed) => break,
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_frame".into(),
                    message: e.to_string(),
                });
                let _ = write_frame(&mut writer, &resp.to_line());
                break;
            }
        };
        let req = match Request::from_line(&line) {
            Ok(r) => r,
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_request".into(),
                    message: e.to_string(),
                });
                if write_frame(&mut writer, &resp.to_line()).is_err() {
                    break;
                }
                continue;
            }
        };
        let (reply_tx, reply_rx) = sync_channel::<Response>(1);
        let resp = match job_tx.try_send((req, reply_tx)) {
            Ok(()) => match reply_rx.recv() {
                Ok(r) => r,
                Err(_) => Response::Error(ErrorReply {
                    code: "internal".into(),
                    message: "inference worker gone".into(),
                }),
            },
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&metrics.shed_total);
                Response::Error(ErrorReply {
                    code: "overloaded".into(),
                    message: "admission control: job queue full".into(),
                })
            }
            Err(TrySendError::Disconnected(_)) => Response::Error(ErrorReply {
                code: "shutdown".into(),
                message: "server stopping".into(),
            }),
        };
        if write_frame(&mut writer, &resp.to_line()).is_err() {
            break;
        }
    }
}
