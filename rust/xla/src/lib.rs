//! Offline stub of the `xla` crate (xla_extension / PJRT bindings).
//!
//! This workspace builds in a fully offline environment where the real
//! `xla` crate (and the multi-GB `xla_extension` C++ distribution it links)
//! is not available. `qpart-runtime` programs against the small API surface
//! below; this crate provides that surface so the whole workspace compiles
//! and every non-PJRT path (bundle loading, quantization, the coordinator's
//! phase-1 serving path, the simulator) runs for real.
//!
//! Semantics:
//! * [`Literal`] is fully functional — a host-side typed buffer with shape,
//!   byte-exact with what the real bindings would hold.
//! * [`PjRtClient::cpu`] succeeds (so engines can be constructed eagerly),
//!   but [`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`] return
//!   [`Error`] with a clear "PJRT backend unavailable" message. Callers that
//!   gate on the artifact bundle (which only exists after `make artifacts`
//!   on a machine with the JAX/XLA toolchain) never reach these paths.
//!
//! To swap in the real bindings, point the workspace `xla` entry at the
//! real crate via `[patch]` (the API below is a strict subset of it).

use std::borrow::Borrow;
use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!("{what}: PJRT backend unavailable in this offline build (xla stub)"))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types QPART artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

impl ElementType {
    fn size_bytes(&self) -> usize {
        match self {
            ElementType::F32 => 4,
        }
    }
}

/// Array shape of a literal (dims as `i64`, matching the real bindings).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Sealed helper: element types [`Literal::to_vec`] can decode.
pub trait NativeType: Sized {
    fn ty() -> ElementType;
    fn read_le(bytes: &[u8]) -> Self;
}

impl NativeType for f32 {
    fn ty() -> ElementType {
        ElementType::F32
    }

    fn read_le(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// A host-side typed buffer with shape — fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    data: Vec<u8>,
}

impl Literal {
    /// Build a literal from a shape and raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if n * ty.size_bytes() != data.len() {
            return Err(Error(format!(
                "literal shape {dims:?} implies {} bytes, got {}",
                n * ty.size_bytes(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.iter().map(|&d| d as i64).collect(), data: data.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Decode the buffer as a vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::ty() != self.ty {
            return Err(Error(format!("literal is {:?}, requested {:?}", self.ty, T::ty())));
        }
        let sz = self.ty.size_bytes();
        Ok(self.data.chunks_exact(sz).map(T::read_le).collect())
    }

    /// Unwrap a 1-tuple result (QPART lowers every executable with
    /// `return_tuple=True`). The stub's executables never produce tuples,
    /// so this is the identity.
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// A device buffer holding one executable output.
#[derive(Debug)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable. Never constructible in the stub (compile errors
/// first), so `execute` existing is purely for type-checking callers.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _name: String,
}

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("execute"))
    }
}

/// The PJRT client. Construction succeeds; compilation does not.
#[derive(Debug)]
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { platform: "cpu (offline xla stub)" })
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compile"))
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }
}

/// Parsed HLO module. The stub validates the file exists and keeps the
/// text (useful in error messages / debugging).
#[derive(Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("read hlo text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping a parsed HLO module.
#[derive(Debug)]
pub struct XlaComputation {
    _hlo_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo_len: proto.text.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1, 3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[1, 3]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
    }

    #[test]
    fn literal_rejects_size_mismatch() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0u8; 8])
            .is_err());
    }

    #[test]
    fn client_constructs_but_does_not_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let proto = HloModuleProto { text: "HloModule m".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
    }
}
