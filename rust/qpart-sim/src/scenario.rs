//! Scenario engine: declarative multi-phase workloads over the fleet
//! (paper §I's shifting edge population — flash crowds, diurnal load,
//! channel fading mid-session, device-class mix changes, phase-2 upload
//! storms).
//!
//! A scenario is a small line-based text format (serde-free, like the
//! config files) composing phases over [`DeviceClass`] populations:
//!
//! ```text
//! # flash crowd: calm, then a steep arrival ramp, then decay
//! scenario flashcrowd
//! seed 42
//! devices 64
//! phase calm duration 1 rate 10
//! phase surge duration 1.5 rate ramp 10 150
//! phase decay duration 1 rate ramp 150 20
//! ```
//!
//! Phase attributes: `duration <s>`, `rate <r>` | `rate ramp <from> <to>` |
//! `rate diurnal <mean> <amp> <period_s>`, `snr <scale>` (channel-fading
//! shift applied to every request in the phase), `mix a=w,b=w` (device-class
//! mix override for event targeting), `phase2 <n>` (uploads per request —
//! an upload storm when > 1).
//!
//! Generation is deterministic from the seed via labeled substreams
//! ([`Rng::from_label`]) and uses thinning for the inhomogeneous-Poisson
//! patterns, so the same file + seed always yields the same [`Trace`]. A
//! trace exports to text and ingests back byte-identically.

use crate::workload::DeviceClass;
use qpart_core::rng::Rng;

/// Arrival-rate pattern within one phase (requests/s over phase-local time).
#[derive(Debug, Clone, PartialEq)]
pub enum RatePattern {
    /// Constant rate.
    Constant(f64),
    /// Linear ramp from `from` at phase start to `to` at phase end.
    Ramp { from: f64, to: f64 },
    /// Sinusoid: `mean + amplitude * sin(2π t / period_s)`, clamped at 0.
    Diurnal { mean: f64, amplitude: f64, period_s: f64 },
}

impl RatePattern {
    /// Instantaneous rate at phase-local time `u` (seconds into the phase).
    pub fn rate_at(&self, u: f64, duration_s: f64) -> f64 {
        match *self {
            RatePattern::Constant(r) => r.max(0.0),
            RatePattern::Ramp { from, to } => {
                let frac = if duration_s > 0.0 { (u / duration_s).clamp(0.0, 1.0) } else { 0.0 };
                (from + (to - from) * frac).max(0.0)
            }
            RatePattern::Diurnal { mean, amplitude, period_s } => {
                let w = if period_s > 0.0 {
                    (2.0 * std::f64::consts::PI * u / period_s).sin()
                } else {
                    0.0
                };
                (mean + amplitude * w).max(0.0)
            }
        }
    }

    /// Upper bound on the rate over the phase (the thinning envelope).
    pub fn max_rate(&self) -> f64 {
        match *self {
            RatePattern::Constant(r) => r.max(0.0),
            RatePattern::Ramp { from, to } => from.max(to).max(0.0),
            RatePattern::Diurnal { mean, amplitude, .. } => (mean + amplitude.abs()).max(0.0),
        }
    }
}

/// One phase of a scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Phase {
    pub name: String,
    pub duration_s: f64,
    pub rate: RatePattern,
    /// Channel-capacity scale applied to requests arriving in this phase
    /// (1.0 = nominal; < 1 models fading).
    pub snr_scale: f64,
    /// Optional device-class mix override: events in this phase target
    /// classes by these weights instead of the population mix.
    pub mix: Option<Vec<(String, f64)>>,
    /// Phase-2 activation uploads per request (≥ 1; > 1 is an upload storm).
    pub phase2_uploads: u32,
}

impl Phase {
    fn new(name: &str) -> Phase {
        Phase {
            name: name.to_string(),
            duration_s: 1.0,
            rate: RatePattern::Constant(10.0),
            snr_scale: 1.0,
            mix: None,
            phase2_uploads: 1,
        }
    }
}

/// A declarative multi-phase workload scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: String,
    pub seed: u64,
    /// Device population size.
    pub devices: usize,
    pub phases: Vec<Phase>,
}

/// One generated request in a [`Trace`].
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Absolute arrival time from scenario start (s).
    pub arrival_s: f64,
    /// Device index in `[0, devices)`.
    pub device: usize,
    /// Device-class name (from the population assignment).
    pub class: String,
    pub accuracy_budget: f64,
    /// Channel scale of the phase the event arrived in.
    pub snr_scale: f64,
    /// Phase-2 uploads this request performs.
    pub phase2_uploads: u32,
}

/// A fully materialised request trace — exportable/ingestible as text.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

fn parse_f64(tok: &str, what: &str) -> Result<f64, String> {
    tok.parse::<f64>().map_err(|_| format!("scenario: bad {what} value {tok:?}"))
}

impl Scenario {
    /// Parse the line-based scenario format. `#` starts a comment; blank
    /// lines are ignored.
    pub fn parse(text: &str) -> Result<Scenario, String> {
        let mut sc = Scenario {
            name: "unnamed".to_string(),
            seed: 1,
            devices: 16,
            phases: Vec::new(),
        };
        for (ln, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                Some(i) => &raw[..i],
                None => raw,
            };
            let toks: Vec<&str> = line.split_whitespace().collect();
            if toks.is_empty() {
                continue;
            }
            let err = |msg: String| format!("scenario line {}: {}", ln + 1, msg);
            match toks[0] {
                "scenario" => {
                    sc.name = toks.get(1).ok_or_else(|| err("missing name".into()))?.to_string();
                }
                "seed" => {
                    let t = toks.get(1).ok_or_else(|| err("missing seed".into()))?;
                    sc.seed = t.parse::<u64>().map_err(|_| err(format!("bad seed {t:?}")))?;
                }
                "devices" => {
                    let t = toks.get(1).ok_or_else(|| err("missing devices".into()))?;
                    sc.devices =
                        t.parse::<usize>().map_err(|_| err(format!("bad devices {t:?}")))?;
                    if sc.devices == 0 {
                        return Err(err("devices must be > 0".into()));
                    }
                }
                "phase" => {
                    let name = toks.get(1).ok_or_else(|| err("missing phase name".into()))?;
                    let mut ph = Phase::new(name);
                    let mut i = 2;
                    while i < toks.len() {
                        match toks[i] {
                            "duration" => {
                                let t = toks
                                    .get(i + 1)
                                    .ok_or_else(|| err("duration needs a value".into()))?;
                                ph.duration_s = parse_f64(t, "duration").map_err(&err)?;
                                i += 2;
                            }
                            "rate" => {
                                let t = toks
                                    .get(i + 1)
                                    .ok_or_else(|| err("rate needs a value".into()))?;
                                match *t {
                                    "ramp" => {
                                        let a = toks.get(i + 2).ok_or_else(|| {
                                            err("rate ramp needs <from> <to>".into())
                                        })?;
                                        let b = toks.get(i + 3).ok_or_else(|| {
                                            err("rate ramp needs <from> <to>".into())
                                        })?;
                                        ph.rate = RatePattern::Ramp {
                                            from: parse_f64(a, "ramp from").map_err(&err)?,
                                            to: parse_f64(b, "ramp to").map_err(&err)?,
                                        };
                                        i += 4;
                                    }
                                    "diurnal" => {
                                        let m = toks.get(i + 2).ok_or_else(|| {
                                            err("rate diurnal needs <mean> <amp> <period>".into())
                                        })?;
                                        let a = toks.get(i + 3).ok_or_else(|| {
                                            err("rate diurnal needs <mean> <amp> <period>".into())
                                        })?;
                                        let p = toks.get(i + 4).ok_or_else(|| {
                                            err("rate diurnal needs <mean> <amp> <period>".into())
                                        })?;
                                        ph.rate = RatePattern::Diurnal {
                                            mean: parse_f64(m, "diurnal mean").map_err(&err)?,
                                            amplitude: parse_f64(a, "diurnal amp")
                                                .map_err(&err)?,
                                            period_s: parse_f64(p, "diurnal period")
                                                .map_err(&err)?,
                                        };
                                        i += 5;
                                    }
                                    _ => {
                                        ph.rate = RatePattern::Constant(
                                            parse_f64(t, "rate").map_err(&err)?,
                                        );
                                        i += 2;
                                    }
                                }
                            }
                            "snr" => {
                                let t = toks
                                    .get(i + 1)
                                    .ok_or_else(|| err("snr needs a value".into()))?;
                                ph.snr_scale = parse_f64(t, "snr").map_err(&err)?;
                                i += 2;
                            }
                            "phase2" => {
                                let t = toks
                                    .get(i + 1)
                                    .ok_or_else(|| err("phase2 needs a count".into()))?;
                                ph.phase2_uploads = t
                                    .parse::<u32>()
                                    .map_err(|_| err(format!("bad phase2 count {t:?}")))?
                                    .max(1);
                                i += 2;
                            }
                            "mix" => {
                                let t = toks
                                    .get(i + 1)
                                    .ok_or_else(|| err("mix needs a=w,b=w".into()))?;
                                let mut mix = Vec::new();
                                for part in t.split(',') {
                                    let (cls, w) = part
                                        .split_once('=')
                                        .ok_or_else(|| err(format!("bad mix entry {part:?}")))?;
                                    mix.push((
                                        cls.to_string(),
                                        parse_f64(w, "mix weight").map_err(&err)?,
                                    ));
                                }
                                ph.mix = Some(mix);
                                i += 2;
                            }
                            other => {
                                return Err(err(format!("unknown phase attribute {other:?}")));
                            }
                        }
                    }
                    if ph.duration_s <= 0.0 || !ph.duration_s.is_finite() {
                        return Err(err("phase duration must be > 0".into()));
                    }
                    sc.phases.push(ph);
                }
                other => return Err(err(format!("unknown directive {other:?}"))),
            }
        }
        if sc.phases.is_empty() {
            return Err("scenario: no phases".to_string());
        }
        Ok(sc)
    }

    /// Canonical text form (parses back to an equal scenario).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("scenario {}\n", self.name));
        out.push_str(&format!("seed {}\n", self.seed));
        out.push_str(&format!("devices {}\n", self.devices));
        for ph in &self.phases {
            out.push_str(&format!("phase {} duration {}", ph.name, ph.duration_s));
            match ph.rate {
                RatePattern::Constant(r) => out.push_str(&format!(" rate {r}")),
                RatePattern::Ramp { from, to } => out.push_str(&format!(" rate ramp {from} {to}")),
                RatePattern::Diurnal { mean, amplitude, period_s } => {
                    out.push_str(&format!(" rate diurnal {mean} {amplitude} {period_s}"))
                }
            }
            if ph.snr_scale != 1.0 {
                out.push_str(&format!(" snr {}", ph.snr_scale));
            }
            if let Some(mix) = &ph.mix {
                let parts: Vec<String> =
                    mix.iter().map(|(c, w)| format!("{c}={w}")).collect();
                out.push_str(&format!(" mix {}", parts.join(",")));
            }
            if ph.phase2_uploads > 1 {
                out.push_str(&format!(" phase2 {}", ph.phase2_uploads));
            }
            out.push('\n');
        }
        out
    }

    /// Names accepted by [`Scenario::builtin`].
    pub fn builtin_names() -> &'static [&'static str] {
        &["flashcrowd", "diurnal", "storm"]
    }

    /// Built-in scenarios (short horizons, sized for CI soaks).
    pub fn builtin(name: &str) -> Option<Scenario> {
        let text = match name {
            "flashcrowd" => {
                "scenario flashcrowd\nseed 42\ndevices 64\n\
                 phase calm duration 1 rate 10\n\
                 phase surge duration 1.5 rate ramp 10 150\n\
                 phase decay duration 1 rate ramp 150 20\n"
            }
            "diurnal" => {
                "scenario diurnal\nseed 7\ndevices 32\n\
                 phase day duration 4 rate diurnal 40 30 2\n"
            }
            "storm" => {
                "scenario storm\nseed 11\ndevices 32\n\
                 phase calm duration 1 rate 20\n\
                 phase storm duration 1.5 rate 40 snr 0.5 phase2 4\n"
            }
            _ => return None,
        };
        Some(Scenario::parse(text).expect("builtin scenario must parse"))
    }

    /// Total scenario duration (s).
    pub fn total_duration_s(&self) -> f64 {
        self.phases.iter().map(|p| p.duration_s).sum()
    }

    /// Deterministically materialise the request trace for a device
    /// population drawn from `classes`.
    ///
    /// Uses thinning for the inhomogeneous-Poisson phases and labeled
    /// substreams so the class assignment, arrival, and per-request draws
    /// do not perturb each other.
    pub fn generate(&self, classes: &[DeviceClass]) -> Trace {
        assert!(!classes.is_empty());
        if self.devices == 0 {
            return Trace::default();
        }
        // Population assignment (same walk as WorkloadGen, own substream).
        let mut class_rng = Rng::from_label(self.seed, "scenario/classes");
        let total_w: f64 = classes.iter().map(|c| c.weight).sum();
        let mut device_class: Vec<usize> = Vec::with_capacity(self.devices);
        for _ in 0..self.devices {
            let mut pick = class_rng.uniform() * total_w;
            let mut chosen = 0usize;
            for (ci, c) in classes.iter().enumerate() {
                if pick < c.weight {
                    chosen = ci;
                    break;
                }
                pick -= c.weight;
            }
            device_class.push(chosen);
        }

        let mut arrivals = Rng::from_label(self.seed, "scenario/arrivals");
        let mut requests = Rng::from_label(self.seed, "scenario/requests");
        let mut events = Vec::new();
        let mut phase_start = 0.0f64;
        for ph in &self.phases {
            // Per-phase device weights: mix override redistributes event
            // targeting across classes; default is uniform over devices.
            let weights: Vec<f64> = match &ph.mix {
                None => vec![1.0; self.devices],
                Some(mix) => {
                    let mut class_w = vec![0.0f64; classes.len()];
                    for (name, w) in mix {
                        if let Some(ci) = classes.iter().position(|c| c.name == name.as_str()) {
                            class_w[ci] = w.max(0.0);
                        }
                    }
                    let members: Vec<usize> = (0..classes.len())
                        .map(|ci| device_class.iter().filter(|&&c| c == ci).count())
                        .collect();
                    let per_dev: Vec<f64> = device_class
                        .iter()
                        .map(|&ci| if members[ci] > 0 { class_w[ci] / members[ci] as f64 } else { 0.0 })
                        .collect();
                    if per_dev.iter().sum::<f64>() > 0.0 {
                        per_dev
                    } else {
                        vec![1.0; self.devices]
                    }
                }
            };
            let w_total: f64 = weights.iter().sum();

            let rate_max = ph.rate.max_rate();
            if rate_max > 0.0 {
                let mut u = 0.0f64;
                loop {
                    u += arrivals.exponential(1.0 / rate_max);
                    if u >= ph.duration_s {
                        break;
                    }
                    // Thinning: accept with prob rate(u)/rate_max.
                    if arrivals.uniform() * rate_max > ph.rate.rate_at(u, ph.duration_s) {
                        continue;
                    }
                    // Weighted device pick.
                    let mut pick = requests.uniform() * w_total;
                    let mut device = self.devices - 1;
                    for (di, w) in weights.iter().enumerate() {
                        if pick < *w {
                            device = di;
                            break;
                        }
                        pick -= w;
                    }
                    let class = &classes[device_class[device]];
                    let accuracy_budget = *requests.choose(&class.accuracy_budgets);
                    events.push(TraceEvent {
                        arrival_s: phase_start + u,
                        device,
                        class: class.name.to_string(),
                        accuracy_budget,
                        snr_scale: ph.snr_scale,
                        phase2_uploads: ph.phase2_uploads,
                    });
                }
            }
            phase_start += ph.duration_s;
        }
        Trace { events }
    }
}

impl Trace {
    /// Export as text. f64 fields use the shortest round-trip
    /// representation, so `parse(to_text())` reproduces the trace and
    /// re-exporting is byte-identical.
    pub fn to_text(&self) -> String {
        let mut out = String::from("trace v1\n");
        for e in &self.events {
            out.push_str(&format!(
                "{} {} {} {} {} {}\n",
                e.arrival_s, e.device, e.class, e.accuracy_budget, e.snr_scale, e.phase2_uploads
            ));
        }
        out
    }

    /// Ingest a text trace produced by [`Trace::to_text`].
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines();
        match lines.next() {
            Some("trace v1") => {}
            other => return Err(format!("trace: bad header {other:?}")),
        }
        let mut events = Vec::new();
        for (ln, raw) in lines.enumerate() {
            let toks: Vec<&str> = raw.split_whitespace().collect();
            if toks.is_empty() {
                continue;
            }
            if toks.len() != 6 {
                return Err(format!("trace line {}: expected 6 fields", ln + 2));
            }
            let err = |f: &str| format!("trace line {}: bad {f}", ln + 2);
            events.push(TraceEvent {
                arrival_s: toks[0].parse().map_err(|_| err("arrival"))?,
                device: toks[1].parse().map_err(|_| err("device"))?,
                class: toks[2].to_string(),
                accuracy_budget: toks[3].parse().map_err(|_| err("budget"))?,
                snr_scale: toks[4].parse().map_err(|_| err("snr"))?,
                phase2_uploads: toks[5].parse().map_err(|_| err("phase2"))?,
            });
        }
        Ok(Trace { events })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet() -> Vec<DeviceClass> {
        DeviceClass::default_fleet()
    }

    #[test]
    fn builtins_parse_and_generate() {
        for name in Scenario::builtin_names() {
            let sc = Scenario::builtin(name).unwrap();
            assert_eq!(sc.name, *name);
            let trace = sc.generate(&fleet());
            assert!(!trace.events.is_empty(), "{name} generated no events");
            // sorted arrivals within the horizon
            assert!(trace
                .events
                .windows(2)
                .all(|w| w[0].arrival_s <= w[1].arrival_s));
            let horizon = sc.total_duration_s();
            assert!(trace.events.iter().all(|e| e.arrival_s < horizon));
        }
        assert!(Scenario::builtin("nope").is_none());
    }

    #[test]
    fn same_seed_same_trace() {
        let sc = Scenario::builtin("flashcrowd").unwrap();
        let a = sc.generate(&fleet()).to_text();
        let b = sc.generate(&fleet()).to_text();
        assert_eq!(a, b);
        // and a different seed genuinely differs
        let mut sc2 = sc.clone();
        sc2.seed = 999;
        assert_ne!(a, sc2.generate(&fleet()).to_text());
    }

    #[test]
    fn scenario_text_round_trips() {
        let sc = Scenario::builtin("storm").unwrap();
        let text = sc.to_text();
        let back = Scenario::parse(&text).unwrap();
        assert_eq!(sc, back);
        assert_eq!(text, back.to_text());
    }

    #[test]
    fn trace_round_trips_byte_identically() {
        let sc = Scenario::builtin("flashcrowd").unwrap();
        let trace = sc.generate(&fleet());
        let text = trace.to_text();
        let back = Trace::parse(&text).unwrap();
        assert_eq!(trace, back);
        assert_eq!(text, back.to_text());
    }

    #[test]
    fn ramp_rates_match_declaration() {
        // One long ramp 10 → 110 over 10 s: early window ≈ rate 20,
        // late window ≈ rate 100 (integral of the ramp over the window).
        let sc = Scenario::parse(
            "scenario ramp\nseed 5\ndevices 16\nphase r duration 10 rate ramp 10 110\n",
        )
        .unwrap();
        let trace = sc.generate(&fleet());
        let early =
            trace.events.iter().filter(|e| e.arrival_s < 2.0).count() as f64;
        let late = trace
            .events
            .iter()
            .filter(|e| e.arrival_s >= 8.0)
            .count() as f64;
        // expected counts: ∫rate = 40 (early), 200 (late); generous ±
        assert!((15.0..80.0).contains(&early), "early={early}");
        assert!((140.0..270.0).contains(&late), "late={late}");
        assert!(late > early * 2.0, "ramp should accelerate: {early} vs {late}");
    }

    #[test]
    fn diurnal_oscillates() {
        let sc = Scenario::parse(
            "scenario d\nseed 9\ndevices 16\nphase day duration 8 rate diurnal 60 50 4\n",
        )
        .unwrap();
        let trace = sc.generate(&fleet());
        // peak half-periods [0,2) and [4,6) vs trough halves [2,4), [6,8)
        let peak = trace
            .events
            .iter()
            .filter(|e| (e.arrival_s % 4.0) < 2.0)
            .count() as f64;
        let trough = trace.events.len() as f64 - peak;
        assert!(peak > trough * 1.5, "peak={peak} trough={trough}");
    }

    #[test]
    fn mix_targets_named_classes() {
        let sc = Scenario::parse(
            "scenario m\nseed 3\ndevices 64\n\
             phase only duration 4 rate 50 mix sensor=1\n",
        )
        .unwrap();
        let trace = sc.generate(&fleet());
        assert!(!trace.events.is_empty());
        assert!(trace.events.iter().all(|e| e.class == "sensor"), "mix leaked classes");
    }

    #[test]
    fn storm_phase_attributes_propagate() {
        let sc = Scenario::builtin("storm").unwrap();
        let trace = sc.generate(&fleet());
        let calm: Vec<_> =
            trace.events.iter().filter(|e| e.arrival_s < 1.0).collect();
        let storm: Vec<_> =
            trace.events.iter().filter(|e| e.arrival_s >= 1.0).collect();
        assert!(!calm.is_empty() && !storm.is_empty());
        assert!(calm.iter().all(|e| e.phase2_uploads == 1 && e.snr_scale == 1.0));
        assert!(storm.iter().all(|e| e.phase2_uploads == 4 && e.snr_scale == 0.5));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Scenario::parse("").is_err());
        assert!(Scenario::parse("bogus 1\n").is_err());
        assert!(Scenario::parse("phase p duration x rate 5\n").is_err());
        assert!(Scenario::parse("phase p duration 1 rate ramp 5\n").is_err());
        assert!(Scenario::parse("scenario s\nphase p duration 1 wat 2\n").is_err());
        assert!(Trace::parse("nope\n").is_err());
        assert!(Trace::parse("trace v1\n1 2 phone\n").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let sc = Scenario::parse(
            "# a comment\n\nscenario c # trailing\nseed 2\ndevices 8\n\
             phase p duration 2 rate 30\n",
        )
        .unwrap();
        assert_eq!(sc.name, "c");
        assert_eq!(sc.devices, 8);
        assert_eq!(sc.phases.len(), 1);
    }
}
