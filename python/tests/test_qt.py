"""Tests for the .qt tensor interchange format (python side)."""

import numpy as np
import pytest

from compile import qt


def test_roundtrip_f32(tmp_path):
    a = np.random.default_rng(0).normal(size=(3, 5, 2)).astype(np.float32)
    p = tmp_path / "a.qt"
    qt.save(p, a)
    b = qt.load(p)
    assert b.dtype == np.float32
    np.testing.assert_array_equal(a, b)


def test_roundtrip_i32(tmp_path):
    a = np.array([[1, -2], [3, 2_000_000_000]], dtype=np.int32)
    p = tmp_path / "a.qt"
    qt.save(p, a)
    b = qt.load(p)
    assert b.dtype == np.int32
    np.testing.assert_array_equal(a, b)


def test_dtype_coercion(tmp_path):
    qt.save(tmp_path / "f.qt", np.ones((2,), dtype=np.float64))
    assert qt.load(tmp_path / "f.qt").dtype == np.float32
    qt.save(tmp_path / "i.qt", np.ones((2,), dtype=np.int64))
    assert qt.load(tmp_path / "i.qt").dtype == np.int32


def test_single_and_empty(tmp_path):
    # note: np.ascontiguousarray promotes 0-d to 1-d, so scalars save as (1,)
    qt.save(tmp_path / "s.qt", np.float32(3.5).reshape(()))
    loaded = qt.load(tmp_path / "s.qt")
    assert loaded.shape == (1,) and loaded[0] == np.float32(3.5)
    qt.save(tmp_path / "e.qt", np.zeros((0, 4), dtype=np.float32))
    assert qt.load(tmp_path / "e.qt").shape == (0, 4)


def test_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.qt"
    p.write_bytes(b"NOPExxxxxxxxxxxxxxx")
    with pytest.raises(ValueError, match="magic"):
        qt.load(p)


def test_rejects_truncated(tmp_path):
    p = tmp_path / "t.qt"
    qt.save(p, np.zeros((10,), dtype=np.float32))
    raw = p.read_bytes()
    p.write_bytes(raw[:-4])
    with pytest.raises(ValueError, match="truncated"):
        qt.load(p)


def test_rejects_trailing(tmp_path):
    p = tmp_path / "t.qt"
    qt.save(p, np.zeros((4,), dtype=np.float32))
    p.write_bytes(p.read_bytes() + b"\0")
    with pytest.raises(ValueError, match="trailing"):
        qt.load(p)


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        qt.save(tmp_path / "c.qt", np.zeros((2,), dtype=np.complex64))
