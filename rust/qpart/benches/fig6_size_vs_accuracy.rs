//! **Fig. 6** — Optimized Model Size vs Accuracy.
//!
//! Paper: the optimized total parameter size decays (roughly
//! exponentially) as the allowed accuracy degradation grows.

mod common;

use common::*;
use qpart_bench::{fmt_bits, Table};

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 6 — optimized model size vs accuracy degradation (mlp6)", setup.calibrated);
    let arch = &setup.arch;
    let l = arch.num_layers();
    let f32_bits = arch.segment_weight_bits_f32(l);

    let mut table = Table::new(
        "payload at the full partition (weights, all layers quantized)",
        &["allowed degradation", "payload", "vs f32", "mean bits/param"],
    );
    let mut sizes = Vec::new();
    for (k, &level) in setup.patterns.levels.iter().enumerate() {
        let pat = setup
            .patterns
            .get(qpart::core::quant::PatternKey { level_idx: k, partition: l })
            .unwrap();
        let w_bits: u64 = (1..=l)
            .map(|i| (pat.weight_bits[i - 1] as u64) * arch.weight_params(i))
            .sum();
        sizes.push(w_bits as f64);
        table.row(vec![
            format!("{:.2}%", level * 100.0),
            fmt_bits(w_bits),
            format!("{:.1}%", 100.0 * w_bits as f64 / f32_bits as f64),
            format!("{:.2}", w_bits as f64 / arch.total_params() as f64),
        ]);
    }
    table.print();
    // decay check: each looser level must not grow the payload
    let monotone = sizes.windows(2).all(|w| w[1] <= w[0] * (1.0 + 1e-9));
    println!(
        "\npaper shape: size decays ~exponentially with allowed degradation. \
         monotone-decreasing: {monotone}"
    );
}
