//! Session table for the two-phase protocol.
//!
//! Phase 1 (`infer`) opens a session remembering the chosen pattern and
//! the boundary-activation shape; phase 2 (`activation`) consumes it.
//! The table is capacity-bounded: oldest sessions are evicted first
//! (devices that never came back must not leak memory).

use qpart_core::quant::QuantPattern;
use std::time::Instant;

/// One open session.
#[derive(Debug, Clone)]
pub struct Session {
    pub id: u64,
    pub model: String,
    pub pattern: QuantPattern,
    /// Expected boundary-activation dims (batch 1).
    pub boundary_dims: Vec<usize>,
    pub opened: Instant,
}

/// Bounded FIFO-evicting session table.
#[derive(Debug)]
pub struct SessionTable {
    capacity: usize,
    next_id: u64,
    /// Insertion-ordered (oldest first) — eviction pops the front.
    sessions: Vec<Session>,
    /// How many sessions were evicted before being consumed.
    pub evicted: u64,
}

impl SessionTable {
    pub fn new(capacity: usize) -> SessionTable {
        assert!(capacity > 0);
        SessionTable { capacity, next_id: 1, sessions: Vec::new(), evicted: 0 }
    }

    /// Open a session; may evict the oldest.
    pub fn open(
        &mut self,
        model: &str,
        pattern: QuantPattern,
        boundary_dims: Vec<usize>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        if self.sessions.len() >= self.capacity {
            self.sessions.remove(0);
            self.evicted += 1;
        }
        self.sessions.push(Session {
            id,
            model: model.to_string(),
            pattern,
            boundary_dims,
            opened: Instant::now(),
        });
        id
    }

    /// Consume (remove + return) a session.
    pub fn take(&mut self, id: u64) -> Option<Session> {
        let idx = self.sessions.iter().position(|s| s.id == id)?;
        Some(self.sessions.remove(idx))
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pat(p: usize) -> QuantPattern {
        QuantPattern {
            partition: p,
            weight_bits: vec![8; p],
            activation_bits: 8,
            accuracy_level: 0.01,
            predicted_degradation: 0.0,
        }
    }

    #[test]
    fn open_take_roundtrip() {
        let mut t = SessionTable::new(4);
        let id = t.open("mlp6", pat(2), vec![1, 256]);
        assert_eq!(t.len(), 1);
        let s = t.take(id).unwrap();
        assert_eq!(s.model, "mlp6");
        assert_eq!(s.boundary_dims, vec![1, 256]);
        assert!(t.take(id).is_none(), "consumed");
        assert!(t.is_empty());
    }

    #[test]
    fn ids_unique_and_monotone() {
        let mut t = SessionTable::new(8);
        let a = t.open("m", pat(0), vec![1, 784]);
        let b = t.open("m", pat(0), vec![1, 784]);
        assert!(b > a);
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut t = SessionTable::new(2);
        let a = t.open("m", pat(0), vec![1]);
        let b = t.open("m", pat(0), vec![1]);
        let c = t.open("m", pat(0), vec![1]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.evicted, 1);
        assert!(t.take(a).is_none(), "oldest evicted");
        assert!(t.take(b).is_some());
        assert!(t.take(c).is_some());
    }
}
