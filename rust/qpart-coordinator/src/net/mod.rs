//! The evented front-end: a `poll(2)`-based connection reactor that
//! decouples **accepted devices** from **OS threads**.
//!
//! The thread-per-connection front-end capped concurrent devices at
//! whatever the OS would give us in threads — each idle or slow device
//! pinned a stack. Here one reactor thread owns every accepted socket as
//! an explicit state machine, and the executor pool (`--workers`) stays
//! the only knob that sizes compute. Connection count and worker count
//! are fully decoupled: `bench-serve --clients 128 --workers 2` holds
//! 128 live devices over 2 inference threads plus one reactor.
//!
//! Layers, bottom up:
//!
//! * [`sys`] — `poll(2)` over raw fds and a UDP-socket-pair [`sys::Waker`]
//!   (std + one libc symbol; no new dependencies).
//! * [`conn`] — the per-connection state machine: read buffer →
//!   incremental frame splitter, outbox with backpressure, negotiation
//!   state, idle accounting. Two flavors ([`conn::ConnKind`]): protocol
//!   peers and metrics scrapes.
//! * [`reactor`] — the event loop: accept gate (`--max-conns`),
//!   idle/slow-client timeouts (`--conn-idle-secs`), job submission into
//!   the existing `sched` queue, and reply routing back through the
//!   [`crate::sched::ReplyRouter`] completion queue.
//!
//! **The wire protocol is untouched.** Framing, negotiation, admission
//! control, coalescing, and every reply byte are identical to the
//! threaded front-end (`ServerConfig::frontend` keeps the thread-based
//! loop available as a baseline, and `bench-serve` checks byte-identity
//! between the two).

pub mod conn;
pub mod reactor;
pub mod sys;

pub use conn::{Conn, ConnKind};
pub use reactor::{Reactor, ReactorParams};
pub use sys::{install_shutdown_handler, request_shutdown, shutdown_requested, Waker};
