//! Typed key and value codecs: the boundary between the typed cache
//! facades above the store and the opaque byte slices below it.
//!
//! Every codec here is little-endian, fixed layout, and total on encode;
//! decoders return `Option` and treat any malformed input as "not ours"
//! (the caller skips the entry rather than failing replay — a store file
//! written by a newer build must never wedge an older one).
//!
//! Floats are carried as `f64::to_bits` so the round trip is **bit
//! exact** — byte-identical serving after replay depends on it.

use crate::decision::{DecisionKey, ProfileBucket};
use crate::sched::SegmentKey;
use qpart_core::cost::CostBreakdown;
use qpart_core::optimizer::Decision;
use qpart_core::quant::QuantPattern;
use qpart_proto::messages::{EncodedSegmentBody, InferReply};

// -- primitive helpers ------------------------------------------------------

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn push_bytes(out: &mut Vec<u8>, b: &[u8]) {
    push_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

/// Sequential little-endian reader over an encoded key/value.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.at.checked_add(n)?;
        let s = self.buf.get(self.at..end)?;
        self.at = end;
        Some(s)
    }

    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|b| u32::from_le_bytes(b.try_into().expect("4-byte slice")))
    }

    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|b| b[0])
    }

    fn f64(&mut self) -> Option<f64> {
        self.take(8)
            .map(|b| f64::from_bits(u64::from_le_bytes(b.try_into().expect("8-byte slice"))))
    }

    fn bytes(&mut self) -> Option<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }

    fn done(&self) -> bool {
        self.at == self.buf.len()
    }
}

// -- decision column --------------------------------------------------------

/// `DecisionKey{model, level, ProfileBucket}` →
/// `[model][u32 level][104B bucket]`.
pub fn encode_decision_key(key: &DecisionKey) -> Vec<u8> {
    let (model, level, bucket) = key;
    let mut out = Vec::with_capacity(4 + model.len() + 4 + 104);
    push_bytes(&mut out, model.as_bytes());
    push_u32(&mut out, *level as u32);
    out.extend_from_slice(&bucket.to_bytes());
    out
}

pub fn decode_decision_key(buf: &[u8]) -> Option<DecisionKey> {
    let mut c = Cursor::new(buf);
    let model = String::from_utf8(c.bytes()?.to_vec()).ok()?;
    let level = c.u32()? as usize;
    let bucket = ProfileBucket::from_bytes(c.take(104)?)?;
    c.done().then_some((model, level, bucket))
}

/// Bit-exact `Decision` value codec:
/// `[u32 partition][weight_bits][u8 act_bits][2×f64 pattern floats]`
/// `[u32 level_idx][7×f64 cost][u32 n][n×f64 objective_by_partition]`.
pub fn encode_decision(d: &Decision) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + d.pattern.weight_bits.len() + 8 * 9);
    push_u32(&mut out, d.pattern.partition as u32);
    push_bytes(&mut out, &d.pattern.weight_bits);
    out.push(d.pattern.activation_bits);
    push_f64(&mut out, d.pattern.accuracy_level);
    push_f64(&mut out, d.pattern.predicted_degradation);
    push_u32(&mut out, d.level_idx as u32);
    for v in [
        d.cost.t_local_s,
        d.cost.t_server_s,
        d.cost.t_tran_s,
        d.cost.e_local_j,
        d.cost.e_tran_j,
        d.cost.server_cost,
        d.cost.objective,
    ] {
        push_f64(&mut out, v);
    }
    push_u32(&mut out, d.objective_by_partition.len() as u32);
    for v in &d.objective_by_partition {
        push_f64(&mut out, *v);
    }
    out
}

pub fn decode_decision(buf: &[u8]) -> Option<Decision> {
    let mut c = Cursor::new(buf);
    let partition = c.u32()? as usize;
    let weight_bits = c.bytes()?.to_vec();
    let activation_bits = c.u8()?;
    let accuracy_level = c.f64()?;
    let predicted_degradation = c.f64()?;
    let level_idx = c.u32()? as usize;
    let cost = CostBreakdown {
        t_local_s: c.f64()?,
        t_server_s: c.f64()?,
        t_tran_s: c.f64()?,
        e_local_j: c.f64()?,
        e_tran_j: c.f64()?,
        server_cost: c.f64()?,
        objective: c.f64()?,
    };
    let n = c.u32()? as usize;
    let mut objective_by_partition = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        objective_by_partition.push(c.f64()?);
    }
    c.done().then_some(Decision {
        pattern: QuantPattern {
            partition,
            weight_bits,
            activation_bits,
            accuracy_level,
            predicted_degradation,
        },
        level_idx,
        cost,
        objective_by_partition,
    })
}

// -- reply column -----------------------------------------------------------

/// Reply cache key `(model, level, partition)` →
/// `[model][u32 level][u32 partition]`.
pub fn encode_reply_key(key: &SegmentKey) -> Vec<u8> {
    let (model, level, partition) = key;
    let mut out = Vec::with_capacity(4 + model.len() + 8);
    push_bytes(&mut out, model.as_bytes());
    push_u32(&mut out, *level as u32);
    push_u32(&mut out, *partition as u32);
    out
}

pub fn decode_reply_key(buf: &[u8]) -> Option<SegmentKey> {
    let mut c = Cursor::new(buf);
    let model = String::from_utf8(c.bytes()?.to_vec()).ok()?;
    let level = c.u32()? as usize;
    let partition = c.u32()? as usize;
    c.done().then_some((model, level, partition))
}

/// Encoded reply value: the session-independent body in its own binary
/// wire form — `[header JSON][blob]` from [`InferReply::to_binary`] with
/// the per-request fields (session, objective) zeroed. Decoding rebuilds
/// the body through [`EncodedSegmentBody::new`], which re-serializes both
/// wire forms deterministically — replayed replies are byte-identical to
/// freshly encoded ones.
pub fn encode_reply_body(body: &EncodedSegmentBody) -> Vec<u8> {
    let (header, blob) = body.to_reply(0, 0.0).to_binary();
    let mut out = Vec::with_capacity(4 + header.len() + blob.len());
    push_bytes(&mut out, header.as_bytes());
    out.extend_from_slice(&blob);
    out
}

pub fn decode_reply_body(buf: &[u8]) -> Option<EncodedSegmentBody> {
    let mut c = Cursor::new(buf);
    let header = std::str::from_utf8(c.bytes()?).ok()?.to_string();
    let blob = &c.buf[c.at..];
    let reply = InferReply::from_binary(&header, blob).ok()?;
    Some(EncodedSegmentBody::new(&reply.model, reply.pattern, reply.segment))
}

// -- plan column ------------------------------------------------------------

/// A phase-2 plan fingerprint `(model, partition)` — the key is the whole
/// record (`[model][u32 partition]`, empty value): replay uses it to
/// pre-build the compile cache's server-segment plans.
pub fn encode_plan_key(model: &str, partition: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + model.len() + 4);
    push_bytes(&mut out, model.as_bytes());
    push_u32(&mut out, partition as u32);
    out
}

pub fn decode_plan_key(buf: &[u8]) -> Option<(String, usize)> {
    let mut c = Cursor::new(buf);
    let model = String::from_utf8(c.bytes()?.to_vec()).ok()?;
    let partition = c.u32()? as usize;
    c.done().then_some((model, partition))
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::cost::CostModel;

    fn bucket() -> ProfileBucket {
        ProfileBucket::of(&CostModel::paper_default())
    }

    #[test]
    fn decision_key_roundtrip() {
        let key: DecisionKey = ("tinymlp".to_string(), 3, bucket());
        let enc = encode_decision_key(&key);
        assert_eq!(decode_decision_key(&enc), Some(key));
        // truncation and trailing garbage both fail closed
        assert_eq!(decode_decision_key(&enc[..enc.len() - 1]), None);
        let mut longer = enc.clone();
        longer.push(0);
        assert_eq!(decode_decision_key(&longer), None);
        assert_eq!(decode_decision_key(b""), None);
    }

    #[test]
    fn decision_value_roundtrip_is_bit_exact() {
        let d = Decision {
            pattern: QuantPattern {
                partition: 2,
                weight_bits: vec![4, 8],
                activation_bits: 6,
                accuracy_level: 0.01,
                predicted_degradation: 0.0099999999,
            },
            level_idx: 1,
            cost: CostBreakdown {
                t_local_s: 1e-3,
                t_server_s: 2e-4,
                t_tran_s: 3.5e-3,
                e_local_j: 0.25,
                e_tran_j: 0.125,
                server_cost: 7e-6,
                objective: 0.0123456789,
            },
            objective_by_partition: vec![0.9, f64::INFINITY, 0.0123456789],
        };
        let got = decode_decision(&encode_decision(&d)).expect("roundtrip");
        // bit-exact: compare through to_bits so ±0.0 and NaN patterns count
        assert_eq!(got.pattern, d.pattern);
        assert_eq!(got.level_idx, d.level_idx);
        assert_eq!(got.cost.objective.to_bits(), d.cost.objective.to_bits());
        assert_eq!(got.cost, d.cost);
        assert_eq!(got.objective_by_partition, d.objective_by_partition);
        assert_eq!(decode_decision(b"\x01"), None);
    }

    #[test]
    fn reply_key_and_plan_key_roundtrip() {
        let key: SegmentKey = ("m".to_string(), 0, 5);
        assert_eq!(decode_reply_key(&encode_reply_key(&key)), Some(key));
        assert_eq!(decode_reply_key(b"xx"), None);
        let enc = encode_plan_key("tinymlp", 2);
        assert_eq!(decode_plan_key(&enc), Some(("tinymlp".to_string(), 2)));
        assert_eq!(decode_plan_key(&enc[..3]), None);
    }

    #[test]
    fn reply_body_roundtrip_is_byte_identical() {
        use qpart_proto::messages::{LayerBlob, PatternInfo, SegmentBlob};
        let body = EncodedSegmentBody::new(
            "tinymlp",
            PatternInfo {
                partition: 1,
                weight_bits: vec![4],
                activation_bits: 8,
                accuracy_level: 0.01,
                predicted_degradation: 0.004,
                objective: 123.0, // forced to NaN by the body; never persisted
            },
            SegmentBlob {
                layers: vec![LayerBlob {
                    layer: 1,
                    bits: 4,
                    w_dims: vec![2, 3],
                    w_qmin: -1.5,
                    w_step: 0.125,
                    w_packed: vec![0xAB, 0xCD, 0xEF],
                    b_qmin: 0.0,
                    b_step: 0.5,
                    b_len: 3,
                    b_packed: vec![0x01, 0x02],
                }],
            },
        );
        let got = decode_reply_body(&encode_reply_body(&body)).expect("roundtrip");
        // both wire forms and a stamped reply come back byte-identical
        assert_eq!(&*got.layers_json_shared(), &*body.layers_json_shared());
        assert_eq!(got.blob(), body.blob());
        assert_eq!(got.to_reply(7, 1.5), body.to_reply(7, 1.5));
        // a re-encode of the decoded body is stable, too
        assert_eq!(encode_reply_body(&got), encode_reply_body(&body));
        assert!(decode_reply_body(b"\x04\x00\x00\x00junk").is_none());
    }

    #[test]
    fn profile_bucket_bytes_roundtrip() {
        let b = bucket();
        assert_eq!(ProfileBucket::from_bytes(&b.to_bytes()), Some(b));
        assert_eq!(ProfileBucket::from_bytes(&[0u8; 103]), None);
    }
}
