//! **Ablation** — the value of the closed-form *layer-wise* bit-width
//! solution (DESIGN.md §6, design-choice ablations).
//!
//! Compares, under the same Σψ ≤ 1 accuracy budget:
//! 1. QPART's water-filling solution (Eq. 27),
//! 2. the best *uniform* bit-width (the same b everywhere — what a system
//!    without layer-wise optimization would ship),
//! 3. the effect of the integer round-up rule,
//! 4. tighter/looser bit bounds.

mod common;

use common::*;
use qpart::core::accuracy::psi;
use qpart::core::optimizer::{solve_pattern, BitBounds};
use qpart_bench::{fmt_bits, Table};

fn main() {
    let setup = mlp6_setup();
    banner("ablation — layer-wise vs uniform bit-widths (mlp6)", setup.calibrated);
    let arch = &setup.arch;
    let calib = &setup.calib;
    let l = arch.num_layers();

    let mut table = Table::new(
        "payload to satisfy the same noise budget (full partition p=L)",
        &["level", "water-filling", "uniform-b", "uniform bits", "overhead"],
    );
    for (k, &level) in calib.levels.iter().enumerate() {
        let pat = solve_pattern(arch, calib, k, l, BitBounds::default()).unwrap();
        let wf_bits = pat.payload_bits(arch);

        // smallest uniform b whose Σψ ≤ 1
        let mut uniform_b = None;
        for b in 2u8..=16 {
            let mut total = psi(calib.s_x(l), b as f64, calib.rho_x(l, k));
            for i in 1..=l {
                total += psi(calib.s_w(i), b as f64, calib.rho_w(i, k));
            }
            if total <= 1.0 {
                uniform_b = Some(b);
                break;
            }
        }
        let (uni_bits, uni_b_str) = match uniform_b {
            Some(b) => {
                let z: u64 = (1..=l).map(|i| arch.weight_params(i)).sum::<u64>()
                    + arch.activation_elems(l);
                (z * b as u64, b.to_string())
            }
            None => (u64::MAX, "infeasible".into()),
        };
        table.row(vec![
            format!("{:.2}%", level * 100.0),
            fmt_bits(wf_bits),
            if uni_bits == u64::MAX { "-".into() } else { fmt_bits(uni_bits) },
            uni_b_str,
            if uni_bits == u64::MAX {
                "-".into()
            } else {
                format!("+{:.1}%", 100.0 * (uni_bits as f64 / wf_bits as f64 - 1.0))
            },
        ]);
    }
    table.print();

    // integer rounding: ceil keeps the constraint, round-to-nearest can break it
    let mut violations = 0usize;
    let mut total = 0usize;
    for k in 0..calib.levels.len() {
        for p in 1..=l {
            let pat = solve_pattern(arch, calib, k, p, BitBounds::default()).unwrap();
            // nearest-rounded variant
            let mut psi_nearest = psi(
                calib.s_x(p),
                pat.activation_bits as f64, // already integer; approximate
                calib.rho_x(p, k),
            );
            for i in 1..=p {
                // subtract a half-bit to emulate round-to-nearest on average
                let b = (pat.weight_bits[i - 1] as f64 - 0.5).max(2.0);
                psi_nearest += psi(calib.s_w(i), b, calib.rho_w(i, k));
            }
            total += 1;
            if psi_nearest > 1.0 {
                violations += 1;
            }
        }
    }
    println!(
        "\nround-to-nearest (instead of round-up) would violate the accuracy budget in \
         {violations}/{total} (level, partition) cells — round-up never does."
    );

    // bounds sensitivity
    let mut t2 = Table::new(
        "bit-bound sensitivity (level a=1%, p=L)",
        &["bounds", "bits", "payload"],
    );
    for (lo, hi) in [(1u8, 24u8), (2, 16), (4, 8)] {
        match solve_pattern(arch, calib, LEVEL_1PCT, l, BitBounds { min_bits: lo, max_bits: hi }) {
            Ok(pat) => t2.row(vec![
                format!("[{lo},{hi}]"),
                format!("{:?}", pat.weight_bits),
                fmt_bits(pat.payload_bits(arch)),
            ]),
            Err(e) => t2.row(vec![format!("[{lo},{hi}]"), format!("{e}"), "-".into()]),
        }
    }
    t2.print();
}
