//! Overload brownout: a server-wide degradation ladder driven by queue
//! pressure, plus the accuracy-budget check that makes stepping down the
//! ladder *safe*.
//!
//! QPART's premise is that every request carries an accuracy requirement,
//! so the right response to overload is not a binary shed but a planned
//! degradation: serve a coarser quantization level whose Algorithm-1
//! predicted degradation still fits the request's budget. The controller
//! here only decides *how hard* the server is being pushed (the brownout
//! level); [`degrade_level`] decides, per request, whether a coarser table
//! row actually honours that request's budget — and when it does not, the
//! request is simply planned at its nominal level (degradation never
//! trades away the accuracy guarantee).
//!
//! Mechanics: workers feed per-job queue-wait samples into an EWMA
//! ([`BrownoutController::observe_wait_us`]); the housekeeping thread
//! calls [`BrownoutController::tick`] a few times per second with the
//! current connection pressure. Hysteresis is asymmetric — a handful of
//! consecutive hot ticks steps the ladder up, but it takes a sustained
//! calm stretch to step back down — so the level cannot flap on bursty
//! arrivals. Transitions are published through the front-end
//! [`Metrics`]: the `brownout_level` gauge plus `brownout_enters_total` /
//! `brownout_exits_total` counters (the acceptance check "brownout enters
//! *and exits*" reads exactly these).

use crate::metrics::Metrics;
use qpart_core::quant::PatternSet;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

/// EWMA smoothing factor for queue-wait samples.
const ALPHA: f64 = 0.05;
/// Per-tick decay applied to the wait EWMA so a silent (empty) queue
/// cools down even when no samples arrive.
const TICK_DECAY: f64 = 0.98;
/// Consecutive hot ticks before stepping the ladder up.
const HOT_TICKS_TO_STEP: u32 = 3;
/// Consecutive calm ticks before stepping the ladder down.
const CALM_TICKS_TO_STEP: u32 = 20;
/// Deepest ladder level (0 = nominal service).
pub const MAX_LEVEL: u32 = 3;

/// Server-wide brownout state machine. Cheap to share: one `Arc` across
/// the front-end, every worker, and the housekeeping thread; all state is
/// atomic and `observe_wait_us` is wait-free in the common case.
#[derive(Debug)]
pub struct BrownoutController {
    /// Queue-wait EWMA threshold (µs) above which a tick counts as hot.
    enter_wait_us: f64,
    /// Current ladder level, `0..=MAX_LEVEL`.
    level: AtomicU32,
    /// Queue-wait EWMA, stored as `f64::to_bits`.
    ewma_bits: AtomicU64,
    hot_ticks: AtomicU32,
    calm_ticks: AtomicU32,
    /// Front-end metrics carrying the gauge + transition counters.
    metrics: Arc<Metrics>,
}

impl BrownoutController {
    /// A controller that flags hot ticks once the queue-wait EWMA passes
    /// `enter_wait_us` (or connection pressure nears `max_conns`).
    /// Returns `None` when `enter_wait_us == 0` — the documented way to
    /// disable brownout entirely (callers then never degrade).
    pub fn new(enter_wait_us: u64, metrics: Arc<Metrics>) -> Option<Arc<BrownoutController>> {
        if enter_wait_us == 0 {
            return None;
        }
        Some(Arc::new(BrownoutController {
            enter_wait_us: enter_wait_us as f64,
            level: AtomicU32::new(0),
            ewma_bits: AtomicU64::new(0f64.to_bits()),
            hot_ticks: AtomicU32::new(0),
            calm_ticks: AtomicU32::new(0),
            metrics,
        }))
    }

    /// Current ladder level (0 = nominal).
    pub fn level(&self) -> u32 {
        self.level.load(Ordering::Relaxed)
    }

    /// Current queue-wait EWMA in microseconds.
    pub fn wait_ewma_us(&self) -> f64 {
        f64::from_bits(self.ewma_bits.load(Ordering::Relaxed))
    }

    /// Fold one queue-wait sample (µs) into the EWMA. Called by workers
    /// for every drained job, so it must not take locks.
    pub fn observe_wait_us(&self, us: u64) {
        let mut cur = self.ewma_bits.load(Ordering::Relaxed);
        loop {
            let prev = f64::from_bits(cur);
            let next = prev + ALPHA * (us as f64 - prev);
            match self.ewma_bits.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// One housekeeping evaluation. `conns_open`/`max_conns` add a second
    /// pressure signal: ≥ 90% of the accept gate counts as hot even when
    /// queue waits look fine (the outbox/accept path is saturating).
    /// Steps the ladder at most one level per call.
    pub fn tick(&self, conns_open: u64, max_conns: u64) {
        // Cool the EWMA so pressure decays even with an empty queue.
        let mut cur = self.ewma_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) * TICK_DECAY).to_bits();
            match self.ewma_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        let wait = self.wait_ewma_us();
        let conn_pressure = max_conns > 0 && conns_open.saturating_mul(10) >= max_conns * 9;
        let hot = wait > self.enter_wait_us || conn_pressure;
        // Exit threshold sits at half the entry threshold (hysteresis).
        let calm = wait < self.enter_wait_us * 0.5 && !conn_pressure;
        if hot {
            self.calm_ticks.store(0, Ordering::Relaxed);
            let streak = self.hot_ticks.fetch_add(1, Ordering::Relaxed) + 1;
            if streak >= HOT_TICKS_TO_STEP {
                self.hot_ticks.store(0, Ordering::Relaxed);
                let lvl = self.level.load(Ordering::Relaxed);
                if lvl < MAX_LEVEL {
                    self.level.store(lvl + 1, Ordering::Relaxed);
                    self.metrics.brownout_level.store((lvl + 1) as u64, Ordering::Relaxed);
                    Metrics::inc(&self.metrics.brownout_enters_total);
                }
            }
        } else if calm {
            self.hot_ticks.store(0, Ordering::Relaxed);
            let streak = self.calm_ticks.fetch_add(1, Ordering::Relaxed) + 1;
            if streak >= CALM_TICKS_TO_STEP {
                self.calm_ticks.store(0, Ordering::Relaxed);
                let lvl = self.level.load(Ordering::Relaxed);
                if lvl > 0 {
                    self.level.store(lvl - 1, Ordering::Relaxed);
                    self.metrics.brownout_level.store((lvl - 1) as u64, Ordering::Relaxed);
                    Metrics::inc(&self.metrics.brownout_exits_total);
                }
            }
        } else {
            // In the hysteresis band: neither streak advances.
            self.hot_ticks.store(0, Ordering::Relaxed);
            self.calm_ticks.store(0, Ordering::Relaxed);
        }
    }
}

/// The accuracy-budget gate of the degradation ladder.
///
/// Given the request's nominal level index (`PatternSet::select_level` of
/// its budget) and the brownout depth (`rungs` = levels to try past
/// nominal), returns the coarsest level index whose *every* pattern's
/// Algorithm-1 `predicted_degradation` still fits `budget` — "every"
/// because Algorithm 2 is then free to pick any partition at that level
/// without re-checking accuracy. When no coarser level fits (the usual
/// case when the offline solve saturates its target), returns `nominal`
/// unchanged: brownout never degrades past the budget.
pub fn degrade_level(set: &PatternSet, nominal: usize, budget: f64, rungs: u32) -> usize {
    if rungs == 0 || nominal + 1 >= set.levels.len() {
        return nominal;
    }
    let top = (nominal + rungs as usize).min(set.levels.len() - 1);
    for j in (nominal + 1..=top).rev() {
        let fits = set.patterns[j]
            .iter()
            .all(|p| p.predicted_degradation <= budget + 1e-12);
        if fits && !set.patterns[j].is_empty() {
            return j;
        }
    }
    nominal
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::quant::QuantPattern;

    fn pat(level: f64, predicted: f64) -> QuantPattern {
        QuantPattern {
            partition: 1,
            weight_bits: vec![8],
            activation_bits: 8,
            accuracy_level: level,
            predicted_degradation: predicted,
        }
    }

    fn table(rows: &[(f64, &[f64])]) -> PatternSet {
        PatternSet {
            model: "tinymlp".into(),
            levels: rows.iter().map(|(l, _)| *l).collect(),
            patterns: rows
                .iter()
                .map(|(l, preds)| preds.iter().map(|&p| pat(*l, p)).collect())
                .collect(),
            segment_bits: Vec::new(),
            payload_bits: Vec::new(),
        }
    }

    #[test]
    fn degrade_picks_coarsest_level_within_budget() {
        // Levels 0.01 / 0.02 / 0.05, but the solves landed well under
        // target: the 0.05 row only predicts 0.018 degradation.
        let set = table(&[
            (0.01, &[0.004, 0.006][..]),
            (0.02, &[0.009, 0.011][..]),
            (0.05, &[0.015, 0.018][..]),
        ]);
        // Budget 0.02, nominal level 1: level 2's worst prediction
        // (0.018) fits, so brownout can jump straight to it.
        assert_eq!(degrade_level(&set, 1, 0.02, 2), 2);
        // One rung only: still allowed to take level 2.
        assert_eq!(degrade_level(&set, 1, 0.02, 1), 2);
        // Zero rungs (no brownout): nominal.
        assert_eq!(degrade_level(&set, 1, 0.02, 0), 1);
    }

    #[test]
    fn degrade_never_exceeds_budget() {
        // The coarser row saturates its target: 0.05 predicted, which a
        // 0.02 budget cannot absorb — stay at nominal.
        let set = table(&[
            (0.01, &[0.009][..]),
            (0.02, &[0.019][..]),
            (0.05, &[0.049][..]),
        ]);
        assert_eq!(degrade_level(&set, 1, 0.02, MAX_LEVEL), 1);
        // And a partially-infeasible row (one pattern over budget) is
        // rejected as a whole, since Algorithm 2 may pick any partition.
        let mixed = table(&[
            (0.01, &[0.009][..]),
            (0.02, &[0.012, 0.03][..]),
        ]);
        assert_eq!(degrade_level(&mixed, 0, 0.01, MAX_LEVEL), 0);
        // Nominal at the last level: nowhere coarser to go.
        assert_eq!(degrade_level(&set, 2, 0.05, MAX_LEVEL), 2);
    }

    #[test]
    fn degrade_skips_unfit_rungs_to_find_a_fit() {
        // Middle rung overshoots, deepest rung fits: the ladder takes
        // the deepest fitting one, not the first.
        let set = table(&[
            (0.005, &[0.004][..]),
            (0.01, &[0.03][..]), // bad solve, over any small budget
            (0.02, &[0.0045][..]),
        ]);
        assert_eq!(degrade_level(&set, 0, 0.005, 2), 2);
        // With only one rung of depth the bad row blocks degradation.
        assert_eq!(degrade_level(&set, 0, 0.005, 1), 0);
    }

    #[test]
    fn controller_steps_up_under_load_and_back_down_when_calm() {
        let metrics = Arc::new(Metrics::default());
        let ctrl = BrownoutController::new(10_000, Arc::clone(&metrics))
            .expect("non-zero threshold enables brownout");
        assert_eq!(ctrl.level(), 0);
        // Hot: queue waits way above the 10ms threshold.
        for _ in 0..HOT_TICKS_TO_STEP {
            for _ in 0..64 {
                ctrl.observe_wait_us(200_000);
            }
            ctrl.tick(0, 64);
        }
        assert_eq!(ctrl.level(), 1, "steps after {HOT_TICKS_TO_STEP} hot ticks");
        // Sustained heat walks the ladder to its cap and no further.
        for _ in 0..(HOT_TICKS_TO_STEP * (MAX_LEVEL + 2)) {
            for _ in 0..64 {
                ctrl.observe_wait_us(200_000);
            }
            ctrl.tick(0, 64);
        }
        assert_eq!(ctrl.level(), MAX_LEVEL);
        assert_eq!(
            metrics.brownout_level.load(Ordering::Relaxed),
            MAX_LEVEL as u64
        );
        // Calm: no new samples, the tick decay drains the EWMA and the
        // calm streak steps the ladder all the way back to 0.
        for _ in 0..2_000 {
            ctrl.tick(0, 64);
        }
        assert_eq!(ctrl.level(), 0, "gauge returns to nominal after load drops");
        assert_eq!(metrics.brownout_level.load(Ordering::Relaxed), 0);
        let enters = metrics.brownout_enters_total.load(Ordering::Relaxed);
        let exits = metrics.brownout_exits_total.load(Ordering::Relaxed);
        assert_eq!(enters, MAX_LEVEL as u64);
        assert_eq!(exits, enters, "every enter eventually exits");
    }

    #[test]
    fn connection_pressure_alone_is_hot() {
        let metrics = Arc::new(Metrics::default());
        let ctrl = BrownoutController::new(10_000, Arc::clone(&metrics)).unwrap();
        for _ in 0..HOT_TICKS_TO_STEP {
            ctrl.tick(60, 64); // ≥ 90% of the accept gate
        }
        assert_eq!(ctrl.level(), 1);
        // Dropping below the pressure band (and a cold EWMA) is calm.
        for _ in 0..CALM_TICKS_TO_STEP {
            ctrl.tick(1, 64);
        }
        assert_eq!(ctrl.level(), 0);
    }

    #[test]
    fn zero_threshold_disables_brownout() {
        assert!(BrownoutController::new(0, Arc::new(Metrics::default())).is_none());
    }
}
