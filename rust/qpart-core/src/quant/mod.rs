//! Quantization: the uniform asymmetric quantizer (paper Eq. 9–10),
//! arbitrary-bit-width bit-packing for the simulated wire (the payload the
//! channel model charges for, Eq. 14), and quantization patterns `(b, p)`
//! (the unit Algorithm 1 produces and Algorithm 2 selects).

mod bitpack;
mod pattern;
mod quantizer;

pub use bitpack::{pack_bits, unpack_bits, packed_len_bytes};
pub use pattern::{PatternKey, PatternSet, QuantPattern};
pub use quantizer::{QuantParams, Quantized, dequantize, quantize, quantize_with};
