//! One eviction engine and one stats shape for every coordinator cache.

use crate::metrics::Metrics;
use qpart_core::json::Value;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

/// The unified cache stats shape (ISSUE-10 satellite): every cache —
/// reply, decision, compile — reports these five numbers, and the
/// metrics hub emits them as labelled `qpart_cache_*{cache="..."}`
/// series.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: u64,
    pub bytes: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// The canonical JSON document for one cache (one shape for all of
    /// them — the `caches` section of the stats document).
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("hits", self.hits.into()),
            ("misses", self.misses.into()),
            ("entries", self.entries.into()),
            ("bytes", self.bytes.into()),
            ("evictions", self.evictions.into()),
        ])
    }
}

/// How a [`CacheCore`] bounds itself.
#[derive(Debug, Clone, Copy)]
pub enum EvictPolicy {
    /// Evict least-recently-used entries while the byte total exceeds
    /// `budget` — but never the sole remaining entry, so one oversized
    /// value still serves (the reply cache's historical contract).
    LruBytes { budget: u64 },
    /// Evict oldest-inserted entries while the entry count exceeds
    /// `capacity`. Replacing a key keeps its queue position (the
    /// decision cache's historical contract).
    FifoCap { capacity: usize },
}

struct CoreInner<K, V> {
    /// key → (value, byte cost)
    map: HashMap<K, (V, u64)>,
    /// LRU: front = coldest; FIFO: front = oldest-inserted.
    order: VecDeque<K>,
    bytes: u64,
}

/// The one eviction engine under the coordinator's caches. Typed facades
/// ([`DecisionCache`](crate::decision::DecisionCache),
/// [`EncodedReplyCache`](crate::sched::EncodedReplyCache)) wrap this with
/// their historical key/value types; the engine owns ordering, byte
/// accounting, hit/miss/eviction counters, and the [`CacheStats`] shape.
pub struct CacheCore<K, V> {
    policy: EvictPolicy,
    inner: RwLock<CoreInner<K, V>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Clone + Eq + std::hash::Hash, V: Clone> CacheCore<K, V> {
    pub fn new(policy: EvictPolicy) -> CacheCore<K, V> {
        CacheCore {
            policy,
            inner: RwLock::new(CoreInner {
                map: HashMap::new(),
                order: VecDeque::new(),
                bytes: 0,
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look `key` up, counting the hit or miss. Under [`EvictPolicy::LruBytes`]
    /// a hit also refreshes recency (which needs the write lock); FIFO
    /// lookups stay on the shared lock.
    pub fn get(&self, key: &K) -> Option<V> {
        let touch = matches!(self.policy, EvictPolicy::LruBytes { .. });
        let found = if touch {
            let mut inner = crate::decision::write_recover(&self.inner);
            let found = inner.map.get(key).map(|(v, _)| v.clone());
            if found.is_some() {
                if let Some(pos) = inner.order.iter().position(|k| k == key) {
                    let k = inner.order.remove(pos).expect("position just found");
                    inner.order.push_back(k);
                }
            }
            found
        } else {
            let inner = crate::decision::read_recover(&self.inner);
            inner.map.get(key).map(|(v, _)| v.clone())
        };
        if found.is_some() {
            Metrics::inc(&self.hits);
        } else {
            Metrics::inc(&self.misses);
        }
        found
    }

    /// Whether `key` is resident, without touching recency or counters.
    pub fn contains(&self, key: &K) -> bool {
        crate::decision::read_recover(&self.inner).map.contains_key(key)
    }

    /// Insert or replace `key`, charging `cost` bytes, and return the
    /// keys evicted to make room (so a store-backed facade can stage the
    /// matching deletes). Replacing a key updates its byte charge; under
    /// LRU a replace refreshes recency, under FIFO it keeps the original
    /// queue position.
    pub fn insert(&self, key: K, value: V, cost: u64) -> Vec<K> {
        let mut inner = crate::decision::write_recover(&self.inner);
        let replaced = inner.map.insert(key.clone(), (value, cost));
        match replaced {
            Some((_, old_cost)) => {
                inner.bytes = inner.bytes.saturating_sub(old_cost) + cost;
                if matches!(self.policy, EvictPolicy::LruBytes { .. }) {
                    if let Some(pos) = inner.order.iter().position(|k| *k == key) {
                        let k = inner.order.remove(pos).expect("position just found");
                        inner.order.push_back(k);
                    }
                }
            }
            None => {
                inner.bytes += cost;
                inner.order.push_back(key);
            }
        }
        let mut evicted = Vec::new();
        loop {
            let over = match self.policy {
                EvictPolicy::LruBytes { budget } => {
                    inner.bytes > budget && inner.order.len() > 1
                }
                EvictPolicy::FifoCap { capacity } => inner.order.len() > capacity,
            };
            if !over {
                break;
            }
            let Some(victim) = inner.order.pop_front() else { break };
            if let Some((_, victim_cost)) = inner.map.remove(&victim) {
                inner.bytes = inner.bytes.saturating_sub(victim_cost);
                Metrics::inc(&self.evictions);
                evicted.push(victim);
            }
        }
        evicted
    }

    /// Visit every resident entry (unspecified order).
    pub fn for_each(&self, f: &mut dyn FnMut(&K, &V)) {
        let inner = crate::decision::read_recover(&self.inner);
        for (k, (v, _)) in &inner.map {
            f(k, v);
        }
    }

    pub fn len(&self) -> usize {
        crate::decision::read_recover(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> u64 {
        crate::decision::read_recover(&self.inner).bytes
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// The unified stats snapshot.
    pub fn stats(&self) -> CacheStats {
        let (entries, bytes) = {
            let inner = crate::decision::read_recover(&self.inner);
            (inner.map.len() as u64, inner.bytes)
        };
        CacheStats {
            hits: self.hits(),
            misses: self.misses(),
            entries,
            bytes,
            evictions: self.evictions(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lru_evicts_coldest_first_and_never_the_sole_entry() {
        let core: CacheCore<u32, &'static str> =
            CacheCore::new(EvictPolicy::LruBytes { budget: 100 });
        assert!(core.insert(1, "a", 40).is_empty());
        assert!(core.insert(2, "b", 40).is_empty());
        // touch 1 so 2 becomes coldest
        assert_eq!(core.get(&1), Some("a"));
        let evicted = core.insert(3, "c", 40);
        assert_eq!(evicted, vec![2]);
        assert_eq!(core.bytes(), 80);
        // one oversized value still serves: sole survivor is never evicted
        let evicted = core.insert(4, "d", 500);
        assert!(evicted.contains(&1) && evicted.contains(&3));
        assert_eq!(core.len(), 1);
        assert_eq!(core.get(&4), Some("d"));
        assert_eq!(core.stats().evictions, 3);
    }

    #[test]
    fn fifo_caps_entries_and_replace_keeps_position() {
        let core: CacheCore<u32, u32> = CacheCore::new(EvictPolicy::FifoCap { capacity: 2 });
        core.insert(1, 10, 0);
        core.insert(2, 20, 0);
        // replacing 1 must not move it to the back of the FIFO queue
        core.insert(1, 11, 0);
        let evicted = core.insert(3, 30, 0);
        assert_eq!(evicted, vec![1], "oldest-inserted goes first despite the replace");
        assert_eq!(core.get(&2), Some(20));
        assert_eq!(core.get(&3), Some(30));
        assert_eq!(core.get(&1), None);
        let stats = core.stats();
        assert_eq!((stats.hits, stats.misses, stats.entries), (2, 1, 2));
    }

    #[test]
    fn replace_updates_byte_charge_without_leaking() {
        let core: CacheCore<u32, &'static str> =
            CacheCore::new(EvictPolicy::LruBytes { budget: 1000 });
        core.insert(1, "a", 100);
        core.insert(1, "bigger", 300);
        assert_eq!(core.bytes(), 300);
        core.insert(1, "small", 10);
        assert_eq!(core.bytes(), 10);
        assert_eq!(core.len(), 1);
    }
}
