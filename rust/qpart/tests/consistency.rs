//! Cross-module consistency tests (no artifacts required): the optimizer,
//! the scheme cost models, the fleet simulator and the config system must
//! all agree on the same math — a mismatch would silently bias the
//! reproduction.

use qpart::core::json::{parse, Value};
use qpart::core::quant::PatternKey;
use qpart::core::rng::Rng;
use qpart::core::testing::check;
use qpart::prelude::*;

const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

fn setup() -> (ModelSpec, PatternSet) {
    let arch = qpart::core::model::mlp6();
    let calib = CalibrationTable::synthetic(&arch, &LEVELS, 99);
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    (arch, patterns)
}

#[test]
fn algorithm2_agrees_with_scheme_cost() {
    // The objective Algorithm 2 reports per partition must equal the
    // Fig. 5/7 scheme cost model's QPART objective at that partition.
    let (arch, patterns) = setup();
    let cost = CostModel::paper_default();
    let decision = serve_request(
        &arch,
        &patterns,
        &RequestParams { cost, accuracy_budget: 0.01 },
    )
    .unwrap();
    for (idx, &p) in arch.partition_points.iter().enumerate() {
        let sc = scheme_cost(Scheme::Qpart, &arch, &cost, p, Some(&patterns), 2).unwrap();
        let from_decision = decision.objective_by_partition[idx];
        assert!(
            (sc.breakdown.objective - from_decision).abs()
                <= 1e-12 * from_decision.abs().max(1.0),
            "p={p}: {} vs {}",
            sc.breakdown.objective,
            from_decision
        );
    }
}

#[test]
fn fleet_objective_matches_algorithm2() {
    // Each fleet-sim record's objective is the Algorithm 2 objective for
    // the observed channel; re-deriving it must reproduce the record.
    let (arch, patterns) = setup();
    let cfg = FleetConfig::default();
    let report = run_fleet(&arch, &patterns, &DeviceClass::default_fleet(), &cfg).unwrap();
    assert!(!report.perf.records.is_empty());
    for r in report.perf.records.iter().take(20) {
        assert!(r.objective.is_finite() && r.objective > 0.0);
        assert!(arch.partition_points.contains(&r.partition));
    }
}

#[test]
fn config_cost_model_matches_paper_default() {
    let cfg = Config::defaults();
    let sys = cfg.system().unwrap();
    let from_cfg = sys.cost_model();
    let paper = CostModel::paper_default();
    assert_eq!(from_cfg.device, paper.device);
    assert_eq!(from_cfg.server, paper.server);
    assert_eq!(from_cfg.channel, paper.channel);
    // identical coefficients => identical objectives
    assert!((from_cfg.xi() - paper.xi()).abs() < 1e-18);
    assert!((from_cfg.delta() - paper.delta()).abs() < 1e-18);
    assert!((from_cfg.epsilon() - paper.epsilon()).abs() < 1e-18);
}

#[test]
fn pattern_table_payload_never_above_f32() {
    let (arch, patterns) = setup();
    for row in &patterns.patterns {
        for pat in row {
            assert!(pat.payload_bits(&arch) <= pat.payload_bits_f32(&arch));
        }
    }
}

#[test]
fn decision_invariant_under_irrelevant_levels() {
    // Asking for 1.0% vs 1.9% budget must select the same offline level
    // (a=1%) and thus the same pattern.
    let (arch, patterns) = setup();
    let cost = CostModel::paper_default();
    let d1 = serve_request(&arch, &patterns, &RequestParams { cost, accuracy_budget: 0.01 })
        .unwrap();
    let d2 = serve_request(&arch, &patterns, &RequestParams { cost, accuracy_budget: 0.019 })
        .unwrap();
    assert_eq!(d1.level_idx, d2.level_idx);
    assert_eq!(d1.pattern, d2.pattern);
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        let pick = if depth > 3 { rng.below(4) } else { rng.below(6) };
        match pick {
            0 => Value::Null,
            1 => Value::Bool(rng.uniform() < 0.5),
            2 => {
                // mix of integral / fractional / large
                match rng.below(3) {
                    0 => Value::Num(rng.below(1_000_000) as f64),
                    1 => Value::Num(rng.range_f64(-1e6, 1e6)),
                    _ => Value::Num(rng.range_f64(-1.0, 1.0) * 1e-9),
                }
            }
            3 => {
                let n = rng.range_usize(0, 12);
                let s: String = (0..n)
                    .map(|_| {
                        let chars = ['a', 'é', '"', '\\', '\n', '\t', '😀', ' ', '0', '}'];
                        *rng.choose(&chars)
                    })
                    .collect();
                Value::Str(s)
            }
            4 => {
                let n = rng.range_usize(0, 5);
                Value::Arr((0..n).map(|_| random_value(rng, depth + 1)).collect())
            }
            _ => {
                let n = rng.range_usize(0, 5);
                Value::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                        .collect(),
                )
            }
        }
    }
    check("json parse∘serialize = id", 150, |rng| {
        let v = random_value(rng, 0);
        let compact = v.to_string_compact();
        assert_eq!(parse(&compact).unwrap(), v, "compact: {compact}");
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v, "pretty");
    });
}

#[test]
fn server_workers_mirror_sim_server_slots() {
    // The live coordinator's executor pool and the simulator's parallel
    // server slots are the same knob; their defaults (and the layered
    // config system's default) must agree, or modeled and measured
    // serving would silently diverge.
    let fleet = FleetConfig::default();
    let server = ServerConfig::default();
    assert_eq!(server.workers, fleet.server_slots);
    let cfg = Config::defaults();
    let serving = cfg.serving().unwrap();
    assert_eq!(serving.workers, server.workers);
    // admission control must shed at the same depth from both entry points
    assert_eq!(serving.queue_capacity, server.queue_capacity);
    // dataplane knobs: the layered config and ServerConfig must agree on
    // defaults, or `--set serving.x=y` and the struct would diverge
    assert_eq!(serving.session_ttl_secs, server.session_ttl.as_secs());
    assert_eq!(serving.batch_window_us, server.batch_window.as_micros() as u64);
    assert_eq!(serving.cache_bytes, server.cache_bytes);
    assert_eq!(serving.binary_frames, server.binary_frames);
    assert_eq!(serving.warm, server.warm.as_str());
    // durable store defaults off from both entry points
    assert_eq!(serving.store_dir.is_empty(), server.store_dir.is_none());
}

#[test]
fn prop_decision_objective_is_minimum() {
    check("Alg2 picks the argmin over feasible partitions", 40, |rng| {
        let arch = qpart::core::model::mlp6();
        let calib = CalibrationTable::synthetic(&arch, &LEVELS, rng.next_u64());
        let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
        let mut cost = CostModel::paper_default();
        cost.channel = Channel::fixed(rng.range_f64(1e5, 1e9), rng.range_f64(0.1, 2.0));
        cost.device.clock_hz = rng.range_f64(5e7, 5e9);
        cost.server.price_per_s = rng.range_f64(0.0, 0.1);
        let budget = *rng.choose(&[0.0025, 0.005, 0.01, 0.02, 0.05, 0.2]);
        let d = serve_request(&arch, &patterns, &RequestParams { cost, accuracy_budget: budget })
            .unwrap();
        let min = d
            .objective_by_partition
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(d.cost.objective <= min * (1.0 + 1e-12) + 1e-18);
    });
}

#[test]
fn prop_solver_payload_monotone_in_budget() {
    check("looser budget never increases payload", 30, |rng| {
        let arch = qpart::core::model::edgecnn(10);
        let calib = CalibrationTable::synthetic(&arch, &LEVELS, rng.next_u64());
        let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
        let p = rng.range_usize(0, arch.num_layers() + 1);
        let mut prev = u64::MAX;
        for k in 0..LEVELS.len() {
            let pat = patterns.get(PatternKey { level_idx: k, partition: p }).unwrap();
            let z = pat.payload_bits(&arch);
            assert!(z <= prev, "k={k} p={p}");
            prev = z;
        }
    });
}
