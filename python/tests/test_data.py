"""Tests for the synthetic dataset generators."""

import numpy as np

from compile import data as D


def test_digits_shapes_and_ranges():
    x, y = D.synth_digits(64, seed=0)
    assert x.shape == (64, 784) and x.dtype == np.float32
    assert y.shape == (64,) and y.dtype == np.int32
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)) <= set(range(10))


def test_images_shapes_and_ranges():
    x, y = D.synth_images(32, classes=100, seed=0)
    assert x.shape == (32, 3, 32, 32) and x.dtype == np.float32
    assert y.min() >= 0 and y.max() < 100


def test_deterministic():
    a = D.synth_digits(16, seed=5)
    b = D.synth_digits(16, seed=5)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_seeds_differ():
    a, _ = D.synth_digits(16, seed=1)
    b, _ = D.synth_digits(16, seed=2)
    assert not np.array_equal(a, b)


def test_splits_share_prototypes():
    """Train/test splits must be the same classification task."""
    xa, ya = D.synth_digits(800, seed=1, proto_seed=9)
    xb, yb = D.synth_digits(800, seed=2, proto_seed=9)
    # class means should correlate strongly across splits
    for c in range(3):
        ma = xa[ya == c].mean(axis=0)
        mb = xb[yb == c].mean(axis=0)
        corr = np.corrcoef(ma, mb)[0, 1]
        assert corr > 0.6, f"class {c} corr {corr}"
    # ... and a different proto_seed must be a different task
    xc, yc = D.synth_digits(800, seed=1, proto_seed=10)
    m9 = xa[ya == 0].mean(axis=0)
    m10 = xc[yc == 0].mean(axis=0)
    assert np.corrcoef(m9, m10)[0, 1] < 0.6


def test_classes_are_distinct():
    x, y = D.synth_digits(400, seed=0)
    m0 = x[y == 0].mean(axis=0)
    m1 = x[y == 1].mean(axis=0)
    assert np.linalg.norm(m0 - m1) > 0.5


def test_make_registry():
    for name in D.DATASETS:
        x, y = D.make(name, 8, seed=0)
        assert x.shape[0] == 8
        assert y.max() < D.DATASETS[name]["classes"]


def test_datasets_differ_by_name():
    a, _ = D.make("svhn_syn", 8, seed=0)
    b, _ = D.make("cifar10_syn", 8, seed=0)
    assert not np.array_equal(a, b)
