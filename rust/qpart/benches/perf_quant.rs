//! **§Perf** — quantize + bit-pack hot loop.
//!
//! This is the per-request O(params) work on the serving path: quantizing
//! the device segment's weights to the pattern's bit-widths and packing
//! the codes for the wire. Target (DESIGN.md §8): ≥200 MB/s/core.
//!
//! Three kernel tiers are reported against each other on the same
//! machine, same buffers: the byte-at-a-time scalar reference
//! (`pack_bits_scalar` / `unpack_bits_scalar`), the PR 4 word-wise (u64
//! chunk) kernels, and the SIMD tier (`quant::simd`, labelled with the
//! detected instruction set — avx2/sse2/neon, or wordwise when the CPU
//! has none). Acceptance: word-wise pack/unpack ≥2× the scalar baseline
//! ("× scalar" column); on AVX2 hardware the SIMD rows should read
//! ≥1.5× the word-wise kernels ("× wordwise" column, soft-gated in CI's
//! perf-smoke job on AVX2 runners only).

mod common;

use common::*;
use qpart::core::quant::simd::{self, pack_bits_simd, quantize_packed_simd, unpack_bits_simd};
use qpart::core::quant::{
    pack_bits, pack_bits_scalar, pack_bits_wordwise, quantize, quantize_packed_wordwise,
    unpack_bits_scalar, unpack_bits_wordwise,
};
use qpart_bench::{black_box, fmt_ns, quick, Table};

fn main() {
    let setup = mlp6_setup();
    banner("perf — quantize / pack / unpack / dequantize", setup.calibrated);
    // layer-1 of mlp6: 784×512 weights (the biggest single buffer)
    let n = 784 * 512;
    let data: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.61803).sin()).collect();
    let mbytes = (n * 4) as f64 / 1e6;

    let simd_name = simd::detected().name();
    let mut table = Table::new(
        "hot-loop throughput (784×512 f32 weights)",
        &["op", "bits", "mean", "p99", "MB/s (f32 in)", "× scalar", "× wordwise"],
    );
    let no_ratio = || "-".to_string();
    for bits in [4u8, 8, 12] {
        let s = quick(|| {
            black_box(quantize(black_box(&data), bits).unwrap());
        });
        let quantize_mean = s.mean_ns;
        table.row(vec![
            "quantize".into(),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
            no_ratio(),
            no_ratio(),
        ]);

        let q = quantize(&data, bits).unwrap();
        let scalar_pack = quick(|| {
            black_box(pack_bits_scalar(black_box(&q.codes), bits).unwrap());
        });
        table.row(vec![
            "pack (scalar ref)".into(),
            bits.to_string(),
            fmt_ns(scalar_pack.mean_ns),
            fmt_ns(scalar_pack.p99_ns),
            format!("{:.0}", scalar_pack.per_second(mbytes)),
            "1.0".into(),
            no_ratio(),
        ]);
        let ww_pack = quick(|| {
            black_box(pack_bits_wordwise(black_box(&q.codes), bits).unwrap());
        });
        table.row(vec![
            "pack (word-wise)".into(),
            bits.to_string(),
            fmt_ns(ww_pack.mean_ns),
            fmt_ns(ww_pack.p99_ns),
            format!("{:.0}", ww_pack.per_second(mbytes)),
            format!("{:.2}", scalar_pack.mean_ns / ww_pack.mean_ns),
            "1.0".into(),
        ]);
        let s = quick(|| {
            black_box(pack_bits_simd(black_box(&q.codes), bits).unwrap());
        });
        table.row(vec![
            format!("pack (simd {simd_name})"),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
            format!("{:.2}", scalar_pack.mean_ns / s.mean_ns),
            format!("{:.2}", ww_pack.mean_ns / s.mean_ns),
        ]);

        let packed = pack_bits(&q.codes, bits).unwrap();
        let scalar_unpack = quick(|| {
            black_box(unpack_bits_scalar(black_box(&packed), n, bits).unwrap());
        });
        table.row(vec![
            "unpack (scalar ref)".into(),
            bits.to_string(),
            fmt_ns(scalar_unpack.mean_ns),
            fmt_ns(scalar_unpack.p99_ns),
            format!("{:.0}", scalar_unpack.per_second(mbytes)),
            "1.0".into(),
            no_ratio(),
        ]);
        let ww_unpack = quick(|| {
            black_box(unpack_bits_wordwise(black_box(&packed), n, bits).unwrap());
        });
        table.row(vec![
            "unpack (word-wise)".into(),
            bits.to_string(),
            fmt_ns(ww_unpack.mean_ns),
            fmt_ns(ww_unpack.p99_ns),
            format!("{:.0}", ww_unpack.per_second(mbytes)),
            format!("{:.2}", scalar_unpack.mean_ns / ww_unpack.mean_ns),
            "1.0".into(),
        ]);
        let s = quick(|| {
            black_box(unpack_bits_simd(black_box(&packed), n, bits).unwrap());
        });
        table.row(vec![
            format!("unpack (simd {simd_name})"),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
            format!("{:.2}", scalar_unpack.mean_ns / s.mean_ns),
            format!("{:.2}", ww_unpack.mean_ns / s.mean_ns),
        ]);

        // fused quantize→pack vs quantize-then-pack (the encode path);
        // the "× scalar" column compares against quantize + scalar pack
        let ww_fused = quick(|| {
            black_box(quantize_packed_wordwise(black_box(&data), bits).unwrap());
        });
        table.row(vec![
            "quantize+pack (fused)".into(),
            bits.to_string(),
            fmt_ns(ww_fused.mean_ns),
            fmt_ns(ww_fused.p99_ns),
            format!("{:.0}", ww_fused.per_second(mbytes)),
            format!("{:.2}", (quantize_mean + scalar_pack.mean_ns) / ww_fused.mean_ns),
            "1.0".into(),
        ]);
        let s = quick(|| {
            black_box(quantize_packed_simd(black_box(&data), bits).unwrap());
        });
        table.row(vec![
            format!("quantize+pack (simd {simd_name})"),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
            format!("{:.2}", (quantize_mean + scalar_pack.mean_ns) / s.mean_ns),
            format!("{:.2}", ww_fused.mean_ns / s.mean_ns),
        ]);

        let s = quick(|| {
            black_box(q.dequantize());
        });
        table.row(vec![
            "dequantize".into(),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
            no_ratio(),
            no_ratio(),
        ]);
    }
    table.print();

    // whole-segment quantization through the executor (bundle-backed):
    // the composed path and the fused packed path the coordinator serves
    if let Some(bundle) = setup.bundle.clone() {
        use qpart::prelude::*;
        use std::sync::Arc;
        let mut ex = Executor::new(Arc::clone(&bundle)).unwrap();
        let pat = setup
            .patterns
            .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: 6 })
            .unwrap()
            .clone();
        let total_mb = setup.arch.total_params() as f64 * 4.0 / 1e6;
        let s = quick(|| {
            black_box(ex.quantize_segment("mlp6", &pat).unwrap());
        });
        println!(
            "\nfull-segment quantize (mlp6, p=6, {:.1} MB of weights): mean {} → {:.0} MB/s",
            total_mb,
            fmt_ns(s.mean_ns),
            s.per_second(total_mb),
        );
        let s = quick(|| {
            black_box(ex.quantize_segment_packed("mlp6", &pat).unwrap());
        });
        println!(
            "full-segment fused quantize+pack (same weights): mean {} → {:.0} MB/s",
            fmt_ns(s.mean_ns),
            s.per_second(total_mb),
        );
    }
}
