//! **Table III** — Accuracy of Algorithms at Different Partition Points.
//!
//! Paper (MNIST mlp6): No-Optimization 96.19 % at every p; QPART within
//! ~0.1 % of it; Model Pruning ≈ 95.03 % (≈1.2 % below); Auto-Encoder
//! worst at small p (93.6 %), recovering at larger p (96 %+).
//!
//! This bench runs **real PJRT inference** over the held-out synthetic
//! test set: QPART through the quantized Pallas-kernel executables, the
//! baselines through their own paths. Requires `make artifacts`.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::Table;
use std::sync::Arc;

fn main() {
    let Some(bundle) = load_bundle() else {
        eprintln!("table3_accuracy requires artifacts/ — run `make artifacts`");
        return;
    };
    banner("Table III — measured accuracy at each partition point (mlp6)", true);
    let entry = bundle.model("mlp6").unwrap().clone();
    let arch = bundle.arch("mlp6").unwrap().clone();
    let calib = bundle.calibration("mlp6").unwrap();
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    let (x, y) = bundle.dataset(&entry.dataset).unwrap();
    let x = HostTensor::from(x);
    // cap eval set for runtime (same subset for all schemes)
    let n = std::env::var("QPART_TABLE3_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(512usize)
        .min(x.batch());
    let xs = x.slice_rows(0, n);
    let ys = &y[..n];
    let mut ex = Executor::new(Arc::clone(&bundle)).unwrap();

    // pruning ratio: largest in the ladder whose degradation at the deepest
    // partition stays within ~1.5% of baseline (the paper balances pruning
    // to match QPART's degradation).
    let base_acc = ex
        .eval_accuracy(&xs, ys, |e, c| Ok(e.run_full("mlp6", c)?))
        .unwrap();
    let deepest = arch.num_layers() - 1;
    let mut prune_ratio = 0.02;
    for &r in &[0.05, 0.1, 0.15, 0.2] {
        let acc = ex
            .eval_accuracy(&xs, ys, |e, c| {
                Ok(e.run_split_pruned("mlp6", deepest, r, c)?.logits)
            })
            .unwrap();
        if base_acc - acc <= 0.015 {
            prune_ratio = r;
        } else {
            break;
        }
    }
    println!("(pruning ratio balanced to ≈1% degradation: {prune_ratio})");

    let mut table = Table::new(
        format!("top-1 accuracy over {n} held-out samples"),
        &["p", "Auto-Encoder", "No Optimization", "Model Pruning", "QPART"],
    );
    for p in 0..arch.num_layers() {
        let qpat = patterns
            .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: p })
            .unwrap()
            .clone();
        let acc_q = ex
            .eval_accuracy(&xs, ys, |e, c| Ok(e.run_split("mlp6", &qpat, c)?.logits))
            .unwrap();
        let acc_no = ex
            .eval_accuracy(&xs, ys, |e, c| Ok(e.run_split_f32("mlp6", p, c)?.logits))
            .unwrap();
        let acc_pr = ex
            .eval_accuracy(&xs, ys, |e, c| {
                Ok(e.run_split_pruned("mlp6", p, prune_ratio, c)?.logits)
            })
            .unwrap();
        let acc_ae = if p == 0 {
            // no trained AE at the raw input — identical to no-optimization
            acc_no
        } else {
            ex.eval_accuracy(&xs, ys, |e, c| Ok(e.run_split_ae("mlp6", p, c)?.logits))
                .unwrap()
        };
        table.row(vec![
            p.to_string(),
            format!("{:.2}%", acc_ae * 100.0),
            format!("{:.2}%", acc_no * 100.0),
            format!("{:.2}%", acc_pr * 100.0),
            format!("{:.2}%", acc_q * 100.0),
        ]);
    }
    table.print();
    println!(
        "\npaper shapes: No-Opt constant ({}: {:.2}%); QPART within ~0.1–0.5% of No-Opt; \
         pruning ≈1% lower; AE weakest at small p. \
         paper row (MNIST): AE 93.6–96.3 / No-Opt 96.19 / Pruning 95.03 / QPART 96.1–96.2",
        entry.dataset,
        base_acc * 100.0
    );
}
