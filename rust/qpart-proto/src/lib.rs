//! # qpart-proto — the QPART wire protocol
//!
//! Wire protocol between edge devices and the QPART coordinator:
//! **newline-delimited JSON over TCP** (JSON-lines), plus an optional
//! **length-prefixed binary frame** for large segment payloads. This crate
//! is the protocol's single source of truth; `cargo doc -p qpart-proto`
//! renders this page as the protocol specification.
//!
//! ## Frame layout
//!
//! Two frame kinds share one TCP stream and are self-distinguishing on
//! their first byte.
//!
//! **JSON frame** (the default; every peer must speak it):
//!
//! ```text
//! <UTF-8 JSON document, no embedded '\n'> '\n'
//! ```
//!
//! * Read with [`read_frame`] (or [`read_any_frame`]) / written with
//!   [`write_frame`].
//! * A trailing `'\r'` before the `'\n'` is tolerated and stripped.
//! * Frames larger than [`MAX_FRAME_BYTES`] (16 MiB) are rejected with
//!   `FrameError::TooLarge` — a full quantized mlp6 segment is well under
//!   1 MiB; the cap only guards against malformed or hostile peers.
//! * Non-UTF-8 frames are rejected (`FrameError::Utf8`).
//!
//! Every document is a JSON object whose `"type"` field tags the variant.
//! Unknown types are answered with an `error` response, not a dropped
//! connection.
//!
//! **Binary frame** (negotiated; symmetric — carries `segment` replies
//! downlink and `activation` requests uplink):
//!
//! ```text
//! 0xB1                         magic byte ([`frame::BINARY_MAGIC`]; a
//!                              UTF-8 continuation byte, so it can never
//!                              open a JSON frame)
//! u32 LE  total_len            length of everything that follows
//! u32 LE  header_len           length of the JSON header
//! header_len bytes             UTF-8 JSON header: the `segment` or
//!                              `activation` document with blob offsets
//!                              instead of base64 (`type` dispatches)
//! total_len - 4 - header_len   raw blob the header's offsets point into
//! ```
//!
//! In a binary **`segment`** header each layer replaces
//! `w_packed`/`b_packed` (base64) with `w_off`/`w_nbytes` and
//! `b_off`/`b_nbytes` — byte ranges into the blob, which holds each
//! layer's bit-packed weight bytes then bias bytes in layer order. In a
//! binary **`activation`** header (the request-frame layout) the `packed`
//! field is replaced by `packed_off`/`packed_nbytes` and the blob is the
//! bit-packed boundary-activation codes; all other fields (`session`,
//! `bits`, `qmin`, `step`, `dims`) are unchanged from the JSON form.
//! Either direction thus ships its multi-kilobyte-to-megabyte payload
//! without base64 expansion (−25% bytes) or JSON string escaping/parsing
//! on either side. Read with [`read_any_frame`], written with
//! [`frame::write_binary_frame`]; decode via
//! [`messages::Response::from_frame`] /
//! [`messages::InferReply::from_binary`] downlink and
//! [`messages::Request::from_frame`] /
//! [`messages::ActivationUpload::from_binary`] uplink. The same
//! [`MAX_FRAME_BYTES`] cap applies to the whole envelope.
//!
//! ### Negotiation rules
//!
//! * Connections start in JSON-lines mode; requests before a granted
//!   `hello` are always JSON.
//! * A device that wants binary frames sends
//!   `{"type":"hello","binary_frames":true}`. The server answers
//!   `{"type":"hello","binary_frames":<granted>}` (always as a JSON
//!   frame) — `true` only if the request asked for it **and** the server
//!   allows it (`--binary-frames`, `ServerConfig::binary_frames`).
//! * A granted hello is **symmetric**: `segment` replies on that
//!   connection use binary frames, and the device **may** send its
//!   `activation` uploads as binary request frames (JSON uploads remain
//!   valid — the framings are self-distinguishing per frame). Every
//!   other message stays JSON-lines. A later `hello` with
//!   `binary_frames:false` switches both directions back.
//! * A binary request frame on a connection that never negotiated is
//!   answered with a `bad_frame` error (the server must not silently
//!   accept what it did not grant).
//! * Peers that never send `hello` get pure JSON-lines — the
//!   compatibility fallback.
//!
//! ### Trace negotiation
//!
//! `hello` also negotiates **request tracing** (span timelines across the
//! coordinator pipeline, see `qpart-coordinator`'s `obs` module):
//!
//! * A device that wants its requests traced sends
//!   `{"type":"hello","binary_frames":…,"trace":true}`. The `trace`
//!   field is serialized **only when true** — an untraced hello is
//!   byte-identical to the pre-trace protocol.
//! * The server answers with `"trace":<id>` (a positive integer) when it
//!   grants tracing, and **omits the field** when it does not (tracing
//!   disabled or unsupported). The granted id names this connection's
//!   timeline at the metrics listener's `/trace?id=<id>` endpoint.
//! * On a connection with a granted trace, `segment` and `result`
//!   replies carry the same id in a `"trace"` field placed immediately
//!   after `"session"` (both JSON and binary-header forms). Replies on
//!   untraced connections never carry the field.
//!
//! **Compatibility rules:** an absent `trace` field is equivalent to
//! talking to an old peer — requests without it are never echoed a trace
//! id, responses without it mean tracing was not granted, and decoders
//! must treat the field as optional everywhere it may appear (`hello`
//! both ways, `segment`, `result`). Server-side sampling
//! (`--trace-sample`) records timelines without echoing ids, so it never
//! changes wire bytes; only an explicit `hello` grant does, and then only
//! on that connection.
//!
//! ### Transport independence
//!
//! Framing and negotiation are defined **per connection over its byte
//! stream** and are independent of how the server carries connections:
//! the coordinator's evented front-end (one poll-based reactor over
//! nonblocking sockets, incremental parsing via [`frame::split_frame`])
//! and its thread-per-connection baseline (blocking reads via
//! [`read_any_frame`]) produce byte-identical frames in both directions.
//! `split_frame` is specified to match the blocking readers exactly —
//! same first-byte dispatch, same [`MAX_FRAME_BYTES`] cap, same error
//! taxonomy — so no wire behavior changed with the front-end.
//!
//! ## Binary payloads (JSON form)
//!
//! Bit-packed tensors (quantized weight/activation codes, see
//! `qpart_core::quant::pack_bits`) travel as **base64** strings (standard
//! alphabet, padded — [`base64::encode`]). A quantized tensor on the wire
//! is the triple of its grid header and packed codes:
//!
//! * `bits` — bit-width `b` (codes are `b`-bit grid indices, LSB-first
//!   packed into bytes),
//! * `qmin`, `step` — the uniform grid `value = qmin + code·step`,
//! * the base64 of the packed bytes (`ceil(n·b/8)` bytes for `n` codes).
//!
//! Raw f32 tensors (the `simulate` input) are base64 of their
//! little-endian bytes ([`messages::f32s_to_b64`]).
//!
//! ## Requests ([`messages::Request`])
//!
//! | `"type"`      | fields | meaning |
//! |---------------|--------|---------|
//! | `ping`        | — | liveness probe; answered with `pong` |
//! | `list_models` | — | enumerate served models; answered with `models` |
//! | `stats`       | — | metrics snapshot; answered with `stats` |
//! | `hello`       | `binary_frames`, optional `trace` | negotiate framing + tracing; answered with `hello` |
//! | `infer`       | [`messages::InferRequest`] fields | **phase 1**: open a session, answered with `segment` |
//! | `activation`  | `session`, `bits`, `qmin`, `step`, `dims`, `packed` | **phase 2**: upload the quantized boundary activation (JSON, or a binary request frame after a granted `hello`), answered with `result` |
//! | `simulate`    | `infer` fields + `input`, `input_dims` | one-shot: the server simulates the device too; answered with `result` |
//!
//! The `infer` request carries exactly the tuple of paper Algorithm 2's
//! Require line: model id, accuracy budget `a` (`accuracy_budget`),
//! channel capacity `r` (`channel_capacity_bps`), transmit power `π`
//! (`tx_power_w`), and the device compute profile: `f_local` (`clock_hz`),
//! `γ_local` (`cycles_per_mac`), `κ` (`kappa`), plus the device memory
//! capacity in bits (`memory_bits`) and optional objective weights
//! `[ω, τ, η]` (`weights`).
//!
//! Example (`infer`):
//!
//! ```json
//! {"type":"infer","model":"mlp6","accuracy_budget":0.01,
//!  "channel_capacity_bps":2e8,"tx_power_w":1.0,"clock_hz":2e8,
//!  "cycles_per_mac":5.0,"kappa":3e-27,"memory_bits":2147483648}
//! ```
//!
//! ## Responses ([`messages::Response`])
//!
//! | `"type"`  | fields | meaning |
//! |-----------|--------|---------|
//! | `pong`    | — | answer to `ping` |
//! | `models`  | `models`: array of `{name, arch, dataset, layers, params, test_accuracy}` | answer to `list_models` |
//! | `stats`   | `stats`: metrics document (aggregated over the executor pool, with a per-worker `workers` array, queue-wait and batching counters, and the encoded-reply `segment_cache` section) | answer to `stats` |
//! | `hello`   | `binary_frames`, optional `trace` id | answer to `hello`: the granted framing (and trace id, when granted) |
//! | `segment` | `session`, optional `trace`, `model`, `pattern`, `layers` | **phase-1 answer**: the quantized, bit-packed model segment (JSON or binary frame per negotiation) |
//! | `result`  | `session`, optional `trace`, `prediction`, `logits`, `server_us`, optional `costs` | **phase-2 / simulate answer** |
//! | `error`   | `code`, `message` | any failure |
//!
//! In a `segment` response, `pattern` reports the chosen quantization
//! pattern (`partition`, per-layer `weight_bits`, `activation_bits`, the
//! offline `accuracy_level`, `predicted_degradation`, and the Eq. 17
//! `objective`), and `layers` is an array of [`messages::LayerBlob`]s —
//! per device-side layer: `layer` (1-based index), `bits`, `w_dims`,
//! weight grid (`w_qmin`, `w_step`) + base64 `w_packed`, and bias grid
//! (`b_qmin`, `b_step`, `b_len`) + base64 `b_packed`. In the **binary**
//! framing the same document is the frame header with
//! `w_off`/`w_nbytes`/`b_off`/`b_nbytes` blob ranges replacing the base64
//! fields.
//!
//! Because coalesced and cached replies share one serialized body
//! ([`messages::EncodedSegmentBody`]), only `session` and
//! `pattern.objective` vary between devices that were answered from the
//! same `(model, accuracy level, partition)` encode.
//!
//! Error `code`s the coordinator emits: `bad_frame`, `bad_request`,
//! `unknown_model`, `unknown_session`, `bad_activation`, `bad_input`,
//! `infeasible` (accuracy budget unreachable), `overloaded` (admission
//! control shed), `internal`, `shutdown`.
//!
//! ## Two-phase serving flow
//!
//! Mirroring Fig. 1/2 of the paper:
//!
//! 1. device → `infer` (model, accuracy budget, channel + compute profile)
//! 2. server → `segment` (the quantized, bit-packed model segment + the
//!    chosen pattern) — the downlink the paper's Eq. 14 charges for
//! 3. device runs layers `1..=p` locally, → `activation` (quantized,
//!    bit-packed boundary activation) — the uplink
//! 4. server finishes layers `p+1..=L`, → `result` (prediction + logits)
//!
//! `simulate` collapses 1–4 into one exchange for load generation: the
//! server plays both roles and reports the Eq. 17 cost breakdown in
//! `costs`.
//!
//! Sessions are server-side state keyed by the `session` id returned in
//! `segment`; they are consumed by the first `activation` referencing
//! them, evicted oldest-first under capacity pressure, and expired by the
//! TTL sweep if the device never uploads (both answer `unknown_session`).

pub mod base64;
pub mod frame;
pub mod messages;

pub use frame::{
    read_any_frame, read_frame, split_frame, write_binary_frame, write_frame, BinaryFrame, Frame,
    FrameError, MAX_FRAME_BYTES,
};
pub use messages::{
    ActivationUpload, EncodedSegmentBody, ErrorReply, HelloReply, HelloRequest, InferReply,
    InferRequest, LayerBlob, PatternInfo, Request, Response, SegmentBlob, JSON_FRAME_TAIL,
};
