"""AOT build orchestrator: data -> train -> calibrate -> lower -> artifacts/.

Run once via `make artifacts` (idempotent; skipped when up to date):

    cd python && python -m compile.aot --out ../artifacts

Produces everything the Rust stack needs at runtime (DESIGN.md §7):

    artifacts/
      manifest.json            archs, model instances, executables, datasets
      calibration/<model>.json s_l / rho_l(a) tables (calibrate.py)
      weights/<model>/*.qt     trained parameters
      ae/<model>/*.qt          autoencoder-baseline parameters
      hlo/<arch>/*.hlo.txt     per-layer + full-model executables
      data/<dataset>_*.qt      held-out test batches for Rust-side eval

Python never runs on the request path; the Rust binary is self-contained
once this completes.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax.numpy as jnp

from . import calibrate as C
from . import data as D
from . import model as M
from . import qt
from . import train as T
from .hlo import lower_to_hlo_text, spec
from .kernels import qconv, qlinear, ref

BATCHES = (1, 32)
EVAL_BATCH = 32
AE_RATIO = 4  # DeepCOD-style bottleneck = d / 4 (d/8 underfits: <60% acc)

# model instances: name -> (arch ctor, dataset, train size, epochs, cal size)
INSTANCES = {
    "mlp6": ("mlp6", "digits", 4000, 4, 768),
    "edgecnn_svhn": ("edgecnn10", "svhn_syn", 3000, 5, 320),
    "edgecnn_cifar10": ("edgecnn10", "cifar10_syn", 2000, 4, 320),
    "edgecnn_cifar100": ("edgecnn100", "cifar100_syn", 4000, 6, 320),
    "tinyresnet": ("tinyresnet", "imagenet_syn", 2500, 5, 256),
}
TEST_N = {"digits": 1000, "svhn_syn": 400, "cifar10_syn": 400,
          "cifar100_syn": 400, "imagenet_syn": 400}
# autoencoder baseline: only for the paper's Table III model
AE_MODELS = ("mlp6",)
AE_BOUNDARIES = (1, 2, 3, 4, 5)


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def _act_shape(arch_spec, l, batch):
    """Activation shape at boundary l (0..L) with a leading batch dim."""
    if l == 0:
        return (batch, *arch_spec["input_shape"])
    layer = arch_spec["layers"][l - 1]
    if layer["kind"] == "linear":
        return (batch, layer["d_out"])
    return (batch, layer["c_out"], layer["out_side"], layer["out_side"])


def _layer_in_shape(arch_spec, l, batch):
    """Input shape layer l expects (flattened for linear after conv)."""
    layer = arch_spec["layers"][l - 1]
    if layer["kind"] == "linear":
        return (batch, layer["d_in"])
    return (batch, layer["c_in"], layer["in_side"], layer["in_side"])


def _wshape(layer):
    if layer["kind"] == "linear":
        return (layer["d_in"], layer["d_out"])
    return (layer["c_in"], layer["k"], layer["k"], layer["c_out"])


def _flat_wshape(layer):
    if layer["kind"] == "linear":
        return (layer["d_in"], layer["d_out"])
    return (layer["c_in"] * layer["k"] ** 2, layer["c_out"])


def _gdim(layer):
    return layer["d_out"] if layer["kind"] == "linear" else layer["c_out"]


def lower_qlayer(arch_spec, l, batch):
    """Quantized layer executable: (x[, skip], codes, qmin, step, bias) -> y."""
    layer = arch_spec["layers"][l - 1]
    has_skip = l in arch_spec["residual"]
    relu = layer["relu"]

    if layer["kind"] == "linear":
        def fn(x, codes, qmin, step, bias):
            return (qlinear(x, codes, qmin, step, bias, relu=relu),)

        def fn_skip(x, skip, codes, qmin, step, bias):
            return (qlinear(x, codes, qmin, step, bias, relu=relu) + skip,)
    else:
        k, stride = layer["k"], layer["stride"]

        def fn(x, codes, qmin, step, bias):
            return (qconv(x, codes, qmin, step, bias, relu, k, stride),)

        def fn_skip(x, skip, codes, qmin, step, bias):
            return (qconv(x, codes, qmin, step, bias, relu, k, stride) + skip,)

    args = [spec(_layer_in_shape(arch_spec, l, batch))]
    if has_skip:
        args.append(spec(_act_shape(arch_spec, l, batch)))
    args += [spec(_flat_wshape(layer)), spec((1, 1)), spec((1, 1)), spec((1, _gdim(layer)))]
    return lower_to_hlo_text(fn_skip if has_skip else fn, *args), has_skip


def lower_f32layer(arch_spec, l, batch):
    """Full-precision layer executable: (x[, skip], w, bias) -> y."""
    layer = arch_spec["layers"][l - 1]
    has_skip = l in arch_spec["residual"]
    relu = layer["relu"]

    if layer["kind"] == "linear":
        def fn(x, w, bias):
            return (ref.linear_ref(x, w, bias, relu),)

        def fn_skip(x, skip, w, bias):
            return (ref.linear_ref(x, w, bias, relu) + skip,)
    else:
        stride = layer["stride"]

        def fn(x, w, bias):
            return (ref.conv_ref(x, w, bias, relu, stride),)

        def fn_skip(x, skip, w, bias):
            return (ref.conv_ref(x, w, bias, relu, stride) + skip,)

    args = [spec(_layer_in_shape(arch_spec, l, batch))]
    if has_skip:
        args.append(spec(_act_shape(arch_spec, l, batch)))
    args += [spec(_wshape(layer)), spec((1, _gdim(layer)))]
    return lower_to_hlo_text(fn_skip if has_skip else fn, *args), has_skip


def lower_full(arch_spec, batch):
    """Whole-model executable: (x, w1, b1, ..., wL, bL) -> logits."""
    layers = arch_spec["layers"]

    def fn(x, *flat):
        params = [dict(w=flat[2 * i], b=flat[2 * i + 1][0]) for i in range(len(layers))]
        return (M.forward(arch_spec, params, x),)

    args = [spec(_act_shape(arch_spec, 0, batch))]
    for layer in layers:
        args.append(spec(_wshape(layer)))
        args.append(spec((1, _gdim(layer))))
    return lower_to_hlo_text(fn, *args)


def lower_ae(d_in, bottleneck, batch):
    """Autoencoder enc/dec executables (linear, no activation)."""
    def enc(h, we, be):
        return (h @ we + be,)

    def dec(z, wd, bd):
        return (z @ wd + bd,)

    enc_txt = lower_to_hlo_text(
        enc, spec((batch, d_in)), spec((d_in, bottleneck)), spec((1, bottleneck)))
    dec_txt = lower_to_hlo_text(
        dec, spec((batch, bottleneck)), spec((bottleneck, d_in)), spec((1, d_in)))
    return enc_txt, dec_txt


# ---------------------------------------------------------------------------
# build steps
# ---------------------------------------------------------------------------

def _arch_to_manifest(arch_spec):
    """Arch spec -> the JSON shape qpart_core::model::ModelSpec expects."""
    layers = []
    for layer in arch_spec["layers"]:
        e = dict(name=layer["name"], kind=layer["kind"], relu=layer["relu"])
        if layer["kind"] == "linear":
            e.update(d_in=layer["d_in"], d_out=layer["d_out"])
        else:
            e.update(c_in=layer["c_in"], c_out=layer["c_out"], k=layer["k"],
                     stride=layer["stride"], in_side=layer["in_side"],
                     out_side=layer["out_side"])
        layers.append(e)
    return dict(
        name=arch_spec["name"],
        num_classes=arch_spec["num_classes"],
        layers=layers,
        partition_points=arch_spec["partition_points"],
        input_shape=list(arch_spec["input_shape"]),
        residual={str(k): v for k, v in arch_spec["residual"].items()},
    )


def build(out_dir, fast=False, only=None, log=print):
    t_start = time.time()
    os.makedirs(out_dir, exist_ok=True)
    for sub in ("calibration", "weights", "ae", "hlo", "data"):
        os.makedirs(os.path.join(out_dir, sub), exist_ok=True)

    instances = {k: v for k, v in INSTANCES.items() if only is None or k in only}
    archs = {}
    models_json = []
    datasets_json = []
    execs_json = []
    done_datasets = set()
    levels = list(C.DEFAULT_LEVELS)

    for name, (arch_name, dataset, n_train, epochs, n_cal) in instances.items():
        if fast:
            n_train, epochs, n_cal = max(600, n_train // 6), 2, 160
        arch_spec = M.SPECS[arch_name]()
        archs[arch_name] = arch_spec
        log(f"[{name}] dataset={dataset} train={n_train} epochs={epochs}")

        # --- data
        x_tr, y_tr = D.make(dataset, n_train, seed=0)
        n_test = TEST_N[dataset] if not fast else 200
        x_te, y_te = D.make(dataset, n_test, seed=1)
        x_cal, y_cal = D.make(dataset, n_cal, seed=2)
        if dataset not in done_datasets:
            qt.save(os.path.join(out_dir, "data", f"{dataset}_test_x.qt"), x_te)
            qt.save(os.path.join(out_dir, "data", f"{dataset}_test_y.qt"), y_te)
            datasets_json.append(dict(
                name=dataset,
                x=f"data/{dataset}_test_x.qt",
                y=f"data/{dataset}_test_y.qt",
                n=int(n_test),
                classes=int(D.DATASETS[dataset]["classes"]),
            ))
            done_datasets.add(dataset)

        # --- train
        t0 = time.time()
        params, history = T.train(arch_spec, x_tr, y_tr, epochs=epochs,
                                  log=lambda s: log(f"  {s}"))
        acc = M.accuracy(arch_spec, params, x_te, y_te)
        log(f"  trained in {time.time()-t0:.1f}s, test acc {acc:.4f}")

        # --- weights
        wdir = os.path.join(out_dir, "weights", name)
        os.makedirs(wdir, exist_ok=True)
        for i, p in enumerate(params, start=1):
            qt.save(os.path.join(wdir, f"l{i}_w.qt"), np.asarray(p["w"]))
            qt.save(os.path.join(wdir, f"l{i}_b.qt"), np.asarray(p["b"]))

        # --- calibration
        t0 = time.time()
        cal = C.calibrate(arch_spec, params, x_cal, y_cal, levels=levels,
                          seed=7, log=(lambda s: log(f"  {s}")) if not fast else None)
        cal_path = f"calibration/{name}.json"
        with open(os.path.join(out_dir, cal_path), "w") as f:
            json.dump(cal, f, indent=1)
        log(f"  calibrated in {time.time()-t0:.1f}s")

        # --- autoencoder baseline (mlp6 only)
        ae_info = None
        if name in AE_MODELS:
            ae_dir = os.path.join(out_dir, "ae", name)
            os.makedirs(ae_dir, exist_ok=True)
            boundaries = []
            h_src = x_tr[:2000]
            for b in AE_BOUNDARIES:
                h = np.asarray(M.forward(arch_spec, params, jnp.asarray(h_src), upto=b))
                bott = max(h.shape[1] // AE_RATIO, 8)
                ae_params, losses = T.train_autoencoder(
                    h, bott, epochs=150 if fast else 400, lr=1e-2, seed=b)
                for key in ("we", "be", "wd", "bd"):
                    qt.save(os.path.join(ae_dir, f"p{b}_{key}.qt"),
                            np.asarray(ae_params[key]))
                boundaries.append(dict(boundary=b, bottleneck=int(bott),
                                       recon_mse=float(losses[-1])))
                log(f"  ae boundary {b}: bottleneck {bott}, mse {losses[-1]:.5f}")
            ae_info = dict(dir=f"ae/{name}", boundaries=boundaries)

        models_json.append(dict(
            name=name,
            arch=arch_name,
            dataset=dataset,
            weights_dir=f"weights/{name}",
            calibration=cal_path,
            test_accuracy=float(acc),
            loss_history=[float(h) for h in history],
            ae=ae_info,
        ))

    # --- lower executables (one set per arch; weights are runtime inputs)
    for arch_name, arch_spec in archs.items():
        hdir = os.path.join(out_dir, "hlo", arch_name)
        os.makedirs(hdir, exist_ok=True)
        n_layers = len(arch_spec["layers"])
        t0 = time.time()
        for batch in BATCHES:
            for l in range(1, n_layers + 1):
                text, has_skip = lower_qlayer(arch_spec, l, batch)
                path = f"hlo/{arch_name}/q_l{l}_b{batch}.hlo.txt"
                with open(os.path.join(out_dir, path), "w") as f:
                    f.write(text)
                execs_json.append(dict(name=f"q_{arch_name}_l{l}_b{batch}", hlo=path,
                                       arch=arch_name, kind="qlayer", layer=l,
                                       batch=batch, has_skip=has_skip))
                text, has_skip = lower_f32layer(arch_spec, l, batch)
                path = f"hlo/{arch_name}/f32_l{l}_b{batch}.hlo.txt"
                with open(os.path.join(out_dir, path), "w") as f:
                    f.write(text)
                execs_json.append(dict(name=f"f32_{arch_name}_l{l}_b{batch}", hlo=path,
                                       arch=arch_name, kind="f32layer", layer=l,
                                       batch=batch, has_skip=has_skip))
        text = lower_full(arch_spec, EVAL_BATCH)
        path = f"hlo/{arch_name}/full_b{EVAL_BATCH}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        execs_json.append(dict(name=f"full_{arch_name}_b{EVAL_BATCH}", hlo=path,
                               arch=arch_name, kind="full", batch=EVAL_BATCH,
                               has_skip=False))
        log(f"[{arch_name}] lowered {2 * 2 * n_layers + 1} executables "
            f"in {time.time()-t0:.1f}s")

    # AE executables (arch-level: depend only on boundary dims)
    for m in models_json:
        if not m["ae"]:
            continue
        arch_spec = archs[m["arch"]]
        hdir = os.path.join(out_dir, "hlo", m["arch"])
        for info in m["ae"]["boundaries"]:
            b, bott = info["boundary"], info["bottleneck"]
            d_in = int(np.prod(_act_shape(arch_spec, b, 1)[1:]))
            for batch in BATCHES:
                enc_txt, dec_txt = lower_ae(d_in, bott, batch)
                for kind, text in (("ae_enc", enc_txt), ("ae_dec", dec_txt)):
                    path = f"hlo/{m['arch']}/{kind}_p{b}_b{batch}.hlo.txt"
                    with open(os.path.join(out_dir, path), "w") as f:
                        f.write(text)
                    execs_json.append(dict(
                        name=f"{kind}_{m['arch']}_p{b}_b{batch}", hlo=path,
                        arch=m["arch"], kind=kind, boundary=b, batch=batch,
                        bottleneck=bott, has_skip=False))

    manifest = dict(
        version=1,
        generated_unix=int(time.time()),
        fast=bool(fast),
        archs=[_arch_to_manifest(a) for a in archs.values()],
        models=models_json,
        executables=execs_json,
        datasets=datasets_json,
        levels=levels,
    )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"artifacts complete in {time.time()-t_start:.1f}s -> {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="small train/calibration (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="comma-separated instance names (default: all)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    build(args.out, fast=args.fast, only=only)


if __name__ == "__main__":
    main()
