//! **§Perf** — PJRT runtime dispatch and split-inference latency.
//!
//! Measures: single-layer executable dispatch (b1), the full quantized
//! device segment, the server segment, a whole b1 split inference, and
//! b32 full-model throughput. Requires `make artifacts`.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::{black_box, fmt_ns, quick, Table};
use std::sync::Arc;

fn main() {
    let Some(bundle) = load_bundle() else {
        eprintln!("perf_runtime requires artifacts/ — run `make artifacts`");
        return;
    };
    banner("perf — PJRT dispatch + split inference (mlp6)", true);
    let arch = bundle.arch("mlp6").unwrap().clone();
    let calib = bundle.calibration("mlp6").unwrap();
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    let mut ex = Executor::new(Arc::clone(&bundle)).unwrap();
    let (x, _) = bundle.dataset("digits").unwrap();
    let x = HostTensor::from(x);
    let x1 = x.slice_rows_padded(0, 1, 1);
    let x32 = x.slice_rows_padded(0, 32, 32);

    let pat = patterns
        .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: 3 })
        .unwrap()
        .clone();
    let seg = ex.quantize_segment("mlp6", &pat).unwrap();
    let weights = ex.weights("mlp6").unwrap();

    let mut table = Table::new("latency (batch 1 unless noted)", &["path", "mean", "p99"]);

    // warm the executable cache first (compile once)
    let _ = ex.run_split("mlp6", &pat, x1.clone()).unwrap();
    let _ = ex.run_full("mlp6", x32.clone()).unwrap();

    let prep = ex.prepared_segment("mlp6", &pat).unwrap();
    let s = quick(|| {
        black_box(ex.run_device_segment_prepared(&arch, &prep, x1.clone()).unwrap());
    });
    table.row(vec![
        "device segment (prepared, p=3)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
    ]);

    let s = quick(|| {
        black_box(ex.run_device_segment(&arch, &seg, x1.clone()).unwrap());
    });
    table.row(vec![
        "device segment (wire blobs, p=3)".into(),
        fmt_ns(s.mean_ns),
        fmt_ns(s.p99_ns),
    ]);

    let boundary = ex.run_device_segment(&arch, &seg, x1.clone()).unwrap();
    let s = quick(|| {
        black_box(
            ex.run_server_segment(&arch, &weights, boundary.clone(), 3).unwrap(),
        );
    });
    table.row(vec!["server segment (f32, p=3)".into(), fmt_ns(s.mean_ns), fmt_ns(s.p99_ns)]);

    let s = quick(|| {
        black_box(ex.run_split("mlp6", &pat, x1.clone()).unwrap());
    });
    table.row(vec!["whole split (quantize+run)".into(), fmt_ns(s.mean_ns), fmt_ns(s.p99_ns)]);
    let split_mean = s.mean_ns;

    let s = quick(|| {
        black_box(ex.run_full("mlp6", x32.clone()).unwrap());
    });
    table.row(vec!["full model (b32)".into(), fmt_ns(s.mean_ns), fmt_ns(s.p99_ns)]);
    println!(
        "b32 full-model throughput: {:.0} samples/s",
        32.0 / (s.mean_ns / 1e9)
    );
    table.print();
    println!(
        "\nsingle-request split latency {:.2} ms → {:.0} req/s on one PJRT device",
        split_mean / 1e6,
        1e9 / split_mean
    );
}
