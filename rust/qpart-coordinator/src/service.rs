//! The coordinator's request brain.
//!
//! At startup: take the shared bundle (one `Arc<Bundle>` across the whole
//! pool), run paper **Algorithm 1** per model (the calibration tables are
//! already in the artifacts, so this is just the closed-form solves —
//! microseconds per pattern) and cache the pattern sets. Per request: run
//! **Algorithm 2** under the request's live channel/compute parameters,
//! fetch or build the encoded segment reply for the decided
//! `(model, accuracy level, partition)` key, open a session, and execute
//! the server-side segment when the boundary activation comes back.
//!
//! The batch path ([`Service::handle_batch`]) is what pool workers drive:
//! a drained batch's `infer` requests are planned individually (decisions
//! depend on per-request channel/compute state) and then **grouped by
//! coalescing key** — one encode per group fans out to every waiting
//! connection via a shared [`EncodedSegmentBody`]. The batch's
//! `activation` uploads take the mirrored phase-2 path: decoded uploads
//! group by `(model, partition)` and row-stack into batched
//! server-segment executions of up to `EVAL_BATCH` rows
//! ([`Service::handle_batch`] → `handle_activation_batch`), so N
//! concurrent same-key uploads cost ⌈N/EVAL_BATCH⌉ executions, not N.
//! The single-request path funnels through the same executor entry, so
//! batched and sequential phase 2 are numerically identical.

use crate::brownout::{degrade_level, BrownoutController};
use crate::decision::{DecisionCache, DecisionKey, ProfileBucket};
use crate::metrics::{ClassCounts, Metrics, MetricsHub};
use crate::obs::{JobTrace, Stage, TraceStamp, Tracer};
use crate::sched::{EncodedReplyCache, Job, ReplySink, SegmentKey, SegmentReply, WireReply};
use crate::session::{Session, SharedSessionTable};
use crate::store::{keys as store_keys, Column, StoreTier};
use qpart_core::channel::Channel;
use qpart_core::cost::{CostModel, DeviceProfile, ServerProfile, TradeoffWeights};
use qpart_core::model::{LayerKind, ModelSpec};
use qpart_core::optimizer::{
    offline_quantize, serve_request_fast, Decision, OfflineConfig, RequestParams,
};
use qpart_core::quant::{unpack_bits, PatternSet, QuantParams, QuantPattern, Quantized};
use qpart_proto::messages::{
    ActivationUpload, EncodedSegmentBody, ErrorReply, HelloReply, InferRequest, LayerBlob,
    ModelInfo, PatternInfo, Request, Response, ResultReply, SegmentBlob, SimulateRequest,
};
use qpart_core::rng::Rng;
use qpart_runtime::{Bundle, CompileCache, Executor, HostTensor, EVAL_BATCH};
use std::sync::Arc;
use std::time::Instant;

/// Server-side fault injection (`--fault-inject`, env-gated behind
/// `QPART_FAULT_INJECT=1` in the CLI): testing-only failure modes
/// compiled in but default-off, used by the chaos/soak harness to prove
/// the supervision and brownout machinery. A default (`is_noop`) spec is
/// exactly the production path — the service drops it at construction.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultSpec {
    /// Probability (0..=1) that handling an infer request panics the
    /// worker thread (exercises `catch_unwind` + supervisor respawn).
    pub worker_panic: f64,
    /// Artificial delay per drained batch, milliseconds (drives queue
    /// waits up so brownout demonstrably enters under load).
    pub exec_delay_ms: u64,
    /// Probability (0..=1) that an infer request fails with an injected
    /// `internal` error before planning (exercises soft-failure paths).
    pub alloc_fail: f64,
}

impl FaultSpec {
    /// Parse the CLI form `worker-panic=P,exec-delay-ms=D,alloc-fail=P`
    /// (any subset of keys, in any order).
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("fault-inject: `{part}` is not key=value"))?;
            match key.trim() {
                "worker-panic" => {
                    spec.worker_panic = parse_prob(val)?;
                }
                "alloc-fail" => {
                    spec.alloc_fail = parse_prob(val)?;
                }
                "exec-delay-ms" => {
                    spec.exec_delay_ms = val
                        .trim()
                        .parse::<u64>()
                        .map_err(|_| format!("fault-inject: bad delay `{val}`"))?;
                }
                other => return Err(format!("fault-inject: unknown key `{other}`")),
            }
        }
        Ok(spec)
    }

    /// Whether this spec injects nothing (the production path).
    pub fn is_noop(&self) -> bool {
        *self == FaultSpec::default()
    }
}

fn parse_prob(val: &str) -> Result<f64, String> {
    let p = val
        .trim()
        .parse::<f64>()
        .map_err(|_| format!("fault-inject: bad probability `{val}`"))?;
    if !(0.0..=1.0).contains(&p) || !p.is_finite() {
        return Err(format!("fault-inject: probability {p} outside 0..=1"));
    }
    Ok(p)
}

/// Options wiring a worker's service into the pool-shared execution
/// plane.
#[derive(Clone)]
pub struct ServiceOptions {
    /// Pool-wide compile cache (executables, prepared segments, phase-2
    /// plans — each built once per server, not once per worker).
    pub compile_cache: Arc<CompileCache>,
    /// Server-wide Algorithm-2 decision cache: repeat
    /// (model, level, profile-bucket) requests skip planning entirely.
    pub decision_cache: Arc<DecisionCache>,
    /// Execute phase 2 with the pure-Rust host reference kernels instead
    /// of PJRT (tests / bench-serve; linear architectures only).
    pub host_fallback: bool,
    /// This worker's span emitter (see [`crate::obs`]). `None` for
    /// standalone services; the server wires one per pool worker. Spans
    /// are only recorded for jobs that carry a [`JobTrace`], so an idle
    /// tracer costs one `Option` check per job.
    pub tracer: Option<Tracer>,
    /// Server-wide brownout controller (see [`crate::brownout`]). `None`
    /// disables degradation entirely — the plan path is then untouched.
    pub brownout: Option<Arc<BrownoutController>>,
    /// Fault injection for the chaos harness; `None` (or a no-op spec)
    /// is the production path.
    pub faults: Option<FaultSpec>,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions {
            compile_cache: Arc::new(CompileCache::new()),
            decision_cache: Arc::new(DecisionCache::new()),
            host_fallback: false,
            tracer: None,
            brownout: None,
            faults: None,
        }
    }
}

/// One phase-1 request drained from the queue, with its reply sink,
/// trace, and per-class attribution.
struct InferJob {
    req: InferRequest,
    tx: ReplySink,
    trace: Option<JobTrace>,
    class: Option<Arc<ClassCounts>>,
}

/// One executor-pool worker's service (owns the non-`Send` PJRT executor;
/// shares the bundle, the session table, the encoded-reply cache, the
/// compile cache, and — via the hub — the metrics view).
pub struct Service {
    pub bundle: Arc<Bundle>,
    executor: Executor,
    /// Offline pattern tables per model instance (Algorithm 1 output).
    patterns: Vec<(String, PatternSet)>,
    /// Shared, sharded session table — sessions opened by any worker are
    /// visible to every worker (phase 2 may land on a different one).
    sessions: Arc<SharedSessionTable>,
    /// This worker's own counters/histograms (registered in `hub`).
    pub metrics: Arc<Metrics>,
    /// The hub aggregating every worker, so the `stats` request reports
    /// the whole server, not one worker.
    hub: Arc<MetricsHub>,
    server_profile: ServerProfile,
    default_weights: TradeoffWeights,
    /// Server-wide encoded replies per (model, level_idx, partition) —
    /// quantize + pack + serialize happens once per key across the whole
    /// pool, not per request or per worker.
    reply_cache: Arc<EncodedReplyCache>,
    /// Server-wide Algorithm-2 memoization per
    /// (model, level, bucketed profile) — repeat profiles skip planning.
    decision_cache: Arc<DecisionCache>,
    /// Span emitter for traced jobs (`None` disables span recording).
    tracer: Option<Tracer>,
    /// Server-wide brownout controller; `None` disables degradation.
    brownout: Option<Arc<BrownoutController>>,
    /// Active fault injection with its own deterministic stream (`None`
    /// on the production path; a no-op spec is dropped at construction).
    faults: Option<(FaultSpec, Rng)>,
}

impl Service {
    /// Build the worker's service over the shared bundle and run
    /// Algorithm 1 for every model. Registers this worker's [`Metrics`]
    /// (and, idempotently, the shared reply cache) in `hub`. Standalone
    /// services get a private compile cache; pool workers share one via
    /// [`Service::with_options`].
    pub fn new(
        bundle: Arc<Bundle>,
        hub: Arc<MetricsHub>,
        sessions: Arc<SharedSessionTable>,
        reply_cache: Arc<EncodedReplyCache>,
    ) -> qpart_runtime::Result<Service> {
        Service::with_options(bundle, hub, sessions, reply_cache, ServiceOptions::default())
    }

    /// [`Service::new`] with explicit execution-plane options (the
    /// executor-pool entry point).
    pub fn with_options(
        bundle: Arc<Bundle>,
        hub: Arc<MetricsHub>,
        sessions: Arc<SharedSessionTable>,
        reply_cache: Arc<EncodedReplyCache>,
        opts: ServiceOptions,
    ) -> qpart_runtime::Result<Service> {
        let metrics = hub.register_worker();
        hub.register_segment_cache(Arc::clone(&reply_cache));
        hub.register_compile_cache(Arc::clone(&opts.compile_cache));
        hub.register_decision_cache(Arc::clone(&opts.decision_cache));
        let mut executor = Executor::with_cache(Arc::clone(&bundle), opts.compile_cache)?;
        executor.set_host_fallback(opts.host_fallback);
        let mut patterns = Vec::new();
        for m in &bundle.models {
            let arch = bundle.arch(&m.arch)?;
            let calib = bundle.calibration(&m.name)?;
            let set = offline_quantize(arch, &calib, OfflineConfig::default())
                .map_err(qpart_runtime::Error::Core)?;
            patterns.push((m.name.clone(), set));
        }
        Ok(Service {
            bundle,
            executor,
            patterns,
            sessions,
            metrics,
            hub,
            server_profile: ServerProfile::paper_default(),
            default_weights: TradeoffWeights::paper_default(),
            reply_cache,
            decision_cache: opts.decision_cache,
            tracer: opts.tracer,
            brownout: opts.brownout,
            faults: opts.faults.filter(|f| !f.is_noop()).map(|f| {
                // per-instance stream: a respawned worker must NOT replay
                // the exact fault sequence that killed its predecessor
                // (a shared label would turn first-draw panics into a
                // permanent crash loop)
                use std::sync::atomic::{AtomicU64, Ordering};
                static FAULT_STREAM_SEQ: AtomicU64 = AtomicU64::new(0);
                let n = FAULT_STREAM_SEQ.fetch_add(1, Ordering::Relaxed);
                (f, Rng::from_label(0xFA17_0B5E, &format!("service/fault/{n}")))
            }),
        })
    }

    /// A [`TraceStamp`] for a traced job's reply push (the front-end
    /// turns it into the Route span), `None` when untraced.
    fn stamp(&self, trace: Option<JobTrace>) -> Option<TraceStamp> {
        match (&self.tracer, trace) {
            (Some(t), Some(trace)) => Some(TraceStamp { trace, pushed_us: t.now_us() }),
            _ => None,
        }
    }

    fn pattern_set(&self, model: &str) -> Option<&PatternSet> {
        self.patterns.iter().find(|(n, _)| n == model).map(|(_, s)| s)
    }

    fn arch_for_model(&self, model: &str) -> qpart_runtime::Result<&ModelSpec> {
        let m = self.bundle.model(model)?;
        self.bundle.arch(&m.arch)
    }

    /// Handle one protocol request.
    pub fn handle(&mut self, req: Request) -> Response {
        Metrics::inc(&self.metrics.requests_total);
        let t0 = Instant::now();
        let resp = match req {
            Request::Ping => Response::Pong,
            Request::ListModels => self.list_models(),
            Request::Stats => Response::Stats(self.stats_json()),
            // framing and tracing are connection-level concerns; a hello
            // that reaches the pool (direct in-process callers) grants
            // nothing
            Request::Hello(_) => {
                Response::Hello(HelloReply { binary_frames: false, trace: None })
            }
            Request::Infer(r) => self.handle_infer(&r),
            Request::Activation(a) => self.handle_activation(&a),
            Request::Simulate(s) => self.handle_simulate(&s),
        };
        self.metrics.handle_latency.observe_us(t0.elapsed().as_micros() as u64);
        if matches!(resp, Response::Error(_)) {
            Metrics::inc(&self.metrics.errors_total);
        }
        resp
    }

    /// Handle one drained batch: `infer` requests are planned, grouped by
    /// `(model, accuracy level, partition)`, and each group is encoded
    /// once and fanned out to every waiting connection; `activation`
    /// uploads are decoded, grouped by `(model, partition)`, and
    /// row-stacked into batched server-segment executions; everything
    /// else is answered individually.
    pub fn handle_batch(&mut self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        Metrics::inc(&self.metrics.batches_total);
        if let Some((spec, _)) = &self.faults {
            if spec.exec_delay_ms > 0 {
                // injected slowdown: drives queue waits up so the chaos
                // harness can prove brownout enters under load
                std::thread::sleep(std::time::Duration::from_millis(spec.exec_delay_ms));
            }
        }
        let dequeued = Instant::now();
        let mut infers: Vec<InferJob> = Vec::new();
        let mut uploads: Vec<(ActivationUpload, ReplySink, Option<JobTrace>)> = Vec::new();
        for job in jobs {
            let wait = dequeued.saturating_duration_since(job.enqueued);
            let wait_us = wait.as_micros() as u64;
            self.metrics.queue_wait.observe_us(wait_us);
            if let Some(b) = &self.brownout {
                b.observe_wait_us(wait_us);
            }
            if let (Some(tr), Some(trace)) = (&self.tracer, job.trace) {
                // span length ≡ the queue_wait histogram sample, exactly
                let start = tr.sink().offset_us(job.enqueued);
                tr.span(trace, Stage::QueueWait, start, start + wait_us);
            }
            match job.req {
                Request::Infer(r) => {
                    // deadline-aware admission: a request that already
                    // overstayed its deadline in the queue is answered
                    // with a soft error instead of burning plan + encode
                    // work on a reply the device will discard
                    if let Some(d) = r.deadline_ms {
                        if wait_us > d.saturating_mul(1000) {
                            Metrics::inc(&self.metrics.requests_total);
                            Metrics::inc(&self.metrics.deadline_shed_total);
                            Metrics::inc(&self.metrics.errors_total);
                            if let Some(c) = &job.class {
                                Metrics::inc(&c.deadline_shed_total);
                            }
                            let stamp = self.stamp(job.trace);
                            job.reply.send_with(
                                WireReply::Msg(Self::err(
                                    "deadline_exceeded",
                                    format!("queued {wait_us}us against a {d}ms deadline"),
                                )),
                                stamp,
                            );
                            continue;
                        }
                    }
                    infers.push(InferJob {
                        req: r,
                        tx: job.reply,
                        trace: job.trace,
                        class: job.class,
                    });
                }
                Request::Activation(a) => uploads.push((a, job.reply, job.trace)),
                req => {
                    let resp = self.handle(req);
                    let stamp = self.stamp(job.trace);
                    job.reply.send_with(WireReply::Msg(resp), stamp);
                }
            }
        }
        self.handle_infer_batch(infers);
        self.handle_activation_batch(uploads);
    }

    /// Plan + group + encode-once + fan out (the coalescing core).
    fn handle_infer_batch(&mut self, jobs: Vec<InferJob>) {
        // one waiting connection within a group
        struct Pending {
            tx: ReplySink,
            objective: f64,
            trace: Option<JobTrace>,
            degraded: bool,
        }
        // all same-key requests of this batch: one encode, many replies
        struct Group {
            key: SegmentKey,
            pattern: QuantPattern,
            arch: ModelSpec,
            pendings: Vec<Pending>,
        }
        // plan every request; identical decisions coalesce into one group
        let mut groups: Vec<Group> = Vec::new();
        for InferJob { req: r, tx, trace, class } in jobs {
            Metrics::inc(&self.metrics.requests_total);
            let t_req = Instant::now();
            let mut inject_fail = false;
            if let Some((spec, rng)) = self.faults.as_mut() {
                if spec.worker_panic > 0.0 && rng.range_f64(0.0, 1.0) < spec.worker_panic {
                    // the supervisor's catch_unwind + sink snapshot turn
                    // this into error replies and a respawned worker
                    panic!("fault-inject: worker-panic");
                }
                inject_fail =
                    spec.alloc_fail > 0.0 && rng.range_f64(0.0, 1.0) < spec.alloc_fail;
            }
            if inject_fail {
                Metrics::inc(&self.metrics.errors_total);
                self.metrics.handle_latency.observe_us(t_req.elapsed().as_micros() as u64);
                let stamp = self.stamp(trace);
                tx.send_with(
                    WireReply::Msg(Self::err("internal", "injected allocation failure")),
                    stamp,
                );
                continue;
            }
            match self.plan_infer(&r) {
                Ok((arch, decision, plan_hit, degraded)) => {
                    if degraded {
                        Metrics::inc(&self.metrics.degraded_total);
                        if let Some(c) = &class {
                            Metrics::inc(&c.degraded_total);
                        }
                    }
                    if let (Some(tr), Some(trace)) = (&self.tracer, trace) {
                        let start = tr.sink().offset_us(t_req);
                        let mut notes = vec![
                            ("cache_hit", i64::from(plan_hit)),
                            ("level", decision.level_idx as i64),
                            ("partition", decision.pattern.partition as i64),
                        ];
                        if degraded {
                            notes.push(("degraded", 1));
                        }
                        tr.span_with(trace, Stage::Plan, start, tr.now_us(), notes);
                    }
                    let key: SegmentKey =
                        (r.model.clone(), decision.level_idx, decision.pattern.partition);
                    let pending =
                        Pending { tx, objective: decision.cost.objective, trace, degraded };
                    match groups.iter().position(|g| g.key == key) {
                        Some(i) => groups[i].pendings.push(pending),
                        None => groups.push(Group {
                            key,
                            pattern: decision.pattern.clone(),
                            arch,
                            pendings: vec![pending],
                        }),
                    }
                }
                Err(resp) => {
                    Metrics::inc(&self.metrics.errors_total);
                    self.metrics
                        .handle_latency
                        .observe_us(t_req.elapsed().as_micros() as u64);
                    let stamp = self.stamp(trace);
                    tx.send_with(WireReply::Msg(resp), stamp);
                }
            }
        }
        for g in groups {
            // per-group clock: a request's recorded handle time covers its
            // own group's encode + fan-out, not other groups in the batch
            let t_group = Instant::now();
            if g.pendings.len() > 1 {
                Metrics::add(&self.metrics.coalesced_total, (g.pendings.len() - 1) as u64);
            }
            match self.encoded_for(&g.key, &g.pattern) {
                Ok((body, encode_hit)) => {
                    // one handling-time measurement per group (the encode
                    // dominates): recording elapsed per pending would make
                    // a request's latency reflect its fan-out position
                    let group_us = t_group.elapsed().as_micros() as u64;
                    let fanout = g.pendings.len() as i64;
                    let boundary = boundary_dims(&g.arch, g.pattern.partition, 1);
                    for p in g.pendings {
                        let session =
                            self.sessions.open(&g.key.0, g.pattern.clone(), boundary.clone());
                        Metrics::inc(&self.metrics.sessions_opened);
                        Metrics::add(&self.metrics.bytes_out, body.wire_bytes());
                        if let (Some(tr), Some(trace)) = (&self.tracer, p.trace) {
                            // every pending shares the group's encode window
                            let start = tr.sink().offset_us(t_group);
                            tr.span_with(
                                trace,
                                Stage::Encode,
                                start,
                                start + group_us,
                                vec![
                                    ("cache_hit", i64::from(encode_hit)),
                                    ("fanout", fanout),
                                ],
                            );
                        }
                        let stamp = self.stamp(p.trace);
                        p.tx.send_with(
                            WireReply::Segment(SegmentReply {
                                session,
                                trace: p.trace.and_then(JobTrace::wire_id),
                                degraded: p.degraded,
                                objective: p.objective,
                                body: Arc::clone(&body),
                            }),
                            stamp,
                        );
                        self.metrics.handle_latency.observe_us(group_us);
                    }
                }
                Err(resp) => {
                    let group_us = t_group.elapsed().as_micros() as u64;
                    for p in g.pendings {
                        Metrics::inc(&self.metrics.errors_total);
                        self.metrics.handle_latency.observe_us(group_us);
                        let stamp = self.stamp(p.trace);
                        p.tx.send_with(WireReply::Msg(resp.clone()), stamp);
                    }
                }
            }
        }
    }

    fn stats_json(&self) -> qpart_core::json::Value {
        let mut v = self.hub.to_json();
        v.set("open_sessions", self.sessions.len().into());
        v.set("session_shards", self.sessions.num_shards().into());
        v.set(
            "session_shard_occupancy",
            qpart_core::json::Value::Arr(
                self.sessions.shard_occupancy().into_iter().map(|n| n.into()).collect(),
            ),
        );
        // age (TTL) and capacity pressure are separate failure modes —
        // both live in the shared table, not in any worker's counters
        v.set("sessions_expired", self.sessions.expired().into());
        v.set("sessions_evicted", self.sessions.evicted().into());
        v.set("models", self.patterns.len().into());
        v
    }

    fn list_models(&self) -> Response {
        let models = self
            .bundle
            .models
            .iter()
            .filter_map(|m| {
                let arch = self.bundle.arch(&m.arch).ok()?;
                Some(ModelInfo {
                    name: m.name.clone(),
                    arch: m.arch.clone(),
                    dataset: m.dataset.clone(),
                    layers: arch.num_layers(),
                    params: arch.total_params(),
                    test_accuracy: m.test_accuracy,
                })
            })
            .collect();
        Response::Models(models)
    }

    fn err(code: &str, message: impl std::fmt::Display) -> Response {
        Response::Error(ErrorReply { code: code.into(), message: message.to_string() })
    }

    fn cost_model_for(&self, r: &InferRequest) -> CostModel {
        CostModel {
            device: DeviceProfile {
                clock_hz: r.clock_hz,
                cycles_per_mac: r.cycles_per_mac,
                kappa: r.kappa,
                memory_bits: r.memory_bits,
            },
            server: self.server_profile,
            channel: Channel::fixed(r.channel_capacity_bps, r.tx_power_w),
            weights: r
                .weights
                .map(|(omega, tau, eta)| TradeoffWeights { omega, tau, eta })
                .unwrap_or(self.default_weights),
        }
    }

    /// Algorithm 2 under the request's live parameters, memoized in the
    /// server-wide [`DecisionCache`]: a repeat
    /// (model, level, profile-bucket) skips planning entirely. On
    /// success, the decided pattern determines the coalescing key; only
    /// the objective value remains per-request (and it is part of the
    /// memoized decision — a pure function of the same key). The first
    /// returned bool is the decision-cache hit flag (surfaced in Plan
    /// spans); the second is the brownout-degradation flag.
    ///
    /// **Brownout**: at ladder level `k`, the plan is biased up to `k`
    /// accuracy levels coarser than the request's nominal selection —
    /// but only when [`degrade_level`]'s table check proves every
    /// candidate pattern's predicted degradation still fits the
    /// request's budget. At level 0 (or with no controller) this is
    /// byte-for-byte the pre-brownout plan path.
    fn plan_infer(
        &self,
        r: &InferRequest,
    ) -> Result<(ModelSpec, Arc<Decision>, bool, bool), Response> {
        let arch = match self.arch_for_model(&r.model) {
            Ok(a) => a.clone(),
            Err(e) => return Err(Self::err("unknown_model", e)),
        };
        let set = match self.pattern_set(&r.model) {
            Some(s) => s,
            None => return Err(Self::err("unknown_model", &r.model)),
        };
        let t_dec = Instant::now();
        // the budget enters Algorithm 2 only through level selection, so
        // the cache keys on the selected level, not the raw budget (on a
        // miss serve_request_fast repeats this O(levels) scan — same
        // single implementation, a handful of float compares)
        let nominal = match set.select_level(r.accuracy_budget) {
            Ok(i) => i,
            Err(e) => return Err(Self::err("infeasible", e)),
        };
        let rungs = self.brownout.as_ref().map(|b| b.level()).unwrap_or(0);
        let level_idx = if rungs > 0 {
            degrade_level(set, nominal, r.accuracy_budget, rungs)
        } else {
            nominal
        };
        let degraded = level_idx != nominal;
        // when degraded, Algorithm 2 plans at the chosen level by
        // substituting that level's own budget (select_level of which is
        // exactly level_idx); the cache key shares entries with requests
        // nominally at that level — the decision is the same pure
        // function of (model, level, profile)
        let budget = if degraded { set.levels[level_idx] } else { r.accuracy_budget };
        let params = RequestParams { cost: self.cost_model_for(r), accuracy_budget: budget };
        let key: DecisionKey = (r.model.clone(), level_idx, ProfileBucket::of(&params.cost));
        if let Some(d) = self.decision_cache.get(&key) {
            self.metrics.decide_latency.observe_us(t_dec.elapsed().as_micros() as u64);
            return Ok((arch, d, true, degraded));
        }
        let decision = match serve_request_fast(&arch, set, &params) {
            Ok(d) => Arc::new(d),
            Err(e) => return Err(Self::err("infeasible", e)),
        };
        self.decision_cache.insert(key, Arc::clone(&decision));
        self.metrics.decide_latency.observe_us(t_dec.elapsed().as_micros() as u64);
        Ok((arch, decision, false, degraded))
    }

    /// Fetch the encoded reply body for `key`, or quantize + pack +
    /// serialize it once and publish it to the shared cache. The encode
    /// goes through the **fused** quantize→pack kernel
    /// (`Executor::quantize_segment_packed`): each layer's weights stream
    /// `&[f32]` → packed wire bytes in one pass, with no intermediate
    /// per-layer code vectors (bit-identical to the composed path).
    fn encoded_for(
        &mut self,
        key: &SegmentKey,
        pattern: &QuantPattern,
    ) -> Result<(Arc<EncodedSegmentBody>, bool), Response> {
        if let Some(body) = self.reply_cache.get(key) {
            return Ok((body, true));
        }
        let t_q = Instant::now();
        let seg = match self.executor.quantize_segment_packed(&key.0, pattern) {
            Ok(s) => s,
            Err(e) => return Err(Self::err("internal", e)),
        };
        let mut layers = Vec::with_capacity(seg.layers.len());
        for pl in seg.layers {
            layers.push(LayerBlob {
                layer: pl.layer,
                bits: pl.weights.params.bits,
                w_dims: pl.w_dims,
                w_qmin: pl.weights.params.min,
                w_step: pl.weights.params.step(),
                w_packed: pl.weights.packed,
                b_qmin: pl.bias.params.min,
                b_step: pl.bias.params.step(),
                b_len: pl.bias.len,
                b_packed: pl.bias.packed,
            });
        }
        let pattern_info = PatternInfo {
            partition: pattern.partition,
            weight_bits: pattern.weight_bits.clone(),
            activation_bits: pattern.activation_bits,
            accuracy_level: pattern.accuracy_level,
            predicted_degradation: pattern.predicted_degradation,
            // stamped per request at send time
            objective: f64::NAN,
        };
        let body =
            Arc::new(EncodedSegmentBody::new(&key.0, pattern_info, SegmentBlob { layers }));
        self.reply_cache.insert(key.clone(), Arc::clone(&body));
        Metrics::inc(&self.metrics.encodes_total);
        self.metrics.quantize_latency.observe_us(t_q.elapsed().as_micros() as u64);
        Ok((body, false))
    }

    /// Phase 1, single-request path (in-process callers; pool workers go
    /// through [`Service::handle_batch`]): decide, fetch/encode, open a
    /// session.
    fn handle_infer(&mut self, r: &InferRequest) -> Response {
        let (arch, decision, _, degraded) = match self.plan_infer(r) {
            Ok(x) => x,
            Err(resp) => return resp,
        };
        if degraded {
            Metrics::inc(&self.metrics.degraded_total);
        }
        let key: SegmentKey = (r.model.clone(), decision.level_idx, decision.pattern.partition);
        let (body, _) = match self.encoded_for(&key, &decision.pattern) {
            Ok(b) => b,
            Err(resp) => return resp,
        };
        let boundary = boundary_dims(&arch, decision.pattern.partition, 1);
        let session = self.sessions.open(&r.model, decision.pattern.clone(), boundary);
        Metrics::inc(&self.metrics.sessions_opened);
        Metrics::add(&self.metrics.bytes_out, body.wire_bytes());
        let mut reply = body.to_reply(session, decision.cost.objective);
        reply.degraded = degraded;
        Response::Segment(reply)
    }

    /// Decode + validate one upload against its session: consume the
    /// session, check dims, unpack + dequantize the boundary activation.
    fn decode_activation(
        &mut self,
        a: &ActivationUpload,
    ) -> Result<(Session, HostTensor), Response> {
        let session = match self.sessions.take(a.session) {
            Some(s) => s,
            None => return Err(Self::err("unknown_session", a.session)),
        };
        if a.dims != session.boundary_dims {
            return Err(Self::err(
                "bad_activation",
                format!("expected dims {:?}, got {:?}", session.boundary_dims, a.dims),
            ));
        }
        let n: usize = a.dims.iter().product();
        Metrics::add(&self.metrics.bytes_in, a.packed.len() as u64);
        let codes = match unpack_bits(&a.packed, n, a.bits) {
            Ok(c) => c,
            Err(e) => return Err(Self::err("bad_activation", e)),
        };
        // u64 shift: a 32-bit upload must not overflow the level count
        let levels = ((1u64 << a.bits.min(32)) - 1) as f32;
        let params = match QuantParams::from_range(a.bits, a.qmin, a.qmin + a.step * levels) {
            Ok(p) => p,
            Err(e) => return Err(Self::err("bad_activation", e)),
        };
        let values = Quantized { params, codes }.dequantize();
        match HostTensor::new(a.dims.clone(), values) {
            Ok(h) => Ok((session, h)),
            Err(e) => Err(Self::err("bad_activation", e)),
        }
    }

    /// Execute the server segment for one `(model, partition)` group of
    /// decoded rows, in chunks of up to [`EVAL_BATCH`] rows per
    /// execution. Each chunk runs at the tightest batch-ladder rung the
    /// runtime can execute (a lone upload runs at batch 1, not padded to
    /// 32); the rows the rung padded are tracked in
    /// `phase2_padded_rows_total`. Returns one response per row, in
    /// input order.
    fn run_phase2(
        &mut self,
        model: &str,
        partition: usize,
        rows: Vec<(u64, HostTensor, Option<JobTrace>)>,
    ) -> Vec<(u64, Response)> {
        let mut out = Vec::with_capacity(rows.len());
        let mut iter = rows.into_iter().peekable();
        while iter.peek().is_some() {
            let chunk: Vec<(u64, HostTensor, Option<JobTrace>)> =
                iter.by_ref().take(EVAL_BATCH).collect();
            let sessions: Vec<(u64, Option<JobTrace>)> =
                chunk.iter().map(|(s, _, t)| (*s, *t)).collect();
            let tensors: Vec<HostTensor> = chunk.into_iter().map(|(_, h, _)| h).collect();
            let t_x = Instant::now();
            let result = self.executor.run_server_segment_rows(model, &tensors, partition);
            let us = t_x.elapsed().as_micros() as u64;
            self.metrics.execute_latency.observe_us(us);
            Metrics::inc(&self.metrics.phase2_execs_total);
            Metrics::add(&self.metrics.phase2_rows_total, sessions.len() as u64);
            if let Some(tr) = &self.tracer {
                let start = tr.sink().offset_us(t_x);
                let rows_note = sessions.len() as i64;
                for trace in sessions.iter().filter_map(|(_, t)| *t) {
                    // batch occupancy: how many rows shared this run
                    tr.span_with(
                        trace,
                        Stage::Execute,
                        start,
                        start + us,
                        vec![("rows", rows_note)],
                    );
                }
            }
            match result {
                Ok(outcome) => {
                    Metrics::add(
                        &self.metrics.phase2_padded_rows_total,
                        outcome.padded_rows as u64,
                    );
                    for ((sid, trace), logits) in sessions.iter().zip(outcome.logits) {
                        let mut reply = result_reply(*sid, &logits, None, us);
                        reply.trace = trace.and_then(JobTrace::wire_id);
                        out.push((*sid, Response::Result(reply)));
                    }
                }
                Err(e) => {
                    let resp = Self::err("internal", e);
                    for (sid, _) in sessions {
                        out.push((sid, resp.clone()));
                    }
                }
            }
        }
        out
    }

    /// Phase 2, single-request path: reconstruct the uploaded activation
    /// and finish on the server. Funnels through the same batched
    /// executor entry as `handle_activation_batch` (with one row), so
    /// sequential and coalesced phase 2 are numerically identical.
    fn handle_activation(&mut self, a: &ActivationUpload) -> Response {
        let (session, h) = match self.decode_activation(a) {
            Ok(x) => x,
            Err(resp) => return resp,
        };
        let mut replies = self.run_phase2(
            &session.model,
            session.pattern.partition,
            vec![(a.session, h, None)],
        );
        match replies.pop() {
            Some((_, resp)) => resp,
            None => Self::err("internal", "phase-2 execution returned nothing"),
        }
    }

    /// Phase 2, batch path: decode every upload, group by
    /// `(model, partition)`, and row-stack each group into
    /// ⌈rows/EVAL_BATCH⌉ server-segment executions — the uplink mirror of
    /// `handle_infer_batch`'s encode-once coalescing.
    fn handle_activation_batch(
        &mut self,
        uploads: Vec<(ActivationUpload, ReplySink, Option<JobTrace>)>,
    ) {
        struct Pending {
            session: u64,
            tensor: HostTensor,
            tx: ReplySink,
            trace: Option<JobTrace>,
        }
        struct Group {
            model: String,
            partition: usize,
            pendings: Vec<Pending>,
        }
        let mut groups: Vec<Group> = Vec::new();
        for (a, tx, trace) in uploads {
            Metrics::inc(&self.metrics.requests_total);
            let t_req = Instant::now();
            match self.decode_activation(&a) {
                Ok((session, tensor)) => {
                    let pending = Pending { session: a.session, tensor, tx, trace };
                    let partition = session.pattern.partition;
                    match groups
                        .iter()
                        .position(|g| g.model == session.model && g.partition == partition)
                    {
                        Some(i) => groups[i].pendings.push(pending),
                        None => groups.push(Group {
                            model: session.model,
                            partition,
                            pendings: vec![pending],
                        }),
                    }
                }
                Err(resp) => {
                    Metrics::inc(&self.metrics.errors_total);
                    self.metrics
                        .handle_latency
                        .observe_us(t_req.elapsed().as_micros() as u64);
                    let stamp = self.stamp(trace);
                    tx.send_with(WireReply::Msg(resp), stamp);
                }
            }
        }
        for g in groups {
            // per-group clock, mirroring the infer batch path: a request's
            // recorded handle time covers its own group's executions
            let t_group = Instant::now();
            let mut txs = Vec::with_capacity(g.pendings.len());
            let mut rows = Vec::with_capacity(g.pendings.len());
            for p in g.pendings {
                txs.push((p.tx, p.trace));
                rows.push((p.session, p.tensor, p.trace));
            }
            let replies = self.run_phase2(&g.model, g.partition, rows);
            let group_us = t_group.elapsed().as_micros() as u64;
            for ((tx, trace), (_, resp)) in txs.iter().zip(replies) {
                if matches!(resp, Response::Error(_)) {
                    Metrics::inc(&self.metrics.errors_total);
                }
                self.metrics.handle_latency.observe_us(group_us);
                let stamp = self.stamp(*trace);
                tx.send_with(WireReply::Msg(resp), stamp);
            }
        }
    }

    /// Pre-warm the execution plane (`--warm-cache`): for every model ×
    /// offline accuracy level, encode the reply Algorithm 2 would pick
    /// under the paper-default device/channel profile and pre-build its
    /// phase-2 plan. Algorithm 1 already enumerated the candidates; this
    /// just front-loads the per-key work the first requests would pay.
    /// Returns the number of keys warmed.
    pub fn warm_cache(&mut self) -> usize {
        let mut targets: Vec<(String, usize, QuantPattern)> = Vec::new();
        for (model, set) in &self.patterns {
            let arch = match self.bundle.model(model).and_then(|m| self.bundle.arch(&m.arch)) {
                Ok(a) => a.clone(),
                Err(_) => continue,
            };
            for &level in &set.levels {
                let params = RequestParams {
                    cost: CostModel::paper_default(),
                    accuracy_budget: level,
                };
                if let Ok(d) = serve_request_fast(&arch, set, &params) {
                    targets.push((model.clone(), d.level_idx, d.pattern));
                }
            }
        }
        let mut warmed = 0usize;
        for (model, level_idx, pattern) in targets {
            let key: SegmentKey = (model.clone(), level_idx, pattern.partition);
            if self.encoded_for(&key, &pattern).is_ok() {
                // hit flag irrelevant here: a warm re-run is already cached
                // plan build is what matters offline; executable compiles
                // are best-effort (absent without `make artifacts`)
                let _ = self.executor.warm_server_segment(&model, pattern.partition);
                Metrics::inc(&self.metrics.warmed_total);
                warmed += 1;
            }
        }
        warmed
    }

    /// Replay the durable store (`--warm log`): decode every live
    /// decision and reply entry back into the shared caches and
    /// pre-build the phase-2 plans named by the persisted fingerprints.
    /// Unlike [`Service::warm_cache`] — which warms the *paper-default*
    /// profile — this restores the **recorded request mix**: whatever
    /// the previous process actually served, byte-identical (the codecs
    /// in [`crate::store::keys`] are bit-exact). Entries that fail to
    /// decode (written by a different build) are skipped, not fatal.
    /// Returns the number of entries warmed.
    pub fn warm_from_store(&mut self, tier: &StoreTier) -> usize {
        let mut warmed = 0usize;
        for (key, value) in tier.snapshot(Column::Decision) {
            let (Some(k), Some(d)) =
                (store_keys::decode_decision_key(&key), store_keys::decode_decision(&value))
            else {
                continue;
            };
            self.decision_cache.insert_warm(k, Arc::new(d));
            Metrics::inc(&self.metrics.warmed_total);
            warmed += 1;
        }
        for (key, value) in tier.snapshot(Column::Reply) {
            let (Some(k), Some(body)) =
                (store_keys::decode_reply_key(&key), store_keys::decode_reply_body(&value))
            else {
                continue;
            };
            self.reply_cache.insert_warm(k, Arc::new(body));
            Metrics::inc(&self.metrics.warmed_total);
            warmed += 1;
        }
        for (key, _) in tier.snapshot(Column::Plan) {
            let Some((model, partition)) = store_keys::decode_plan_key(&key) else {
                continue;
            };
            // plan build is what matters offline; executable compiles
            // are best-effort (absent without `make artifacts`)
            let _ = self.executor.warm_server_segment(&model, partition);
            Metrics::inc(&self.metrics.warmed_total);
            warmed += 1;
        }
        warmed
    }

    /// The pool-wide compile cache this worker shares (observability).
    pub fn compile_cache(&self) -> Arc<CompileCache> {
        self.executor.compile_cache()
    }

    /// One-shot: the server simulates the device too (load generation).
    fn handle_simulate(&mut self, s: &SimulateRequest) -> Response {
        let arch = match self.arch_for_model(&s.req.model) {
            Ok(a) => a.clone(),
            Err(e) => return Self::err("unknown_model", e),
        };
        let set = match self.pattern_set(&s.req.model) {
            Some(set) => set,
            None => return Self::err("unknown_model", &s.req.model),
        };
        let t_dec = Instant::now();
        let cost_model = self.cost_model_for(&s.req);
        let params =
            RequestParams { cost: cost_model, accuracy_budget: s.req.accuracy_budget };
        let decision = match serve_request_fast(&arch, set, &params) {
            Ok(d) => d,
            Err(e) => return Self::err("infeasible", e),
        };
        self.metrics.decide_latency.observe_us(t_dec.elapsed().as_micros() as u64);
        let x = match HostTensor::new(s.input_dims.clone(), s.input.clone()) {
            Ok(x) => x,
            Err(e) => return Self::err("bad_input", e),
        };
        let t_x = Instant::now();
        let outcome = match self.executor.run_split(&s.req.model, &decision.pattern, x) {
            Ok(o) => o,
            Err(e) => return Self::err("internal", e),
        };
        self.metrics.execute_latency.observe_us(t_x.elapsed().as_micros() as u64);
        // simulated (paper-model) costs at the decided partition
        let payload = outcome.weight_bits + outcome.activation_bits;
        let breakdown = cost_model.evaluate(&arch, decision.pattern.partition, payload);
        let mut costs = breakdown.to_json();
        costs.set("payload_bits", payload.into());
        costs.set("partition", decision.pattern.partition.into());
        costs.set(
            "predicted_degradation",
            decision.pattern.predicted_degradation.into(),
        );
        Response::Result(result_reply(
            0,
            &outcome.logits,
            Some(costs),
            t_x.elapsed().as_micros() as u64,
        ))
    }
}

/// Boundary-activation dims at partition `p`.
pub fn boundary_dims(arch: &ModelSpec, p: usize, batch: usize) -> Vec<usize> {
    if p == 0 {
        let mut v = vec![batch];
        v.extend_from_slice(&arch.input_shape);
        return v;
    }
    match arch.layers[p - 1].kind {
        LayerKind::Linear { d_out, .. } => vec![batch, d_out],
        LayerKind::Conv2d { c_out, out_side, .. } => vec![batch, c_out, out_side, out_side],
    }
}

fn result_reply(
    session: u64,
    logits: &HostTensor,
    costs: Option<qpart_core::json::Value>,
    server_us: u64,
) -> ResultReply {
    let classes = logits.row_elems();
    let row = &logits.data[..classes];
    let prediction = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(-1);
    ResultReply {
        session,
        // stamped by the caller for hello-negotiated traces
        trace: None,
        prediction,
        logits: row.iter().map(|&x| x as f64).collect(),
        costs,
        server_us,
    }
}
