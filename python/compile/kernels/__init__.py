"""L1 Pallas kernels + pure-jnp oracles (see qlinear.py, ref.py)."""

from . import ref  # noqa: F401
from .qlinear import qconv, qlinear, vmem_footprint_bytes  # noqa: F401
