//! Pool-wide compile cache: compiled executables, prepared device
//! segments, and phase-2 server-segment plans, shared across every
//! executor in a worker pool.
//!
//! Before this cache existed each pool worker owned private
//! `HashMap` caches inside its [`crate::Executor`], so a pool of `N`
//! workers compiled every executable `N` times, loaded every model's
//! weights from disk `N` times, and held `N` copies of the prepared
//! literals. The cache lifts all of that state into one mutex-guarded
//! registry keyed by `(model, partition, fingerprint)` (plus the artifact
//! name for raw executables), so each artifact is compiled/prepared
//! **once per server**, not once per worker.
//!
//! Concurrency contract: every `get_or_build` entry point holds its map's
//! mutex across the build closure. Compiles are rare (startup + pattern
//! churn) and the serialized section is exactly the work being
//! deduplicated, so this coarse locking is what guarantees the
//! **at-most-one compilation per key** property the stats report
//! ([`CompileCache::max_compiles_per_key`]).
//!
//! Error results are *not* cached: a failed build leaves the key absent so
//! a later attempt (e.g. after `make artifacts`) can succeed.

use crate::bundle::ModelWeights;
use crate::engine::Exec;
use crate::error::Result;
use qpart_core::json::Value;
use qpart_core::model::ModelSpec;
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Lock a cache map, recovering from poison. A worker that panics inside
/// a `get_or_build` closure (supervised + respawned by the coordinator)
/// poisons the map's mutex *without* corrupting it — the insert only
/// happens after the build returns `Ok`, so a poisoned map is simply one
/// that is missing the entry whose build blew up. Serving the pool from
/// it is safe; refusing to would turn one bad request into a dead server.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Cache key for segment-level state: `(model, partition, fingerprint)`.
/// Prepared device segments use the pattern's bit fingerprint; phase-2
/// server plans use the constant `"f32/server"` fingerprint (the server
/// side always runs full precision).
pub type CompileKey = (String, usize, String);

/// Fingerprint used by phase-2 server-segment plans.
pub const SERVER_FINGERPRINT: &str = "f32/server";

/// Pre-built f32 weight literals for one model (weight + bias per layer).
///
/// Wrapped so the pool can share literals across worker threads.
pub struct WeightLiterals {
    /// `(w, bias[1, G])` per layer, executable-input ready.
    pub layers: Vec<(xla::Literal, xla::Literal)>,
}

// SAFETY: literals are immutable host-side buffers after construction;
// nothing mutates them through shared references. The offline `xla` stub
// is a plain `Vec<u8>` wrapper; the real bindings hold host literals that
// are likewise only read after creation.
unsafe impl Send for WeightLiterals {}
unsafe impl Sync for WeightLiterals {}

impl std::fmt::Debug for WeightLiterals {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WeightLiterals").field("layers", &self.layers.len()).finish()
    }
}

/// Everything phase-2 execution needs for one `(model, partition)`:
/// the arch, the shared weights, and (on the PJRT path) the pre-built
/// weight literals. Assembled once per key across the whole pool.
#[derive(Debug)]
pub struct ServerSegmentPlan {
    /// The model's architecture spec.
    pub arch: ModelSpec,
    /// Partition point `p`: the plan executes layers `p+1..=L`.
    pub start: usize,
    /// Shared trained weights (host-fallback execution reads these).
    pub weights: Arc<ModelWeights>,
    /// Pre-built f32 literals (PJRT path; `None` under host fallback).
    pub literals: Option<Arc<WeightLiterals>>,
    /// Batch-ladder rungs this plan can execute, ascending: every rung
    /// under host fallback, only the rungs whose `f32layer` executables
    /// the bundle lowered on the PJRT path. Computed once at plan build,
    /// so the per-execution rung pick is a table read, not a bundle scan.
    pub rungs: Vec<usize>,
}

/// The pool-wide compile cache. One per server, shared via `Arc` by every
/// worker's [`crate::Executor`].
#[derive(Default)]
pub struct CompileCache {
    /// Compiled executables by artifact name (`q_l3_b32`, ...).
    execs: Mutex<HashMap<String, Arc<Exec>>>,
    /// Prepared device segments by `(model, partition, bit fingerprint)`.
    prepared: Mutex<HashMap<CompileKey, Arc<PreparedSegmentEntry>>>,
    /// Phase-2 plans by `(model, partition, "f32/server")`.
    plans: Mutex<HashMap<CompileKey, Arc<ServerSegmentPlan>>>,
    /// Trained weights by model (one resident copy per server).
    weights: Mutex<HashMap<String, Arc<ModelWeights>>>,
    /// f32 weight literals by model.
    literals: Mutex<HashMap<String, Arc<WeightLiterals>>>,
    /// Per-key build counts — the once-per-key assertion the stats report.
    counts: Mutex<HashMap<CompileKey, u64>>,
    exec_compiles: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Alias so the cache does not depend on `executor`'s internals directly
/// (the concrete type is [`crate::executor::PreparedSegment`]).
pub type PreparedSegmentEntry = crate::executor::PreparedSegment;

impl std::fmt::Debug for CompileCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CompileCache")
            .field("executables", &self.exec_len())
            .field("prepared_segments", &self.prepared_len())
            .field("server_plans", &self.plan_len())
            .field("compilations", &self.compilations())
            .finish()
    }
}

fn get_or_build<K, V, F>(
    map: &Mutex<HashMap<K, Arc<V>>>,
    hits: &AtomicU64,
    misses: &AtomicU64,
    key: &K,
    build: F,
) -> Result<(Arc<V>, bool)>
where
    K: Eq + Hash + Clone,
    F: FnOnce() -> Result<V>,
{
    let mut m = lock_recover(map);
    if let Some(v) = m.get(key) {
        hits.fetch_add(1, Ordering::Relaxed);
        return Ok((Arc::clone(v), false));
    }
    misses.fetch_add(1, Ordering::Relaxed);
    // build under the lock: this serialization IS the at-most-once
    // guarantee (see the module docs)
    let v = Arc::new(build()?);
    m.insert(key.clone(), Arc::clone(&v));
    Ok((v, true))
}

impl CompileCache {
    pub fn new() -> CompileCache {
        CompileCache::default()
    }

    /// Fetch a compiled executable by artifact name, compiling at most
    /// once across the pool.
    pub fn exec(&self, name: &str, build: impl FnOnce() -> Result<Exec>) -> Result<Arc<Exec>> {
        let name = name.to_string();
        let (v, built) = get_or_build(&self.execs, &self.hits, &self.misses, &name, build)?;
        if built {
            self.exec_compiles.fetch_add(1, Ordering::Relaxed);
        }
        Ok(v)
    }

    /// Fetch a prepared device segment, building at most once per key.
    pub fn prepared(
        &self,
        key: &CompileKey,
        build: impl FnOnce() -> Result<PreparedSegmentEntry>,
    ) -> Result<Arc<PreparedSegmentEntry>> {
        let (v, built) = get_or_build(&self.prepared, &self.hits, &self.misses, key, build)?;
        if built {
            self.note_compiled(key);
        }
        Ok(v)
    }

    /// Fetch a phase-2 server-segment plan, building at most once per key.
    pub fn plan(
        &self,
        key: &CompileKey,
        build: impl FnOnce() -> Result<ServerSegmentPlan>,
    ) -> Result<Arc<ServerSegmentPlan>> {
        let (v, built) = get_or_build(&self.plans, &self.hits, &self.misses, key, build)?;
        if built {
            self.note_compiled(key);
        }
        Ok(v)
    }

    /// Fetch a model's trained weights (one resident copy per server).
    pub fn weights(
        &self,
        model: &str,
        build: impl FnOnce() -> Result<ModelWeights>,
    ) -> Result<Arc<ModelWeights>> {
        let model = model.to_string();
        let (v, _) = get_or_build(&self.weights, &self.hits, &self.misses, &model, build)?;
        Ok(v)
    }

    /// Fetch a model's f32 weight literals.
    pub fn weight_literals(
        &self,
        model: &str,
        build: impl FnOnce() -> Result<WeightLiterals>,
    ) -> Result<Arc<WeightLiterals>> {
        let model = model.to_string();
        let (v, _) = get_or_build(&self.literals, &self.hits, &self.misses, &model, build)?;
        Ok(v)
    }

    fn note_compiled(&self, key: &CompileKey) {
        *lock_recover(&self.counts).entry(key.clone()).or_insert(0) += 1;
    }

    /// Cache lookups that found an entry.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache lookups that had to build.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Executables compiled (each artifact name at most once).
    pub fn exec_compiles(&self) -> u64 {
        self.exec_compiles.load(Ordering::Relaxed)
    }

    /// Segment-level builds performed, summed over keys (prepared device
    /// segments + server plans).
    pub fn compilations(&self) -> u64 {
        lock_recover(&self.counts).values().sum()
    }

    /// Per-key build counts (the acceptance check: every value is ≤ 1).
    pub fn compile_counts(&self) -> HashMap<CompileKey, u64> {
        lock_recover(&self.counts).clone()
    }

    /// The worst per-key build count — 1 (or 0) when the once-per-key
    /// contract holds.
    pub fn max_compiles_per_key(&self) -> u64 {
        lock_recover(&self.counts).values().copied().max().unwrap_or(0)
    }

    /// Resident compiled executables.
    pub fn exec_len(&self) -> usize {
        lock_recover(&self.execs).len()
    }

    /// Resident prepared device segments.
    pub fn prepared_len(&self) -> usize {
        lock_recover(&self.prepared).len()
    }

    /// Resident phase-2 plans.
    pub fn plan_len(&self) -> usize {
        lock_recover(&self.plans).len()
    }

    /// The `compile_cache` section of the coordinator's stats document.
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("executables", self.exec_len().into()),
            ("exec_compiles", self.exec_compiles().into()),
            ("prepared_segments", self.prepared_len().into()),
            ("server_plans", self.plan_len().into()),
            ("compilations", self.compilations().into()),
            ("max_compiles_per_key", self.max_compiles_per_key().into()),
            ("hits", self.hits().into()),
            ("misses", self.misses().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use qpart_core::model::mlp6;

    fn empty_weights() -> ModelWeights {
        ModelWeights { layers: Vec::new() }
    }

    #[test]
    fn weights_build_once_and_share() {
        let cache = CompileCache::new();
        let a = cache.weights("m", || Ok(empty_weights())).unwrap();
        let b = cache.weights("m", || panic!("second lookup must not rebuild")).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "shared entry");
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn plans_count_at_most_once_per_key() {
        let cache = CompileCache::new();
        let key: CompileKey = ("m".into(), 2, SERVER_FINGERPRINT.into());
        let build = || {
            Ok(ServerSegmentPlan {
                arch: mlp6(),
                start: 2,
                weights: Arc::new(empty_weights()),
                literals: None,
                rungs: crate::executor::BATCH_LADDER.to_vec(),
            })
        };
        let a = cache.plan(&key, build).unwrap();
        let b = cache.plan(&key, || panic!("must hit")).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let key2: CompileKey = ("m".into(), 3, SERVER_FINGERPRINT.into());
        let _ = cache
            .plan(&key2, || {
                Ok(ServerSegmentPlan {
                    arch: mlp6(),
                    start: 3,
                    weights: Arc::new(empty_weights()),
                    literals: None,
                    rungs: crate::executor::BATCH_LADDER.to_vec(),
                })
            })
            .unwrap();
        assert_eq!(cache.compilations(), 2, "one build per distinct key");
        assert_eq!(cache.max_compiles_per_key(), 1);
        assert_eq!(cache.compile_counts().len(), 2);
    }

    #[test]
    fn failed_builds_are_not_cached() {
        let cache = CompileCache::new();
        let err = cache.weights("m", || Err(Error::Xla("boom".into())));
        assert!(err.is_err());
        // the key stays absent; a later build succeeds
        let ok = cache.weights("m", || Ok(empty_weights()));
        assert!(ok.is_ok());
        assert_eq!(cache.misses(), 2, "both lookups missed");
        assert_eq!(cache.hits(), 0);
    }

    #[test]
    fn panicked_build_does_not_brick_the_cache() {
        let cache = Arc::new(CompileCache::new());
        let c2 = Arc::clone(&cache);
        let joined = std::thread::spawn(move || {
            let _ = c2.weights("boom", || panic!("injected build panic"));
        })
        .join();
        assert!(joined.is_err(), "the build panic propagates to its thread");
        // The panic happened while holding the weights mutex; the cache
        // must keep serving (poison recovered, failed key stays absent).
        let ok = cache.weights("m", || Ok(empty_weights()));
        assert!(ok.is_ok());
        assert!(cache.weights("boom", || Ok(empty_weights())).is_ok());
    }

    #[test]
    fn stats_json_has_all_fields() {
        let cache = CompileCache::new();
        let v = cache.to_json();
        for key in [
            "executables",
            "exec_compiles",
            "prepared_segments",
            "server_plans",
            "compilations",
            "max_compiles_per_key",
            "hits",
            "misses",
        ] {
            assert!(v.get(key).is_some(), "{key}");
        }
    }
}
