"""The `.qt` tensor file format (Python writer/reader).

This is the build-time half of the interchange with the Rust runtime
(`qpart_core::tensor`). See DESIGN.md §7; layout:

    magic   4 bytes  b"QTEN"
    version u32      1
    dtype   u32      0 = f32, 1 = i32
    ndim    u32
    dims    ndim x u64
    data    prod(dims) x 4 bytes, little-endian, C-order
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"QTEN"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def save(path, array) -> None:
    """Write `array` (float32 or int32) as a .qt file."""
    arr = np.ascontiguousarray(array)
    if arr.dtype not in _CODES:
        if np.issubdtype(arr.dtype, np.floating):
            arr = arr.astype(np.float32)
        elif np.issubdtype(arr.dtype, np.integer):
            arr = arr.astype(np.int32)
        else:
            raise TypeError(f"unsupported dtype {arr.dtype}")
    code = _CODES[arr.dtype]
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, code))
        f.write(struct.pack("<I", arr.ndim))
        for d in arr.shape:
            f.write(struct.pack("<Q", d))
        f.write(arr.tobytes(order="C"))


def load(path) -> np.ndarray:
    """Read a .qt file back into a numpy array."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        version, code = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        if code not in _DTYPES:
            raise ValueError(f"{path}: unknown dtype code {code}")
        (ndim,) = struct.unpack("<I", f.read(4))
        if ndim > 8:
            raise ValueError(f"{path}: ndim {ndim} too large")
        dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
        n = int(np.prod(dims)) if ndim else 1
        raw = f.read(4 * n)
        if len(raw) != 4 * n:
            raise ValueError(f"{path}: truncated data")
        if f.read(1):
            raise ValueError(f"{path}: trailing bytes")
    return np.frombuffer(raw, dtype=_DTYPES[code]).reshape(dims).copy()
