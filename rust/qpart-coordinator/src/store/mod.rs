//! The store tier: one layered byte-store API under every coordinator
//! cache, plus the append-only segment log that makes warm state survive
//! restarts.
//!
//! Before this module, the coordinator's three caches — the Algorithm-2
//! [`DecisionCache`](crate::decision::DecisionCache), the
//! [`EncodedReplyCache`](crate::sched::EncodedReplyCache), and the
//! pool-wide compile cache — had three incompatible APIs (different key
//! types, eviction policies, stats shapes, warm-up paths) and all of them
//! forgot everything on restart. The store tier unifies them:
//!
//! ```text
//!   DecisionCache        EncodedReplyCache       CompileCache plans
//!   (typed facade)       (typed facade)          (fingerprints only)
//!        │                     │                        │
//!        └────────── CacheCore (one eviction engine, ───┘
//!        │           one CacheStats shape)        │
//!        ▼                     ▼                  ▼
//!   ┌─────────────────── StoreTier ───────────────────┐
//!   │  staged write-ahead ops → Temporal overlay      │
//!   │  ──commit──▶ SegmentLog (append-only, CRC'd)    │
//!   │              │ in-memory MemLayer mirror        │
//!   │              └ on-disk  store.log  (--store-dir)│
//!   └──────────────────────────────────────────────────┘
//! ```
//!
//! # The layer trait stack
//!
//! The shape follows calimero-core's storage layers: every store is a
//! [`Layer`] whose associated `Base` names the layer it composes over —
//! [`Identity`] terminates the stack. Read access is [`ReadLayer`]
//! (`has`/`get`/`for_each` over `(column, key) → value` byte slices),
//! write access is [`WriteLayer`] (`put`/`delete`). A
//! [`Temporal`](temporal::Temporal) is the write-ahead overlay in the
//! stack: `Temporal<'_, L>` has `Base = L`, buffers puts and tombstones
//! in memory, answers reads through the overlay first, and `commit()`
//! applies the net effect to its base in one deterministic sweep. The
//! in-memory terminal layer is [`MemLayer`] (`Base = Identity`); the
//! durable terminal layer is [`SegmentLog`] (`Base = MemLayer` — it *is*
//! a mem layer that also appends every committed mutation to disk).
//!
//! Keys are **typed** at the cache facades ([`keys`] has the codecs:
//! `DecisionKey{model, level, ProfileBucket}`, the reply `SegmentKey`,
//! and plan fingerprints) and byte slices below the facade line, so the
//! log, the overlay, and any future replication hook move opaque bytes.
//!
//! # Durability model
//!
//! The log is append-only: every committed `put`/`delete` becomes one
//! CRC-guarded record (see [`qpart_proto::frame::StoreRecord`]) behind
//! the same `0xB1` + little-endian length envelope discipline as the wire
//! protocol's binary frames. Replay on open:
//!
//! * a record whose CRC mismatches but whose envelope is intact is
//!   **skipped** (counted in `store_corrupt_records_total`) — corruption
//!   at rest never replays as state and never hides later records;
//! * a record that runs past end-of-file (a torn final write from a
//!   crash) marks the recovered tail: the file is truncated there and
//!   every earlier record survives.
//!
//! Background **compaction** rewrites exactly the live key set (last put
//! wins, tombstones drop) into a fresh file and atomically renames it
//! over the log, bounding disk growth to the working set.
//!
//! There are no external database dependencies — the log is a single
//! file of wire-format records.

pub mod cache;
pub mod keys;
pub mod log;
pub mod mem;
pub mod temporal;
pub mod tier;

pub use cache::{CacheCore, CacheStats, EvictPolicy};
pub use log::SegmentLog;
pub use mem::MemLayer;
pub use temporal::Temporal;
pub use tier::StoreTier;

/// A typed-key namespace in the store. Each column holds one kind of
/// entry; the `u8` code is what store records carry on disk.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Column {
    /// Memoized Algorithm-2 decisions
    /// (`DecisionKey{model, level, ProfileBucket}` → encoded `Decision`).
    Decision,
    /// Encoded segment replies
    /// (`(model, level, partition)` → binary reply body).
    Reply,
    /// Phase-2 plan fingerprints (`(model, partition)` → empty): replay
    /// pre-builds the compile cache's server-segment plans.
    Plan,
}

impl Column {
    /// Every column, in stable display order.
    pub const ALL: [Column; 3] = [Column::Decision, Column::Reply, Column::Plan];

    /// The on-disk column code.
    pub fn code(self) -> u8 {
        match self {
            Column::Decision => 1,
            Column::Reply => 2,
            Column::Plan => 3,
        }
    }

    /// Decode an on-disk column code.
    pub fn from_code(code: u8) -> Option<Column> {
        match code {
            1 => Some(Column::Decision),
            2 => Some(Column::Reply),
            3 => Some(Column::Plan),
            _ => None,
        }
    }

    /// Human-readable column name (stats documents, labels).
    pub fn label(self) -> &'static str {
        match self {
            Column::Decision => "decision",
            Column::Reply => "reply",
            Column::Plan => "plan",
        }
    }

    fn index(self) -> usize {
        match self {
            Column::Decision => 0,
            Column::Reply => 1,
            Column::Plan => 2,
        }
    }
}

/// A member of the layered store stack. `Base` names the layer this one
/// composes over (calimero-style): an overlay's `Base` is the layer its
/// commits land on; terminal layers point at [`Identity`]. The
/// association is compile-time documentation of the stack's shape — it
/// keeps "who commits into whom" explicit at every level.
pub trait Layer {
    /// The layer this one composes over ([`Identity`] when terminal).
    type Base: Layer;
}

/// The terminal base of the stack: no layer below. Uninhabited — it only
/// exists at the type level.
pub enum Identity {}

impl Layer for Identity {
    type Base = Identity;
}

/// Read access to a layer: `(column, key) → value` over byte slices.
pub trait ReadLayer: Layer {
    /// Whether `key` is live in `col`.
    fn has(&self, col: Column, key: &[u8]) -> bool;

    /// The live value of `key` in `col`, if any.
    fn get(&self, col: Column, key: &[u8]) -> Option<Vec<u8>>;

    /// Visit every live `(key, value)` of `col`. Return `false` from the
    /// visitor to stop early. Iteration order is unspecified (layers
    /// that need determinism — the log's compaction — sort internally).
    fn for_each(&self, col: Column, f: &mut dyn FnMut(&[u8], &[u8]) -> bool);

    /// Live entries in `col`.
    fn len(&self, col: Column) -> usize {
        let mut n = 0;
        self.for_each(col, &mut |_, _| {
            n += 1;
            true
        });
        n
    }

    /// Whether `col` holds no live entries.
    fn is_empty(&self, col: Column) -> bool {
        self.len(col) == 0
    }
}

/// Write access to a layer.
pub trait WriteLayer: ReadLayer {
    /// Insert or replace `key` in `col`.
    fn put(&mut self, col: Column, key: &[u8], value: &[u8]);

    /// Remove `key` from `col` (a no-op when absent).
    fn delete(&mut self, col: Column, key: &[u8]);
}

/// Extension adapters every [`WriteLayer`] gets for free.
pub trait LayerExt: WriteLayer + Sized {
    /// Open a write-ahead [`Temporal`] overlay over this layer: reads
    /// see staged state, writes buffer in memory, and
    /// [`Temporal::commit`] applies the net effect to `self`.
    fn temporal(&mut self) -> Temporal<'_, Self> {
        Temporal::new(self)
    }
}

impl<L: WriteLayer> LayerExt for L {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn column_codes_roundtrip_and_are_distinct() {
        for col in Column::ALL {
            assert_eq!(Column::from_code(col.code()), Some(col));
        }
        assert_eq!(Column::from_code(0), None);
        assert_eq!(Column::from_code(9), None);
        let labels: Vec<_> = Column::ALL.iter().map(|c| c.label()).collect();
        assert_eq!(labels, vec!["decision", "reply", "plan"]);
    }

    // the trait-stack property tests over every layer implementation
    // live in `mem`, `temporal`, and `log`
}
