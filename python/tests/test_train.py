"""Training-loop tests (uses the session-scoped tiny models)."""

import numpy as np

from compile import data as D
from compile import model as M
from compile import train as T


def test_loss_decreases(tiny_mlp6):
    h = tiny_mlp6["history"]
    assert h[-1] < h[0] * 0.5, f"loss did not drop: {h}"


def test_accuracy_beats_chance(tiny_mlp6):
    assert tiny_mlp6["acc"] > 0.6, tiny_mlp6["acc"]


def test_cnn_loss_decreases(tiny_cnn):
    h = tiny_cnn["history"]
    assert h[-1] < h[0], h


def test_training_deterministic():
    spec = M.mlp6_spec()
    x, y = D.make("digits", 256, seed=0)
    p1, h1 = T.train(spec, x, y, epochs=1, seed=9)
    p2, h2 = T.train(spec, x, y, epochs=1, seed=9)
    assert h1 == h2
    np.testing.assert_array_equal(np.asarray(p1[0]["w"]), np.asarray(p2[0]["w"]))


def test_autoencoder_reconstructs():
    rng = np.random.default_rng(0)
    # low-rank data: a rank-8 subspace the bottleneck-8 AE can capture
    basis = rng.normal(size=(8, 64)).astype(np.float32)
    coef = rng.normal(size=(400, 8)).astype(np.float32)
    h = coef @ basis
    params, losses = T.train_autoencoder(h, bottleneck=8, epochs=400, lr=1e-2, seed=0)
    # rank-8 data through a bottleneck-8 linear AE: large relative reduction
    assert losses[-1] < 0.2 * losses[0], (losses[0], losses[-1])
