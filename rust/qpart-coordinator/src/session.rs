//! Session tables for the two-phase protocol.
//!
//! Phase 1 (`infer`) opens a session remembering the chosen pattern and
//! the boundary-activation shape; phase 2 (`activation`) consumes it.
//! Tables are bounded two ways: **capacity** (oldest evicted first when a
//! shard fills) and **age** (a TTL sweep expires sessions whose device
//! never uploaded — see [`SharedSessionTable::sweep_expired`], driven by
//! the server's GC thread). Either way, devices that never came back
//! must not leak memory.
//!
//! Two layers:
//! * [`SessionTable`] — the single-threaded building block (one FIFO).
//! * [`SharedSessionTable`] — what the executor pool actually uses: the id
//!   space is global (one atomic counter) and sessions are spread over
//!   `N` mutex-protected shards keyed by `id % N`, so a session opened by
//!   one worker is visible to whichever worker receives the phase-2
//!   upload, while workers on different shards never contend.

use qpart_core::quant::QuantPattern;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One open session.
#[derive(Debug, Clone)]
pub struct Session {
    pub id: u64,
    pub model: String,
    pub pattern: QuantPattern,
    /// Expected boundary-activation dims (batch 1).
    pub boundary_dims: Vec<usize>,
    pub opened: Instant,
}

/// Bounded FIFO-evicting session table (single shard).
#[derive(Debug)]
pub struct SessionTable {
    capacity: usize,
    next_id: u64,
    /// Insertion-ordered (oldest first) — eviction pops the front.
    sessions: Vec<Session>,
    /// How many sessions were evicted under capacity pressure.
    pub evicted: u64,
    /// How many sessions were expired by the TTL sweep.
    pub expired: u64,
}

impl SessionTable {
    pub fn new(capacity: usize) -> SessionTable {
        assert!(capacity > 0);
        SessionTable { capacity, next_id: 1, sessions: Vec::new(), evicted: 0, expired: 0 }
    }

    /// Open a session with a locally assigned id; may evict the oldest.
    pub fn open(
        &mut self,
        model: &str,
        pattern: QuantPattern,
        boundary_dims: Vec<usize>,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.open_with_id(id, model, pattern, boundary_dims);
        id
    }

    /// Open a session under an externally assigned id (the sharded table
    /// owns the id space); may evict the oldest in this table.
    pub fn open_with_id(
        &mut self,
        id: u64,
        model: &str,
        pattern: QuantPattern,
        boundary_dims: Vec<usize>,
    ) {
        if self.sessions.len() >= self.capacity {
            self.sessions.remove(0);
            self.evicted += 1;
        }
        self.sessions.push(Session {
            id,
            model: model.to_string(),
            pattern,
            boundary_dims,
            opened: Instant::now(),
        });
    }

    /// Consume (remove + return) a session.
    pub fn take(&mut self, id: u64) -> Option<Session> {
        let idx = self.sessions.iter().position(|s| s.id == id)?;
        Some(self.sessions.remove(idx))
    }

    /// Non-consuming lookup.
    pub fn contains(&self, id: u64) -> bool {
        self.sessions.iter().any(|s| s.id == id)
    }

    /// Expire sessions opened at or before `now - ttl`; returns how many.
    /// Insertion order is open order, so expired sessions are a prefix.
    pub fn sweep_expired(&mut self, ttl: Duration, now: Instant) -> usize {
        let keep_from = self
            .sessions
            .iter()
            .position(|s| now.saturating_duration_since(s.opened) < ttl)
            .unwrap_or(self.sessions.len());
        self.sessions.drain(..keep_from);
        self.expired += keep_from as u64;
        keep_from
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }
}

/// Thread-safe sharded session table shared by the executor pool.
///
/// Ids are allocated from one atomic counter (monotone, unique across the
/// whole server); the owning shard is `id % shards`, so any worker can
/// resolve any session with exactly one shard lock. Total capacity is
/// split evenly across shards (rounded up), preserving the FIFO-eviction
/// bound per shard.
#[derive(Debug)]
pub struct SharedSessionTable {
    shards: Vec<Mutex<SessionTable>>,
    next_id: AtomicU64,
}

impl SharedSessionTable {
    /// `capacity` sessions total, spread over `shards` shards. Both are
    /// clamped to ≥ 1 (these arrive from user-facing config; a zero must
    /// degrade to the minimum, not panic the server). The shard count is
    /// additionally capped at `capacity` and the remainder distributed,
    /// so the per-shard capacities sum to exactly `capacity` — the
    /// operator's memory bound is never exceeded by rounding.
    pub fn new(capacity: usize, shards: usize) -> SharedSessionTable {
        let capacity = capacity.max(1);
        let shards = shards.max(1).min(capacity);
        let base = capacity / shards;
        let remainder = capacity % shards;
        SharedSessionTable {
            shards: (0..shards)
                .map(|i| Mutex::new(SessionTable::new(base + usize::from(i < remainder))))
                .collect(),
            next_id: AtomicU64::new(1),
        }
    }

    fn shard(&self, id: u64) -> &Mutex<SessionTable> {
        &self.shards[(id % self.shards.len() as u64) as usize]
    }

    /// Open a session; returns the globally unique id.
    pub fn open(&self, model: &str, pattern: QuantPattern, boundary_dims: Vec<usize>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shard(id).lock().unwrap().open_with_id(id, model, pattern, boundary_dims);
        id
    }

    /// Consume (remove + return) a session from its shard.
    pub fn take(&self, id: u64) -> Option<Session> {
        self.shard(id).lock().unwrap().take(id)
    }

    /// Non-consuming lookup.
    pub fn contains(&self, id: u64) -> bool {
        self.shard(id).lock().unwrap().contains(id)
    }

    /// Open sessions across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total sessions evicted (capacity pressure) across all shards.
    pub fn evicted(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().evicted).sum()
    }

    /// Total sessions expired by TTL sweeps across all shards.
    pub fn expired(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().expired).sum()
    }

    /// Expire sessions older than `ttl` in every shard; returns how many.
    /// One shard is locked at a time, so sweeps never stall the pool.
    pub fn sweep_expired(&self, ttl: Duration) -> usize {
        let now = Instant::now();
        self.shards.iter().map(|s| s.lock().unwrap().sweep_expired(ttl, now)).sum()
    }

    /// Open sessions per shard (stats: load-balance observability).
    pub fn shard_occupancy(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.lock().unwrap().len()).collect()
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::Arc;

    fn pat(p: usize) -> QuantPattern {
        QuantPattern {
            partition: p,
            weight_bits: vec![8; p],
            activation_bits: 8,
            accuracy_level: 0.01,
            predicted_degradation: 0.0,
        }
    }

    #[test]
    fn open_take_roundtrip() {
        let mut t = SessionTable::new(4);
        let id = t.open("mlp6", pat(2), vec![1, 256]);
        assert_eq!(t.len(), 1);
        let s = t.take(id).unwrap();
        assert_eq!(s.model, "mlp6");
        assert_eq!(s.boundary_dims, vec![1, 256]);
        assert!(t.take(id).is_none(), "consumed");
        assert!(t.is_empty());
    }

    #[test]
    fn ids_unique_and_monotone() {
        let mut t = SessionTable::new(8);
        let a = t.open("m", pat(0), vec![1, 784]);
        let b = t.open("m", pat(0), vec![1, 784]);
        assert!(b > a);
    }

    #[test]
    fn capacity_evicts_oldest() {
        let mut t = SessionTable::new(2);
        let a = t.open("m", pat(0), vec![1]);
        let b = t.open("m", pat(0), vec![1]);
        let c = t.open("m", pat(0), vec![1]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.evicted, 1);
        assert!(t.take(a).is_none(), "oldest evicted");
        assert!(t.take(b).is_some());
        assert!(t.take(c).is_some());
    }

    #[test]
    fn sharded_open_take_roundtrip() {
        let t = SharedSessionTable::new(64, 4);
        assert_eq!(t.num_shards(), 4);
        let id = t.open("mlp6", pat(2), vec![1, 256]);
        assert!(t.contains(id));
        assert_eq!(t.len(), 1);
        let s = t.take(id).unwrap();
        assert_eq!(s.id, id);
        assert_eq!(s.boundary_dims, vec![1, 256]);
        assert!(t.take(id).is_none(), "consumed");
        assert!(t.is_empty());
    }

    #[test]
    fn sharded_zero_config_degrades_to_minimum() {
        // user-facing knobs (--sessions 0, workers 0) must not panic the
        // server: both clamp to 1.
        let t = SharedSessionTable::new(0, 0);
        assert_eq!(t.num_shards(), 1);
        let a = t.open("m", pat(0), vec![1]);
        let b = t.open("m", pat(0), vec![1]);
        assert_eq!(t.len(), 1, "capacity clamps to 1");
        assert!(t.take(a).is_none(), "evicted");
        assert!(t.take(b).is_some());
    }

    #[test]
    fn sharded_ids_unique_across_shards() {
        let t = SharedSessionTable::new(1024, 7);
        let mut seen = HashSet::new();
        for _ in 0..500 {
            let id = t.open("m", pat(0), vec![1]);
            assert!(seen.insert(id), "duplicate id {id}");
        }
        assert_eq!(t.len(), 500);
    }

    #[test]
    fn sharded_capacity_evicts_oldest_per_shard() {
        // 1 shard makes the global FIFO order observable.
        let t = SharedSessionTable::new(2, 1);
        let a = t.open("m", pat(0), vec![1]);
        let b = t.open("m", pat(0), vec![1]);
        let c = t.open("m", pat(0), vec![1]);
        assert_eq!(t.len(), 2);
        assert_eq!(t.evicted(), 1);
        assert!(t.take(a).is_none(), "oldest evicted first");
        assert!(t.take(b).is_some());
        assert!(t.take(c).is_some());

        // multi-shard: capacity bounds the total exactly; eviction follows
        // insertion order within each shard.
        let t = SharedSessionTable::new(8, 4);
        let ids: Vec<u64> = (0..40).map(|_| t.open("m", pat(0), vec![1])).collect();
        assert_eq!(t.len(), 8, "per-shard capacities sum to the configured total");
        assert_eq!(t.evicted() as usize + t.len(), ids.len());
        // the newest session in every shard must have survived
        for id in ids.iter().rev().take(4) {
            assert!(t.contains(*id), "newest sessions evicted");
        }
    }

    #[test]
    fn sharded_capacity_is_exact_under_uneven_division() {
        // 65 sessions over 64 shards must NOT round up to 128 resident
        let t = SharedSessionTable::new(65, 64);
        assert_eq!(t.num_shards(), 64);
        for _ in 0..1000 {
            t.open("m", pat(0), vec![1]);
        }
        assert_eq!(t.len(), 65, "configured bound is exact");
        // more shards than capacity: shard count is capped, not inflated
        let t = SharedSessionTable::new(2, 64);
        assert_eq!(t.num_shards(), 2);
        for _ in 0..10 {
            t.open("m", pat(0), vec![1]);
        }
        assert_eq!(t.len(), 2);
    }

    #[test]
    fn ttl_sweep_expires_only_old_sessions() {
        let mut t = SessionTable::new(8);
        let a = t.open("m", pat(0), vec![1]);
        let b = t.open("m", pat(0), vec![1]);
        // ttl = 0: everything already open is expired
        let n = t.sweep_expired(Duration::ZERO, Instant::now());
        assert_eq!(n, 2);
        assert_eq!(t.expired, 2);
        assert!(t.is_empty());
        assert!(t.take(a).is_none());
        assert!(t.take(b).is_none());
        // a generous ttl expires nothing
        let c = t.open("m", pat(0), vec![1]);
        assert_eq!(t.sweep_expired(Duration::from_secs(3600), Instant::now()), 0);
        assert_eq!(t.expired, 2);
        assert!(t.take(c).is_some());
    }

    #[test]
    fn sharded_ttl_sweep_and_occupancy() {
        let t = SharedSessionTable::new(64, 4);
        for _ in 0..10 {
            t.open("m", pat(0), vec![1]);
        }
        let occ = t.shard_occupancy();
        assert_eq!(occ.len(), 4);
        assert_eq!(occ.iter().sum::<usize>(), 10);
        assert_eq!(t.sweep_expired(Duration::from_secs(3600)), 0, "fresh sessions stay");
        assert_eq!(t.len(), 10);
        let swept = t.sweep_expired(Duration::ZERO);
        assert_eq!(swept, 10);
        assert_eq!(t.expired(), 10);
        assert!(t.is_empty());
        assert_eq!(t.shard_occupancy().iter().sum::<usize>(), 0);
        // expiry (TTL) and eviction (capacity) are separate counters
        assert_eq!(t.evicted(), 0);
    }

    #[test]
    fn sharded_concurrent_open_lookup_evict() {
        let t = Arc::new(SharedSessionTable::new(256, 8));
        let threads = 8;
        let per_thread = 200;
        let mut handles = Vec::new();
        for k in 0..threads {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                let mut ids = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    let id = t.open("m", pat(i % 3), vec![1, 16]);
                    ids.push(id);
                    // every other open, consume one of our own sessions
                    // (it may have been evicted under pressure — both
                    // outcomes are legal, but no panic and no cross-talk)
                    if i % 2 == k % 2 {
                        if let Some(s) = t.take(ids[i / 2]) {
                            assert_eq!(s.id, ids[i / 2]);
                        }
                    }
                }
                ids
            }));
        }
        let mut all_ids = HashSet::new();
        for h in handles {
            for id in h.join().unwrap() {
                assert!(all_ids.insert(id), "duplicate id across threads: {id}");
            }
        }
        assert_eq!(all_ids.len(), threads * per_thread);
        // conservation: everything opened was either taken, evicted, or is
        // still resident.
        assert!(t.len() <= 256, "capacity respected: {}", t.len());
    }
}
