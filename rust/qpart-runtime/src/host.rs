//! Host reference kernels: a pure-Rust f32 forward pass for server
//! segments.
//!
//! The offline build ships an `xla` stub whose PJRT client cannot
//! compile, so phase-2 execution historically needed `make artifacts` on
//! a machine with the JAX/XLA toolchain. These kernels implement the same
//! math as the lowered `f32layer` executables (`x·W + b`, optional ReLU,
//! optional residual add — see `python/compile/aot.py::lower_f32layer`)
//! for **linear** layers, so the coordinator's batched phase-2 path,
//! its tests, and `qpart bench-serve` can run end to end with no PJRT.
//!
//! Scope: linear architectures only (the synthetic `tinymlp` bundle and
//! the mlp models). Convolution layers report a clear error directing to
//! the PJRT artifacts. Enabled explicitly via
//! [`crate::Executor::set_host_fallback`] — never silently, so a
//! production build can't mask a missing PJRT backend.
//!
//! Determinism: each output row accumulates independently in input order,
//! so a row's result is bit-identical whether it runs alone or stacked in
//! a padded batch — the property the batched-vs-sequential equivalence
//! tests assert.

use crate::bundle::ModelWeights;
use crate::engine::HostTensor;
use crate::error::{Error, Result};
use qpart_core::model::{LayerKind, ModelSpec};
use std::collections::HashMap;

/// Run f32 layers `start+1..=end` of `arch` on `h` (any batch size).
pub fn run_layers(
    arch: &ModelSpec,
    weights: &ModelWeights,
    h: HostTensor,
    start: usize,
    end: usize,
) -> Result<HostTensor> {
    let mut h = h;
    let mut acts: HashMap<usize, HostTensor> = HashMap::new();
    acts.insert(start, h.clone());
    for l in (start + 1)..=end {
        let layer = &arch.layers[l - 1];
        let (d_in, d_out) = match layer.kind {
            LayerKind::Linear { d_in, d_out } => (d_in, d_out),
            LayerKind::Conv2d { .. } => {
                return Err(Error::Shape(format!(
                    "host reference kernels support linear layers only \
                     (layer {l} of {} is conv2d); run `make artifacts` and \
                     use the PJRT executables for conv architectures",
                    arch.name
                )))
            }
        };
        if h.row_elems() != d_in {
            return Err(Error::Shape(format!(
                "layer {l} expects {d_in} inputs, activation has {}",
                h.row_elems()
            )));
        }
        let batch = h.batch();
        let w = weights.flat_w(l)?;
        let wd = w.data();
        let bd = weights.bias(l).data();
        if w.dims() != &[d_in, d_out] || bd.len() != d_out {
            return Err(Error::Shape(format!(
                "layer {l}: weights {:?} / bias {} do not match spec {d_in}x{d_out}",
                w.dims(),
                bd.len()
            )));
        }
        let mut out = vec![0.0f32; batch * d_out];
        for (xrow, orow) in h.data.chunks_exact(d_in).zip(out.chunks_exact_mut(d_out)) {
            orow.copy_from_slice(bd);
            for (i, &xi) in xrow.iter().enumerate() {
                if xi != 0.0 {
                    let wrow = &wd[i * d_out..(i + 1) * d_out];
                    for (o, &wj) in orow.iter_mut().zip(wrow) {
                        *o += xi * wj;
                    }
                }
            }
            if layer.relu {
                for o in orow.iter_mut() {
                    if *o < 0.0 {
                        *o = 0.0;
                    }
                }
            }
        }
        // residual add AFTER the activation, matching the lowered
        // `qlinear(...) + skip` ordering
        if let Some(src) = arch.residual_source(l) {
            let skip = acts
                .get(&src)
                .ok_or_else(|| Error::Shape(format!("skip source {src} unavailable")))?;
            if skip.data.len() != out.len() {
                return Err(Error::Shape(format!(
                    "layer {l}: skip has {} elements, output has {}",
                    skip.data.len(),
                    out.len()
                )));
            }
            for (o, &s) in out.iter_mut().zip(&skip.data) {
                *o += s;
            }
        }
        h = HostTensor::new(vec![batch, d_out], out)?;
        acts.insert(l, h.clone());
    }
    Ok(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::model::LayerSpec;
    use qpart_core::tensor::Tensor;

    fn lin(name: &str, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
        LayerSpec { name: name.into(), kind: LayerKind::Linear { d_in, d_out }, relu }
    }

    fn toy() -> (ModelSpec, ModelWeights) {
        let arch =
            ModelSpec::new("toy", vec![lin("fc1", 2, 2, true), lin("fc2", 2, 1, false)], 1)
                .unwrap();
        let weights = ModelWeights {
            layers: vec![
                (
                    Tensor::new(vec![2, 2], vec![1.0, -1.0, 2.0, 1.0]).unwrap(),
                    Tensor::new(vec![2], vec![0.5, -0.5]).unwrap(),
                ),
                (
                    Tensor::new(vec![2, 1], vec![1.0, -2.0]).unwrap(),
                    Tensor::new(vec![1], vec![0.25]).unwrap(),
                ),
            ],
        };
        (arch, weights)
    }

    #[test]
    fn forward_matches_hand_computation() {
        let (arch, w) = toy();
        // x = [1, 2]: fc1 pre-act = [1*1+2*2+0.5, 1*(-1)+2*1-0.5] = [5.5, 0.5]
        // relu → [5.5, 0.5]; fc2 = 5.5*1 + 0.5*(-2) + 0.25 = 4.75
        let x = HostTensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
        let y = run_layers(&arch, &w, x, 0, 2).unwrap();
        assert_eq!(y.dims, vec![1, 1]);
        assert!((y.data[0] - 4.75).abs() < 1e-6, "{}", y.data[0]);
    }

    #[test]
    fn relu_clamps_negative_preactivations() {
        let (arch, w) = toy();
        // x = [-1, 0]: fc1 pre-act = [-1+0.5, 1-0.5] = [-0.5, 0.5] → relu [0, 0.5]
        // fc2 = 0*1 + 0.5*(-2) + 0.25 = -0.75 (no relu on the last layer)
        let x = HostTensor::new(vec![1, 2], vec![-1.0, 0.0]).unwrap();
        let y = run_layers(&arch, &w, x, 0, 2).unwrap();
        assert!((y.data[0] + 0.75).abs() < 1e-6, "{}", y.data[0]);
    }

    #[test]
    fn batched_rows_equal_single_rows() {
        let (arch, w) = toy();
        let rows = [vec![1.0f32, 2.0], vec![-1.0, 0.5], vec![0.0, 0.0], vec![3.0, -4.0]];
        let stacked = HostTensor::new(
            vec![rows.len(), 2],
            rows.iter().flatten().copied().collect(),
        )
        .unwrap();
        let batched = run_layers(&arch, &w, stacked, 0, 2).unwrap();
        for (i, r) in rows.iter().enumerate() {
            let single = run_layers(
                &arch,
                &w,
                HostTensor::new(vec![1, 2], r.clone()).unwrap(),
                0,
                2,
            )
            .unwrap();
            assert_eq!(single.data[0], batched.data[i], "row {i} must be bit-identical");
        }
    }

    #[test]
    fn partial_segment_starts_mid_model() {
        let (arch, w) = toy();
        // start = 1: run only fc2 on a boundary activation
        let h = HostTensor::new(vec![1, 2], vec![2.0, 1.0]).unwrap();
        let y = run_layers(&arch, &w, h, 1, 2).unwrap();
        assert!((y.data[0] - (2.0 - 2.0 + 0.25)).abs() < 1e-6);
    }

    #[test]
    fn conv_layers_are_rejected_clearly() {
        let arch = ModelSpec::new(
            "convy",
            vec![LayerSpec {
                name: "c1".into(),
                kind: LayerKind::Conv2d {
                    c_in: 1,
                    c_out: 2,
                    k: 3,
                    stride: 1,
                    in_side: 8,
                    out_side: 8,
                },
                relu: true,
            }],
            2,
        )
        .unwrap();
        let w = ModelWeights {
            layers: vec![(Tensor::zeros(vec![1, 3, 3, 2]), Tensor::zeros(vec![2]))],
        };
        let x = HostTensor::zeros(vec![1, 1, 8, 8]);
        let err = run_layers(&arch, &w, x, 0, 1).unwrap_err();
        assert!(err.to_string().contains("linear layers only"), "{err}");
    }
}
