//! Calibration tables: per-layer noise scales `s_l` and robustness
//! parameters `ρ_l(a)` for a set of accuracy-degradation levels.
//!
//! Produced offline by `python/compile/calibrate.py` (paper Algorithm 1
//! lines 7–10: inject noise, bisect the threshold where degradation hits
//! `a`, fit `s_l` from measured quantization-noise energies) and consumed
//! by the Rust closed-form solver.

use super::{noise_energy, psi};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::model::ModelSpec;
use crate::quant::QuantPattern;

/// Per-source calibration: noise scale `s` (level-independent) and
/// robustness `ρ(a_k)` per accuracy level `k`.
#[derive(Debug, Clone, PartialEq)]
pub struct SourceCalib {
    /// `s` of Eq. 18/19: `‖σ‖² = s · 4^{−b}`.
    pub s: f64,
    /// `ρ(a_k)` of Eq. 22, one per level, same order as the table's levels.
    pub rho: Vec<f64>,
}

/// Calibration for one model.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationTable {
    pub model: String,
    /// Accuracy-degradation levels `a_1 < a_2 < …` (fractions).
    pub levels: Vec<f64>,
    /// Weight calibration per layer `l ∈ 1..=L` (index `l-1`).
    pub weight: Vec<SourceCalib>,
    /// Activation calibration per boundary `l ∈ 0..=L` (index `l`).
    pub activation: Vec<SourceCalib>,
}

impl CalibrationTable {
    /// Number of learnable layers covered.
    pub fn num_layers(&self) -> usize {
        self.weight.len()
    }

    /// `s_l^w` for layer `l ∈ 1..=L`.
    pub fn s_w(&self, l: usize) -> f64 {
        self.weight[l - 1].s
    }

    /// `ρ_l^w(a_k)` for layer `l ∈ 1..=L`, level index `k`.
    pub fn rho_w(&self, l: usize, k: usize) -> f64 {
        self.weight[l - 1].rho[k]
    }

    /// `s^x` for the activation at boundary `l ∈ 0..=L`.
    pub fn s_x(&self, l: usize) -> f64 {
        self.activation[l].s
    }

    /// `ρ^x(a_k)` at boundary `l ∈ 0..=L`.
    pub fn rho_x(&self, l: usize, k: usize) -> f64 {
        self.activation[l].rho[k]
    }

    /// ψ contribution of quantizing layer `l`'s weights at `bits` (Eq. 20).
    pub fn psi_w(&self, l: usize, bits: f64, k: usize) -> f64 {
        psi(self.s_w(l), bits, self.rho_w(l, k))
    }

    /// ψ contribution of the boundary activation (Eq. 21).
    pub fn psi_x(&self, l: usize, bits: f64, k: usize) -> f64 {
        psi(self.s_x(l), bits, self.rho_x(l, k))
    }

    /// Total ψ of a pattern (constraint LHS of Eq. 23, with Δ = 1):
    /// `ψ_x(p) + Σ_{l=1..p} ψ_l^w`.
    pub fn pattern_psi(&self, pattern: &QuantPattern, k: usize) -> f64 {
        let mut total = self.psi_x(pattern.partition, pattern.activation_bits as f64, k);
        for (i, &b) in pattern.weight_bits.iter().enumerate() {
            total += self.psi_w(i + 1, b as f64, k);
        }
        total
    }

    /// Predicted accuracy degradation of a pattern at level `k`:
    /// `a_k · Σψ` (ψ is calibrated so that Σψ = 1 ⟺ degradation = a_k).
    pub fn predicted_degradation(&self, pattern: &QuantPattern, k: usize) -> f64 {
        self.levels[k] * self.pattern_psi(pattern, k)
    }

    /// Total output-noise energy of a pattern (for diagnostics).
    pub fn pattern_noise_energy(&self, pattern: &QuantPattern) -> f64 {
        let mut total = noise_energy(self.s_x(pattern.partition), pattern.activation_bits as f64);
        for (i, &b) in pattern.weight_bits.iter().enumerate() {
            total += noise_energy(self.s_w(i + 1), b as f64);
        }
        total
    }

    /// Structural check against a model descriptor.
    pub fn validate(&self, model: &ModelSpec) -> Result<()> {
        let l = model.num_layers();
        if self.weight.len() != l {
            return Err(Error::InvalidArg(format!(
                "calibration has {} weight entries, model '{}' has {l} layers",
                self.weight.len(),
                model.name
            )));
        }
        if self.activation.len() != l + 1 {
            return Err(Error::InvalidArg(format!(
                "calibration has {} activation entries, expected {}",
                self.activation.len(),
                l + 1
            )));
        }
        let nk = self.levels.len();
        if nk == 0 {
            return Err(Error::InvalidArg("calibration has no levels".into()));
        }
        if self.levels.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::InvalidArg("levels must be strictly ascending".into()));
        }
        for (i, c) in self.weight.iter().chain(self.activation.iter()).enumerate() {
            if c.rho.len() != nk {
                return Err(Error::InvalidArg(format!("entry {i}: rho count != level count")));
            }
            if c.s <= 0.0 || !c.s.is_finite() {
                return Err(Error::InvalidArg(format!("entry {i}: s must be positive")));
            }
            if c.rho.iter().any(|&r| r <= 0.0 || !r.is_finite()) {
                return Err(Error::InvalidArg(format!("entry {i}: rho must be positive")));
            }
        }
        Ok(())
    }

    /// Deterministic plausible calibration for descriptor-only experiments
    /// (ResNet Table IV payload columns, cost-figure simulations) and tests.
    ///
    /// Heuristics encoded (matching what real calibrations show):
    /// * `s_l` grows with the layer's parameter count (more quantized values
    ///   → more injected energy) and shrinks with depth (noise injected
    ///   close to the output passes through fewer contractive layers — but
    ///   the final logits are touchy, so the last layer bumps up again);
    /// * `ρ(a)` scales linearly with `a` (twice the tolerated degradation ≈
    ///   twice the tolerable noise energy, the linearity the paper's metric
    ///   assumes).
    pub fn synthetic(model: &ModelSpec, levels: &[f64], seed: u64) -> CalibrationTable {
        use crate::rng::Rng;
        let mut rng = Rng::new(seed ^ 0x5EED_CA11_B0B0);
        let l = model.num_layers();
        let mut weight = Vec::with_capacity(l);
        for i in 1..=l {
            let z = model.weight_params(i) as f64;
            let depth_factor = 1.0 / (1.0 + 0.35 * (i as f64 - 1.0));
            let last_bump = if i == l { 2.0 } else { 1.0 };
            let jitter = 0.8 + 0.4 * rng.uniform();
            // per-parameter unit-range quantization noise ≈ range²/12 · z,
            // attenuated by the network gain to the output
            let s = z * (1.0 / 12.0) * depth_factor * last_bump * jitter;
            let rho = levels.iter().map(|&a| a * 120.0 * (0.9 + 0.2 * rng.uniform())).collect();
            weight.push(SourceCalib { s, rho });
        }
        let mut activation = Vec::with_capacity(l + 1);
        for i in 0..=l {
            let z = model.activation_elems(i) as f64;
            let depth_factor = 1.0 / (1.0 + 0.25 * i as f64);
            let jitter = 0.8 + 0.4 * rng.uniform();
            let s = z * (1.0 / 12.0) * depth_factor * jitter;
            let rho = levels.iter().map(|&a| a * 120.0 * (0.9 + 0.2 * rng.uniform())).collect();
            activation.push(SourceCalib { s, rho });
        }
        CalibrationTable { model: model.name.clone(), levels: levels.to_vec(), weight, activation }
    }

    // ----- JSON (calibration.json) -----

    pub fn to_json(&self) -> Value {
        let src = |c: &SourceCalib| {
            Value::obj([("s", c.s.into()), ("rho", Value::num_arr(&c.rho))])
        };
        Value::obj([
            ("model", self.model.as_str().into()),
            ("levels", Value::num_arr(&self.levels)),
            ("weight", Value::Arr(self.weight.iter().map(src).collect())),
            ("activation", Value::Arr(self.activation.iter().map(src).collect())),
        ])
    }

    pub fn from_json(v: &Value) -> Result<CalibrationTable> {
        let src = |x: &Value| -> Result<SourceCalib> {
            Ok(SourceCalib { s: x.req_f64("s")?, rho: x.req_f64_arr("rho")? })
        };
        Ok(CalibrationTable {
            model: v.req_str("model")?.to_string(),
            levels: v.req_f64_arr("levels")?,
            weight: v.req_arr("weight")?.iter().map(src).collect::<Result<_>>()?,
            activation: v.req_arr("activation")?.iter().map(src).collect::<Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp6;

    const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

    #[test]
    fn synthetic_validates() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 1);
        c.validate(&m).unwrap();
        assert_eq!(c.num_layers(), 6);
    }

    #[test]
    fn synthetic_deterministic() {
        let m = mlp6();
        assert_eq!(
            CalibrationTable::synthetic(&m, &LEVELS, 7),
            CalibrationTable::synthetic(&m, &LEVELS, 7)
        );
        assert_ne!(
            CalibrationTable::synthetic(&m, &LEVELS, 7),
            CalibrationTable::synthetic(&m, &LEVELS, 8)
        );
    }

    #[test]
    fn rho_increases_with_level() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 2);
        for l in 1..=6 {
            for k in 1..LEVELS.len() {
                assert!(c.rho_w(l, k) > c.rho_w(l, k - 1), "rho must grow with tolerance");
            }
        }
    }

    #[test]
    fn pattern_psi_additive() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 3);
        let p2 = QuantPattern {
            partition: 2,
            weight_bits: vec![8, 8],
            activation_bits: 8,
            accuracy_level: 0.01,
            predicted_degradation: 0.0,
        };
        let manual = c.psi_w(1, 8.0, 2) + c.psi_w(2, 8.0, 2) + c.psi_x(2, 8.0, 2);
        assert!((c.pattern_psi(&p2, 2) - manual).abs() < 1e-12);
    }

    #[test]
    fn more_bits_less_psi() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 4);
        let mk = |b: u8| QuantPattern {
            partition: 3,
            weight_bits: vec![b; 3],
            activation_bits: b,
            accuracy_level: 0.01,
            predicted_degradation: 0.0,
        };
        assert!(c.pattern_psi(&mk(4), 2) > c.pattern_psi(&mk(8), 2));
    }

    #[test]
    fn json_roundtrip() {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 5);
        let v = c.to_json();
        let text = v.to_string_pretty();
        let back = CalibrationTable::from_json(&crate::json::parse(&text).unwrap()).unwrap();
        // f64 → shortest-round-trip text → f64 is exact
        assert_eq!(back, c);
    }

    #[test]
    fn validate_rejects_malformed() {
        let m = mlp6();
        let mut c = CalibrationTable::synthetic(&m, &LEVELS, 6);
        c.weight.pop();
        assert!(c.validate(&m).is_err());

        let mut c2 = CalibrationTable::synthetic(&m, &LEVELS, 6);
        c2.levels = vec![0.01, 0.01];
        assert!(c2.validate(&m).is_err());

        let mut c3 = CalibrationTable::synthetic(&m, &LEVELS, 6);
        c3.weight[0].s = -1.0;
        assert!(c3.validate(&m).is_err());
    }
}
