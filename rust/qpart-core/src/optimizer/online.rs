//! Paper **Algorithm 2** — Online Inference Serving.
//!
//! Per request: (1) pick the largest offline accuracy level not exceeding
//! the request's budget `a`; (2) evaluate the Eq. 17 objective for every
//! partition point under the request's *live* device/channel parameters;
//! (3) return the minimizing `(b, p)` pattern. The device memory capacity
//! acts as a feasibility filter (§III constraint).

use crate::cost::{CostBreakdown, CostModel};
use crate::error::{Error, Result};
use crate::model::ModelSpec;
use crate::quant::{PatternSet, QuantPattern};

/// The per-request parameters Algorithm 2 needs (the tuple of paper
/// Algorithm 2's Require line: device profile, channel, weights arrive in
/// [`CostModel`]; `a` is the accuracy-degradation budget).
#[derive(Debug, Clone, Copy)]
pub struct RequestParams {
    pub cost: CostModel,
    /// Maximum acceptable accuracy degradation (fraction).
    pub accuracy_budget: f64,
}

/// The serving decision for one request.
#[derive(Debug, Clone)]
pub struct Decision {
    /// Chosen pattern (owned copy — callers ship it to the device).
    pub pattern: QuantPattern,
    /// Index of the accuracy level used.
    pub level_idx: usize,
    /// Objective breakdown at the chosen partition.
    pub cost: CostBreakdown,
    /// Objective value per candidate partition (diagnostics / Fig. 7).
    /// Empty when the decision came from [`serve_request_fast`] — the
    /// serving path never reads it, so it skips the per-request
    /// allocation.
    pub objective_by_partition: Vec<f64>,
}

/// Run Algorithm 2 against an offline pattern set, keeping the full
/// per-partition objective vector (diagnostics / the Fig. 7 benches).
pub fn serve_request(
    model: &ModelSpec,
    patterns: &PatternSet,
    req: &RequestParams,
) -> Result<Decision> {
    serve_request_impl(model, patterns, req, true)
}

/// [`serve_request`] without diagnostics: identical level selection,
/// memory filtering, and argmin (same pattern, level, and cost breakdown
/// — property-tested), but `objective_by_partition` stays empty, so the
/// hot serving path allocates nothing it never reads.
pub fn serve_request_fast(
    model: &ModelSpec,
    patterns: &PatternSet,
    req: &RequestParams,
) -> Result<Decision> {
    serve_request_impl(model, patterns, req, false)
}

fn serve_request_impl(
    model: &ModelSpec,
    patterns: &PatternSet,
    req: &RequestParams,
    diagnostics: bool,
) -> Result<Decision> {
    if patterns.model != model.name {
        return Err(Error::InvalidArg(format!(
            "pattern set is for '{}', model is '{}'",
            patterns.model, model.name
        )));
    }
    // line 1: a* = max level ≤ a
    let level_idx = patterns.select_level(req.accuracy_budget)?;
    let row = &patterns.patterns[level_idx];
    if row.is_empty() {
        return Err(Error::NotFound("pattern set has no partitions".into()));
    }

    // lines 2–5: evaluate the objective at every allowed partition point
    let mut objective_by_partition = Vec::with_capacity(if diagnostics { row.len() } else { 0 });
    let mut best: Option<(usize, CostBreakdown)> = None;
    for (idx, pat) in row.iter().enumerate() {
        // Eq. 14 payload is a pure function of the pattern; the offline
        // pass precomputed it (like the segment bits below) so the
        // per-request cost is one table read, not an O(layers) sum. Sets
        // deserialized without a model fall back to summing.
        let payload = patterns
            .payload_bits_at(level_idx, idx)
            .unwrap_or_else(|| pat.payload_bits(model));
        let breakdown = req.cost.evaluate(model, pat.partition, payload);
        if diagnostics {
            objective_by_partition.push(breakdown.objective);
        }
        // memory constraint: the quantized segment must fit the device.
        // The segment size is a pure function of the pattern, so the
        // offline pass precomputed it; only sets deserialized without a
        // model (empty table) fall back to summing here.
        let segment_bits = patterns
            .segment_bits_at(level_idx, idx)
            .unwrap_or_else(|| pat.segment_bits(model));
        if !req.cost.fits_memory(segment_bits) {
            continue;
        }
        match &best {
            Some((_, cur)) if cur.objective <= breakdown.objective => {}
            _ => best = Some((idx, breakdown)),
        }
    }
    let (best_idx, cost) = best.ok_or_else(|| {
        Error::Infeasible("no partition fits the device memory capacity".into())
    })?;
    Ok(Decision { pattern: row[best_idx].clone(), level_idx, cost, objective_by_partition })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::CalibrationTable;
    use crate::channel::Channel;
    use crate::model::mlp6;
    use crate::optimizer::{offline_quantize, OfflineConfig};

    const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

    fn setup() -> (crate::model::ModelSpec, PatternSet) {
        let m = mlp6();
        let c = CalibrationTable::synthetic(&m, &LEVELS, 31);
        let set = offline_quantize(&m, &c, OfflineConfig::default()).unwrap();
        (m, set)
    }

    fn req(a: f64) -> RequestParams {
        RequestParams { cost: CostModel::paper_default(), accuracy_budget: a }
    }

    #[test]
    fn decision_minimizes_objective() {
        let (m, set) = setup();
        let d = serve_request(&m, &set, &req(0.01)).unwrap();
        let min = d
            .objective_by_partition
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!((d.cost.objective - min).abs() <= 1e-12 * min.abs().max(1.0));
        assert_eq!(d.objective_by_partition.len(), m.num_layers() + 1);
    }

    #[test]
    fn level_selection_respects_budget() {
        let (m, set) = setup();
        let d = serve_request(&m, &set, &req(0.012)).unwrap();
        assert_eq!(d.level_idx, 2); // 0.01 is the largest ≤ 0.012
        assert!(d.pattern.accuracy_level <= 0.012);
        assert!(serve_request(&m, &set, &req(0.0001)).is_err());
    }

    #[test]
    fn slow_channel_pushes_partition_to_server_side() {
        // With a very slow channel, shipping weights is expensive; the raw
        // input (small) should win → partition 0.
        let (m, set) = setup();
        let mut r = req(0.05);
        r.cost.channel = Channel::fixed(10e3, 1.0); // 10 kbps
        let d = serve_request(&m, &set, &r).unwrap();
        assert_eq!(d.pattern.partition, 0, "slow link should avoid weight shipping");
    }

    #[test]
    fn pricey_server_pushes_work_to_device() {
        let (m, set) = setup();
        let mut cheap = req(0.05);
        cheap.cost.server.price_per_s = 0.0;
        let d_cheap = serve_request(&m, &set, &cheap).unwrap();

        let mut pricey = req(0.05);
        pricey.cost.server.price_per_s = 1e4;
        pricey.cost.weights.eta = 1.0;
        let d_pricey = serve_request(&m, &set, &pricey).unwrap();
        assert!(
            d_pricey.pattern.partition >= d_cheap.pattern.partition,
            "expensive server must not decrease local work ({} vs {})",
            d_pricey.pattern.partition,
            d_cheap.pattern.partition
        );
    }

    #[test]
    fn memory_constraint_filters_partitions() {
        let (m, set) = setup();
        let mut r = req(0.05);
        r.cost.device.memory_bits = 1; // nothing fits except p=0 (empty segment)
        let d = serve_request(&m, &set, &r).unwrap();
        assert_eq!(d.pattern.partition, 0);
    }

    #[test]
    fn precomputed_and_fallback_memory_filters_agree() {
        // Algorithm 1 fills the segment-bits table; a set deserialized
        // without a model (empty table) must decide identically via the
        // per-pattern fallback.
        let (m, set) = setup();
        assert_eq!(set.segment_bits.len(), set.levels.len(), "offline pass fills the table");
        let mut stripped = set.clone();
        stripped.segment_bits = Vec::new();
        for budget in [0.0025, 0.01, 0.05] {
            let mut r = req(budget);
            // a capacity that rules out the deepest partitions
            r.cost.device.memory_bits = 2_000_000;
            let a = serve_request(&m, &set, &r).unwrap();
            let b = serve_request(&m, &stripped, &r).unwrap();
            assert_eq!(a.pattern, b.pattern, "budget {budget}");
            assert_eq!(a.level_idx, b.level_idx);
        }
    }

    #[test]
    fn precomputed_and_fallback_payload_tables_agree() {
        // Mirror of the memory-filter agreement test for the Eq. 14
        // payload table: a deserialized set (empty table) must produce
        // identical decisions and objective values via the per-pattern
        // fallback sum.
        let (m, set) = setup();
        assert_eq!(set.payload_bits.len(), set.levels.len(), "offline pass fills the table");
        let mut stripped = set.clone();
        stripped.payload_bits = Vec::new();
        for budget in [0.0025, 0.01, 0.05] {
            let r = req(budget);
            let a = serve_request(&m, &set, &r).unwrap();
            let b = serve_request(&m, &stripped, &r).unwrap();
            assert_eq!(a.pattern, b.pattern, "budget {budget}");
            assert_eq!(a.level_idx, b.level_idx);
            assert_eq!(a.cost.objective, b.cost.objective, "budget {budget}");
            assert_eq!(a.objective_by_partition, b.objective_by_partition);
        }
    }

    #[test]
    fn fast_path_matches_full_decision() {
        // serve_request_fast must make the same decision as serve_request
        // in every respect except the diagnostics vector.
        let (m, set) = setup();
        for budget in [0.0025, 0.005, 0.01, 0.02, 0.05] {
            for memory_bits in [u64::MAX, 2_000_000, 1] {
                let mut r = req(budget);
                r.cost.device.memory_bits = memory_bits;
                let full = serve_request(&m, &set, &r).unwrap();
                let fast = serve_request_fast(&m, &set, &r).unwrap();
                assert_eq!(fast.pattern, full.pattern, "budget {budget}");
                assert_eq!(fast.level_idx, full.level_idx);
                assert_eq!(fast.cost.objective, full.cost.objective);
                assert!(
                    fast.objective_by_partition.is_empty(),
                    "fast path skips diagnostics"
                );
                assert_eq!(full.objective_by_partition.len(), set.patterns[full.level_idx].len());
            }
        }
        // infeasible requests fail identically
        assert!(serve_request_fast(&m, &set, &req(0.0001)).is_err());
    }

    #[test]
    fn wrong_model_rejected() {
        let (_, set) = setup();
        let other = crate::model::edgecnn(10);
        assert!(serve_request(&other, &set, &req(0.01)).is_err());
    }
}
