//! Minimal wire-protocol walkthrough: start the coordinator, act as one
//! edge device, and print every step of the two-phase exchange.
//!
//! ```text
//! cargo run --release --example serve_loopback
//! ```

use qpart::coordinator::client::paper_request;
use qpart::prelude::*;
use qpart::proto::messages::{Request, Response};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    if Bundle::load("artifacts").is_err() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let handle = serve(qpart::coordinator::ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 16,
        session_capacity: 64,
        artifacts_dir: "artifacts".into(),
        ..Default::default()
    })?;
    println!("[server] listening on {}", handle.addr);

    let bundle = Arc::new(Bundle::load("artifacts")?);
    let mut client = DeviceClient::connect(&handle.addr.to_string(), Arc::clone(&bundle))?;

    // 0) ping + model discovery
    println!("[device] → ping");
    println!("[device] ← pong: {}", client.ping()?);
    if let Response::Models(models) = client.call(&Request::ListModels)? {
        for m in &models {
            println!(
                "[device] ← model {} ({} layers, {} params, {:.1}% test acc)",
                m.name,
                m.layers,
                m.params,
                m.test_accuracy * 100.0
            );
        }
    }

    // 1) phase 1: infer request → quantized segment
    let (x, y) = bundle.dataset("digits")?;
    let x = HostTensor::from(x);
    let input = x.slice_rows_padded(0, 1, 1);
    let req = paper_request("mlp6", 0.01);
    println!(
        "\n[device] → infer: model={} a≤{:.1}% r={:.0} Mbps f={:.0} MHz",
        req.model,
        req.accuracy_budget * 100.0,
        req.channel_capacity_bps / 1e6,
        req.clock_hz / 1e6
    );
    let reply = match client.call(&Request::Infer(req.clone()))? {
        Response::Segment(r) => r,
        other => return Err(format!("unexpected: {other:?}").into()),
    };
    println!(
        "[device] ← segment: session={} p={} bits={:?} b_x={} predicted degradation {:.3}%",
        reply.session,
        reply.pattern.partition,
        reply.pattern.weight_bits,
        reply.pattern.activation_bits,
        reply.pattern.predicted_degradation * 100.0
    );
    let wire_bytes: usize = reply
        .segment
        .layers
        .iter()
        .map(|l| l.w_packed.len() + l.b_packed.len())
        .sum();
    println!(
        "[device]   downlink: {} layers, {} KiB bit-packed (f32 would be {} KiB)",
        reply.segment.layers.len(),
        wire_bytes / 1024,
        reply
            .segment
            .layers
            .iter()
            .map(|l| l.w_dims.iter().product::<usize>() + l.b_len)
            .sum::<usize>()
            * 4
            / 1024
    );

    // 2) device-side inference + phase 2 (handled inside DeviceClient::infer;
    //    here we re-do the whole flow at once for the printout)
    let (pred, logits, partition) = client.infer(req, input)?;
    println!("\n[device] → activation (quantized boundary at p={partition})");
    println!(
        "[device] ← result: prediction={pred} (label={}) logits[pred]={:.2}",
        y[0], logits[pred as usize]
    );

    // 3) server stats
    if let Response::Stats(stats) = client.call(&Request::Stats)? {
        println!("\n[server] stats: {}", stats.to_string_pretty());
    }
    handle.shutdown();
    Ok(())
}
