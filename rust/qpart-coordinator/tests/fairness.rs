//! Per-connection fair-queuing integration tests: `fair_rate` must bound
//! a hot connection hammering the server while leaving paced peers —
//! which own independent token buckets — completely untouched, and a
//! throttled connection must recover once its bucket refills.

use qpart_coordinator::testing::{synthetic_bundle, BlockingConn};
use qpart_coordinator::{serve, ServerConfig};
use qpart_proto::messages::{HelloRequest, Request, Response};
use std::time::{Duration, Instant};

#[test]
fn hot_connection_is_throttled_paced_connections_are_not() {
    let dir = synthetic_bundle("fair-hot");
    // 2 req/s sustained, 4-token burst per connection
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        fair_rate: 2.0,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // the hot client hammers 100 requests back-to-back
    let mut hot = BlockingConn::connect(&addr).unwrap();
    let (mut hot_ok, mut hot_throttled) = (0u64, 0u64);
    for _ in 0..100 {
        match hot.call(&Request::Ping).unwrap() {
            Response::Pong => hot_ok += 1,
            Response::Error(e) if e.code == "throttled" => hot_throttled += 1,
            other => panic!("unexpected {other:?}"),
        }
    }
    assert_eq!(hot_ok + hot_throttled, 100);
    assert!(hot_ok >= 4, "the burst allowance must admit at least 4, got {hot_ok}");
    assert!(
        hot_throttled >= 50,
        "a hot connection must be rate-bound: only {hot_throttled}/100 throttled"
    );

    // a paced client on its own connection owns its own bucket: at well
    // under the sustained rate it is never refused, even while the hot
    // client's bucket is empty
    let mut paced = BlockingConn::connect(&addr).unwrap();
    for i in 0..5 {
        match paced.call(&Request::Ping).unwrap() {
            Response::Pong => {}
            other => panic!("paced request {i} refused: {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(600));
    }

    // the throttled connection was never closed — once the bucket
    // refills (2 tokens/s) the same socket is served again
    let deadline = Instant::now() + Duration::from_secs(10);
    let recovered = loop {
        match hot.call(&Request::Ping).unwrap() {
            Response::Pong => break true,
            Response::Error(e) if e.code == "throttled" => {
                if Instant::now() > deadline {
                    break false;
                }
                std::thread::sleep(Duration::from_millis(100));
            }
            other => panic!("unexpected {other:?}"),
        }
    };
    assert!(recovered, "throttled connection never recovered after refill");

    let snap = handle.snapshot();
    assert!(
        snap.sched_throttled_total >= hot_throttled,
        "sched_throttled_total {} < client-observed {hot_throttled}",
        snap.sched_throttled_total
    );
    assert_eq!(snap.errors_total, 0, "throttling must not be counted as an error");
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn zero_fair_rate_disables_throttling_entirely() {
    let dir = synthetic_bundle("fair-off");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
    for _ in 0..50 {
        assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
    }
    assert_eq!(handle.snapshot().sched_throttled_total, 0);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn class_weights_scale_per_connection_rates() {
    let dir = synthetic_bundle("fair-weights");
    // base 5 req/s: a heavy class (hello weight 2.0) sustains 10/s while a
    // light class (0.5) sustains 2.5/s — both on the same --fair-rate
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        fair_rate: 5.0,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    let connect = |weight: f64| -> BlockingConn {
        let mut conn = BlockingConn::connect(&addr).unwrap();
        let hello = Request::Hello(HelloRequest { weight, ..HelloRequest::default() });
        match conn.call(&hello).unwrap() {
            Response::Hello(_) => conn,
            other => panic!("hello: unexpected {other:?}"),
        }
    };
    // empty a bucket so the next window measures pure weighted refill
    let drain = |conn: &mut BlockingConn| {
        let mut streak = 0;
        for _ in 0..200 {
            match conn.call(&Request::Ping).unwrap() {
                Response::Pong => streak = 0,
                Response::Error(e) if e.code == "throttled" => {
                    streak += 1;
                    if streak >= 5 {
                        return;
                    }
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        panic!("bucket never drained in 200 requests");
    };
    let mut heavy = connect(2.0);
    let mut light = connect(0.5);
    drain(&mut heavy);
    drain(&mut light);

    // one second of refill: heavy accrues ~10 tokens, light ~2.5; the
    // admitted counts must reflect the 4x class-weight ratio (bounds are
    // loose because wall time keeps refilling during the hammer)
    std::thread::sleep(Duration::from_secs(1));
    let hammer = |conn: &mut BlockingConn| -> u64 {
        let mut ok = 0u64;
        for _ in 0..100 {
            match conn.call(&Request::Ping).unwrap() {
                Response::Pong => ok += 1,
                Response::Error(e) if e.code == "throttled" => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        ok
    };
    let heavy_ok = hammer(&mut heavy);
    let light_ok = hammer(&mut light);
    assert!((6..=25).contains(&heavy_ok), "heavy class: ~10 admits expected, got {heavy_ok}");
    assert!((1..=7).contains(&light_ok), "light class: ~2-3 admits expected, got {light_ok}");
    assert!(
        heavy_ok >= 2 * light_ok,
        "class weights did not separate rates: heavy {heavy_ok} vs light {light_ok}"
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fresh_connections_start_with_a_fresh_bucket() {
    let dir = synthetic_bundle("fair-fresh");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        fair_rate: 1.0,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // exhaust one connection's burst (2 tokens at rate 1)...
    let mut first = BlockingConn::connect(&addr).unwrap();
    let mut refused = false;
    for _ in 0..20 {
        if matches!(first.call(&Request::Ping).unwrap(), Response::Error(_)) {
            refused = true;
            break;
        }
    }
    assert!(refused, "20 instant requests never hit the 2-token burst cap");
    drop(first);

    // ...a replacement connection (possibly reusing the reactor slot) is
    // not haunted by the dead connection's empty bucket
    let mut second = BlockingConn::connect(&addr).unwrap();
    match second.call(&Request::Ping).unwrap() {
        Response::Pong => {}
        other => panic!("fresh connection inherited an empty bucket: {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
