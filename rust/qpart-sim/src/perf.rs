//! Performance module: per-request records and summary statistics.

use qpart_core::json::Value;

/// Everything measured for one served request.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub device: usize,
    pub model: String,
    pub arrival_s: f64,
    pub done_s: f64,
    /// Decision + queueing on the server before the downlink starts.
    pub plan_s: f64,
    pub downlink_s: f64,
    pub device_compute_s: f64,
    pub uplink_s: f64,
    pub server_compute_s: f64,
    pub device_energy_j: f64,
    pub payload_bits: u64,
    pub partition: usize,
    pub objective: f64,
}

impl RequestRecord {
    pub fn latency_s(&self) -> f64 {
        self.done_s - self.arrival_s
    }
}

/// Summary stats over a metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from unsorted samples. Empty input → all NaN, n = 0.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                p50: f64::NAN,
                p95: f64::NAN,
                p99: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
            };
        }
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| {
            let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
            s[idx]
        };
        Summary {
            n: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            min: s[0],
            max: s[s.len() - 1],
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("n", self.n.into()),
            ("mean", self.mean.into()),
            ("p50", self.p50.into()),
            ("p95", self.p95.into()),
            ("p99", self.p99.into()),
            ("min", self.min.into()),
            ("max", self.max.into()),
        ])
    }
}

/// Collects records and derives summaries.
#[derive(Debug, Default)]
pub struct PerfCollector {
    pub records: Vec<RequestRecord>,
}

impl PerfCollector {
    pub fn new() -> PerfCollector {
        PerfCollector { records: Vec::new() }
    }

    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn latency(&self) -> Summary {
        Summary::of(&self.records.iter().map(RequestRecord::latency_s).collect::<Vec<_>>())
    }

    pub fn energy(&self) -> Summary {
        Summary::of(&self.records.iter().map(|r| r.device_energy_j).collect::<Vec<_>>())
    }

    pub fn payload(&self) -> Summary {
        Summary::of(&self.records.iter().map(|r| r.payload_bits as f64).collect::<Vec<_>>())
    }

    pub fn objective(&self) -> Summary {
        Summary::of(&self.records.iter().map(|r| r.objective).collect::<Vec<_>>())
    }

    /// Served requests per simulated second.
    pub fn throughput_rps(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let t_end = self.records.iter().map(|r| r.done_s).fold(0.0, f64::max);
        let t_start = self.records.iter().map(|r| r.arrival_s).fold(f64::INFINITY, f64::min);
        self.records.len() as f64 / (t_end - t_start).max(1e-9)
    }

    /// Histogram of chosen partition points (index = p).
    pub fn partition_histogram(&self, max_p: usize) -> Vec<usize> {
        let mut h = vec![0usize; max_p + 1];
        for r in &self.records {
            if r.partition < h.len() {
                h[r.partition] += 1;
            }
        }
        h
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("requests", self.records.len().into()),
            ("latency_s", self.latency().to_json()),
            ("device_energy_j", self.energy().to_json()),
            ("payload_bits", self.payload().to_json()),
            ("objective", self.objective().to_json()),
            ("throughput_rps", self.throughput_rps().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, done: f64, p: usize) -> RequestRecord {
        RequestRecord {
            device: 0,
            model: "m".into(),
            arrival_s: arrival,
            done_s: done,
            plan_s: 0.0,
            downlink_s: 0.0,
            device_compute_s: 0.0,
            uplink_s: 0.0,
            server_compute_s: 0.0,
            device_energy_j: 0.1,
            payload_bits: 100,
            partition: p,
            objective: 1.0,
        }
    }

    #[test]
    fn summary_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&samples);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        // nearest-rank with round-half-up: (99·0.5).round() = 50 → sample 51
        assert_eq!(s.p50, 51.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn collector_aggregates() {
        let mut c = PerfCollector::new();
        c.push(rec(0.0, 1.0, 2));
        c.push(rec(0.5, 2.0, 2));
        c.push(rec(1.0, 2.5, 4));
        assert_eq!(c.latency().n, 3);
        assert!((c.throughput_rps() - 3.0 / 2.5).abs() < 1e-12);
        assert_eq!(c.partition_histogram(6), vec![0, 0, 2, 0, 1, 0, 0]);
    }
}
