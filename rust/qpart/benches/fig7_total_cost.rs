//! **Fig. 7** — Layer-wise Total Cost Comparison (4 schemes).
//!
//! Paper: QPART achieves the lowest Eq. 17 objective at every partition
//! point; the autoencoder scheme is the most expensive (extra encode/
//! decode compute); pruning sits between.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::Table;

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 7 — layer-wise total objective, 4 schemes (mlp6)", setup.calibrated);
    let cost = CostModel::paper_default();
    let arch = &setup.arch;
    let list = schemes();

    let mut table = Table::new(
        "Eq. 17 objective vs partition point",
        &["p", "QPART", "No Optimization", "Model Pruning", "Auto-Encoder"],
    );
    let mut qpart_wins = 0usize;
    for p in 0..=arch.num_layers() {
        let vals: Vec<f64> = list
            .iter()
            .map(|&s| {
                scheme_cost(s, arch, &cost, p, Some(&setup.patterns), LEVEL_1PCT)
                    .unwrap()
                    .breakdown
                    .objective
            })
            .collect();
        if vals[0] <= vals.iter().cloned().fold(f64::INFINITY, f64::min) + 1e-15 {
            qpart_wins += 1;
        }
        table.row(
            std::iter::once(p.to_string())
                .chain(vals.iter().map(|v| format!("{v:.5}")))
                .collect(),
        );
    }
    table.print();
    println!(
        "\npaper shape: QPART lowest everywhere — holds at {}/{} partition points.",
        qpart_wins,
        arch.num_layers() + 1
    );
}
