//! Arbitrary-bit-width bit-packing.
//!
//! The paper charges the channel `b` bits per quantized parameter (Eq. 14).
//! A real deployment has to actually put `b`-bit codes on the wire, so the
//! coordinator bit-packs code streams LSB-first into a byte buffer. This is
//! on the serving hot path (every response ships a packed segment) and is
//! benchmarked by `perf_quant`.

use crate::error::{Error, Result};

/// Bytes needed to pack `n` codes at `bits` bits each.
pub fn packed_len_bytes(n: usize, bits: u8) -> usize {
    ((n as u64 * bits as u64).div_ceil(8)) as usize
}

/// Pack `codes` (each `< 2^bits`) at `bits` bits per code, LSB-first.
pub fn pack_bits(codes: &[u32], bits: u8) -> Result<Vec<u8>> {
    if !(1..=24).contains(&bits) {
        return Err(Error::InvalidArg(format!("pack_bits: bits must be 1..=24, got {bits}")));
    }
    let limit = 1u64 << bits;
    let mut out = vec![0u8; packed_len_bytes(codes.len(), bits)];
    let mut acc: u64 = 0; // bit accumulator, LSB-first
    let mut acc_bits: u32 = 0;
    let mut pos = 0usize;
    for &c in codes {
        if (c as u64) >= limit {
            return Err(Error::InvalidArg(format!("code {c} does not fit in {bits} bits")));
        }
        acc |= (c as u64) << acc_bits;
        acc_bits += bits as u32;
        while acc_bits >= 8 {
            out[pos] = (acc & 0xFF) as u8;
            pos += 1;
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out[pos] = (acc & 0xFF) as u8;
    }
    Ok(out)
}

/// Unpack `n` codes at `bits` bits per code from `buf`.
pub fn unpack_bits(buf: &[u8], n: usize, bits: u8) -> Result<Vec<u32>> {
    if !(1..=24).contains(&bits) {
        return Err(Error::InvalidArg(format!("unpack_bits: bits must be 1..=24, got {bits}")));
    }
    let need = packed_len_bytes(n, bits);
    if buf.len() < need {
        return Err(Error::InvalidArg(format!(
            "unpack_bits: buffer has {} bytes, need {need}",
            buf.len()
        )));
    }
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..n {
        while acc_bits < bits as u32 {
            acc |= (buf[pos] as u64) << acc_bits;
            pos += 1;
            acc_bits += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        acc_bits -= bits as u32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1u8..=24 {
            let limit = 1u64 << bits;
            let codes: Vec<u32> =
                (0..200u64).map(|i| ((i * 2_654_435_761) % limit) as u32).collect();
            let packed = pack_bits(&codes, bits).unwrap();
            assert_eq!(packed.len(), packed_len_bytes(codes.len(), bits));
            let back = unpack_bits(&packed, codes.len(), bits).unwrap();
            assert_eq!(back, codes, "bits={bits}");
        }
    }

    #[test]
    fn exact_sizes() {
        assert_eq!(packed_len_bytes(8, 1), 1);
        assert_eq!(packed_len_bytes(9, 1), 2);
        assert_eq!(packed_len_bytes(3, 5), 2); // 15 bits → 2 bytes
        assert_eq!(packed_len_bytes(0, 7), 0);
    }

    #[test]
    fn rejects_oversized_codes() {
        assert!(pack_bits(&[8], 3).is_err());
        assert!(pack_bits(&[7], 3).is_ok());
    }

    #[test]
    fn rejects_short_buffer() {
        let packed = pack_bits(&[1, 2, 3], 8).unwrap();
        assert!(unpack_bits(&packed[..2], 3, 8).is_err());
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(pack_bits(&[0], 0).is_err());
        assert!(pack_bits(&[0], 25).is_err());
        assert!(unpack_bits(&[0], 1, 0).is_err());
    }

    #[test]
    fn empty_roundtrip() {
        let packed = pack_bits(&[], 5).unwrap();
        assert!(packed.is_empty());
        assert_eq!(unpack_bits(&packed, 0, 5).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn prop_pack_unpack_identity() {
        check("pack∘unpack = id", 80, |rng| {
            let bits = rng.range_usize(1, 25) as u8;
            let n = rng.range_usize(0, 500);
            let limit = 1u64 << bits;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(limit) as u32).collect();
            let packed = pack_bits(&codes, bits).unwrap();
            let back = unpack_bits(&packed, n, bits).unwrap();
            assert_eq!(back, codes);
        });
    }

    #[test]
    fn prop_payload_matches_eq14_accounting() {
        // The packed byte length is exactly ceil(n·b/8): the wire carries
        // what Eq. 14 charges for (up to sub-byte padding).
        check("packed length", 40, |rng| {
            let bits = rng.range_usize(1, 17) as u8;
            let n = rng.range_usize(0, 300);
            let codes: Vec<u32> = (0..n).map(|_| rng.below(1u64 << bits) as u32).collect();
            let packed = pack_bits(&codes, bits).unwrap();
            assert_eq!(packed.len() as u64, (n as u64 * bits as u64).div_ceil(8));
        });
    }
}
