//! Request-scoped tracing: span timelines from accept to reply.
//!
//! Dependency-free observability substrate for the coordinator. A
//! [`TraceId`] is minted at accept (sampled via `--trace-sample`) or
//! granted when a peer negotiates `trace: true` in its `hello`; every
//! pipeline stage then emits a [`Span`] — reactor read, FairQueue admit,
//! queue wait, Algorithm-2 planning, encode/quantize, phase-2 execution,
//! ReplyRouter completion, outbox flush — into a lock-cheap per-worker
//! [`SpanRing`]. Rings are drained into a bounded server-wide
//! [`TraceSink`] store that backs three exposure paths:
//!
//! 1. `/trace?id=` and `/trace/slow` on the `--metrics-listen` listener
//!    (JSON timelines),
//! 2. slow-request exemplars (`--trace-slow-ms` keeps the N worst full
//!    timelines, linked from the Prometheus histogram HELP lines),
//! 3. `bench-serve --trace-out` exporting Chrome trace-event JSON
//!    (`chrome://tracing` / Perfetto loadable).
//!
//! With sampling disabled and no hello-negotiated trace the layer is
//! inert: no spans are recorded and wire bytes are byte-identical to an
//! untraced build — the only residual cost is an `Option` check per
//! connection.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use qpart_core::json::Value;

/// Worker id used for spans emitted by the front-end (reactor or
/// per-connection threads) rather than an executor worker.
pub const FRONT_WORKER: u32 = u32::MAX;

/// Pipeline stage a span measures. Stage names are identical across the
/// reactor and threaded front-ends so span *sets* compare equal between
/// them.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Socket readable → a full frame parsed off the connection buffer.
    Read,
    /// FairQueue admission + job enqueue onto the worker channel.
    Admit,
    /// Enqueue → worker dequeue (equals the `queue_wait` histogram sample).
    QueueWait,
    /// `plan_infer`: Algorithm-2 decision (or DecisionCache hit).
    Plan,
    /// Quantize + pack + reply encode (or EncodedReplyCache hit).
    Encode,
    /// Phase-2 stack + server-segment execution.
    Execute,
    /// Worker reply push → front-end completion routing (ReplyRouter).
    Route,
    /// Reply bytes entering the outbox → flushed to the socket.
    Flush,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Read => "read",
            Stage::Admit => "admit",
            Stage::QueueWait => "queue_wait",
            Stage::Plan => "plan",
            Stage::Encode => "encode",
            Stage::Execute => "execute",
            Stage::Route => "route",
            Stage::Flush => "flush",
        }
    }
}

/// One timed pipeline stage of one traced request. Timestamps are
/// microseconds since the owning [`TraceSink`]'s epoch (server start).
#[derive(Debug, Clone)]
pub struct Span {
    pub trace: u64,
    pub stage: Stage,
    pub start_us: u64,
    pub end_us: u64,
    pub worker: u32,
    /// Small structured annotations: cache hit/miss, batch occupancy,
    /// chosen level/partition, row counts.
    pub notes: Vec<(&'static str, i64)>,
}

impl Span {
    fn to_json(&self) -> Value {
        let mut v = Value::obj([
            ("stage", self.stage.name().into()),
            ("start_us", self.start_us.into()),
            ("end_us", self.end_us.into()),
            ("worker", i64::from(self.worker as i32).into()),
        ]);
        if !self.notes.is_empty() {
            let notes =
                self.notes.iter().map(|(k, n)| (k.to_string(), Value::Num(*n as f64))).collect();
            v.set("notes", Value::Obj(notes));
        }
        v
    }
}

/// Identity a traced request carries through the pipeline.
///
/// `echo` is true only when the peer negotiated `trace: true` in `hello`:
/// then (and only then) the id is echoed back in `segment`/`result`
/// replies. Accept-sampled traces record spans without touching wire
/// bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobTrace {
    pub id: u64,
    pub echo: bool,
}

impl JobTrace {
    /// The id to stamp into a wire reply: `Some` only for negotiated traces.
    pub fn wire_id(self) -> Option<u64> {
        if self.echo {
            Some(self.id)
        } else {
            None
        }
    }
}

/// Rides a completed reply from a worker back to the front-end so the
/// Route span can measure completion-queue latency.
#[derive(Debug, Clone, Copy)]
pub struct TraceStamp {
    pub trace: JobTrace,
    /// When the worker pushed the reply (µs since sink epoch).
    pub pushed_us: u64,
}

/// Lock-cheap bounded span buffer, one per worker/front-end thread.
/// Writers take an uncontended mutex (the only other party is the
/// drain); overflow increments a counter instead of blocking.
pub struct SpanRing {
    cap: usize,
    spans: Mutex<Vec<Span>>,
    dropped: AtomicU64,
}

impl SpanRing {
    fn new(cap: usize) -> Self {
        SpanRing { cap, spans: Mutex::new(Vec::new()), dropped: AtomicU64::new(0) }
    }

    pub fn push(&self, span: Span) {
        let mut g = self.spans.lock().unwrap();
        if g.len() >= self.cap {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        g.push(span);
    }

    fn drain(&self) -> Vec<Span> {
        std::mem::take(&mut *self.spans.lock().unwrap())
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// Per-thread handle for emitting spans: an `Arc`'d ring plus the worker
/// id stamped into every span.
#[derive(Clone)]
pub struct Tracer {
    sink: Arc<TraceSink>,
    ring: Arc<SpanRing>,
    worker: u32,
}

impl Tracer {
    pub fn sink(&self) -> &Arc<TraceSink> {
        &self.sink
    }

    /// Microseconds since the sink epoch.
    pub fn now_us(&self) -> u64 {
        self.sink.now_us()
    }

    pub fn span(&self, trace: JobTrace, stage: Stage, start_us: u64, end_us: u64) {
        self.span_with(trace, stage, start_us, end_us, Vec::new());
    }

    pub fn span_with(
        &self,
        trace: JobTrace,
        stage: Stage,
        start_us: u64,
        end_us: u64,
        notes: Vec<(&'static str, i64)>,
    ) {
        self.ring.push(Span {
            trace: trace.id,
            stage,
            start_us,
            end_us: end_us.max(start_us),
            worker: self.worker,
            notes,
        });
    }
}

/// Per-ring capacity: generous enough that a drain cadence of tens of
/// milliseconds never drops spans under normal load.
const RING_CAP: usize = 8192;

struct SlowExemplar {
    total_us: u64,
    id: u64,
    spans: Vec<Span>,
}

#[derive(Default)]
struct TraceStore {
    traces: HashMap<u64, Vec<Span>>,
    /// FIFO eviction order over `traces` keys.
    order: VecDeque<u64>,
    /// N worst full timelines, sorted worst-first. Holds its own span
    /// copies so exemplars survive FIFO eviction from `traces`.
    slow: Vec<SlowExemplar>,
    dropped_spans: u64,
}

/// Server-wide trace collector: mints ids, owns the per-thread rings,
/// and stores drained spans in a bounded FIFO keyed by trace id.
pub struct TraceSink {
    epoch: Instant,
    next_id: AtomicU64,
    sample: f64,
    /// Accept counter driving the deterministic sampler.
    accepts: AtomicU64,
    rings: Mutex<Vec<Arc<SpanRing>>>,
    store: Mutex<TraceStore>,
    slow_us: u64,
    slow_keep: usize,
    store_cap: usize,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink")
            .field("sample", &self.sample)
            .field("slow_us", &self.slow_us)
            .field("slow_keep", &self.slow_keep)
            .field("store_cap", &self.store_cap)
            .finish_non_exhaustive()
    }
}

impl TraceSink {
    /// `sample` is the accept-sampling rate in [0, 1]; `slow_us = 0`
    /// disables slow-exemplar capture.
    pub fn new(sample: f64, slow_us: u64, slow_keep: usize, store_cap: usize) -> Arc<TraceSink> {
        Arc::new(TraceSink {
            epoch: Instant::now(),
            next_id: AtomicU64::new(1),
            sample: sample.clamp(0.0, 1.0),
            accepts: AtomicU64::new(0),
            rings: Mutex::new(Vec::new()),
            store: Mutex::new(TraceStore::default()),
            slow_us,
            slow_keep: slow_keep.max(1),
            store_cap: store_cap.max(1),
        })
    }

    /// True when accept-sampling can ever fire. Hello-negotiated traces
    /// work regardless.
    pub fn sampling(&self) -> bool {
        self.sample > 0.0
    }

    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Microseconds from the sink epoch to `t` (0 if `t` predates it).
    pub fn offset_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Deterministic accept-sampler: connection n is traced iff
    /// `floor((n+1)·rate) > floor(n·rate)`, so a rate of 0.25 traces
    /// exactly every 4th accept and 1.0 traces all of them.
    pub fn sample_accept(&self) -> Option<JobTrace> {
        if self.sample <= 0.0 {
            return None;
        }
        let n = self.accepts.fetch_add(1, Ordering::Relaxed);
        let taken = ((n + 1) as f64 * self.sample).floor() > (n as f64 * self.sample).floor();
        if taken {
            Some(JobTrace { id: self.mint(), echo: false })
        } else {
            None
        }
    }

    /// Mint a trace for a peer that negotiated `trace: true` in hello;
    /// the id is echoed in that connection's replies.
    pub fn grant(&self) -> JobTrace {
        JobTrace { id: self.mint(), echo: true }
    }

    fn mint(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Register a per-thread ring and hand back the emitting handle.
    pub fn tracer(self: &Arc<Self>, worker: u32) -> Tracer {
        let ring = Arc::new(SpanRing::new(RING_CAP));
        self.rings.lock().unwrap().push(Arc::clone(&ring));
        Tracer { sink: Arc::clone(self), ring, worker }
    }

    /// Drain every ring into the bounded store and refresh slow
    /// exemplars for the traces that gained spans.
    pub fn drain(&self) {
        let rings: Vec<Arc<SpanRing>> = self.rings.lock().unwrap().clone();
        let mut fresh: Vec<Span> = Vec::new();
        for ring in &rings {
            fresh.append(&mut ring.drain());
        }
        if fresh.is_empty() {
            return;
        }
        let mut touched: Vec<u64> = Vec::new();
        let mut store = self.store.lock().unwrap();
        for span in fresh {
            if !touched.contains(&span.trace) {
                touched.push(span.trace);
            }
            match store.traces.get_mut(&span.trace) {
                Some(spans) => spans.push(span),
                None => {
                    store.order.push_back(span.trace);
                    store.traces.insert(span.trace, vec![span]);
                }
            }
        }
        while store.order.len() > self.store_cap {
            if let Some(old) = store.order.pop_front() {
                if let Some(spans) = store.traces.remove(&old) {
                    store.dropped_spans += spans.len() as u64;
                }
            }
        }
        if self.slow_us > 0 {
            for id in touched {
                let Some(spans) = store.traces.get(&id) else { continue };
                let total = timeline_total_us(spans);
                if total < self.slow_us {
                    continue;
                }
                let spans = spans.clone();
                match store.slow.iter_mut().find(|e| e.id == id) {
                    Some(e) => {
                        e.total_us = total;
                        e.spans = spans;
                    }
                    None => store.slow.push(SlowExemplar { total_us: total, id, spans }),
                }
                store.slow.sort_by(|a, b| b.total_us.cmp(&a.total_us));
                store.slow.truncate(self.slow_keep);
            }
        }
    }

    /// Number of complete timelines currently stored.
    pub fn stored(&self) -> usize {
        self.store.lock().unwrap().traces.len()
    }

    /// Spans lost to ring overflow or store eviction.
    pub fn spans_dropped(&self) -> u64 {
        let rings: u64 = self.rings.lock().unwrap().iter().map(|r| r.dropped()).sum();
        rings + self.store.lock().unwrap().dropped_spans
    }

    /// JSON timeline for one trace id (drains rings first so the store
    /// is current). Checks slow exemplars when the FIFO already evicted
    /// the id. `None` if the id is unknown.
    pub fn trace_json(&self, id: u64) -> Option<String> {
        self.drain();
        let store = self.store.lock().unwrap();
        let spans = store
            .traces
            .get(&id)
            .or_else(|| store.slow.iter().find(|e| e.id == id).map(|e| &e.spans))?;
        Some(timeline_json(id, spans).to_string_compact())
    }

    /// JSON array of the N worst full timelines (worst first).
    pub fn slow_json(&self) -> String {
        self.drain();
        let store = self.store.lock().unwrap();
        let items =
            store.slow.iter().map(|e| timeline_json(e.id, &e.spans)).collect::<Vec<Value>>();
        Value::obj([
            ("slow_threshold_us", self.slow_us.into()),
            ("slow", Value::Arr(items)),
        ])
        .to_string_compact()
    }

    /// JSON index of stored trace ids, FIFO order (oldest first).
    pub fn list_json(&self) -> String {
        self.drain();
        let store = self.store.lock().unwrap();
        Value::obj([
            ("traces", Value::Arr(store.order.iter().map(|&id| id.into()).collect())),
            ("dropped_spans", store.dropped_spans.into()),
        ])
        .to_string_compact()
    }

    /// Chrome trace-event JSON (`chrome://tracing` / Perfetto) for every
    /// stored timeline: one complete ("X") event per span, `tid` = trace
    /// id so each request renders as its own track.
    pub fn chrome_trace_json(&self) -> String {
        self.drain();
        let store = self.store.lock().unwrap();
        let mut events: Vec<Value> = Vec::new();
        for &id in &store.order {
            let Some(spans) = store.traces.get(&id) else { continue };
            let mut spans = spans.clone();
            spans.sort_by_key(|s| (s.start_us, s.end_us));
            for s in spans {
                let mut args = vec![("worker".to_string(), Value::Num(s.worker as i32 as f64))];
                for (k, n) in &s.notes {
                    args.push((k.to_string(), Value::Num(*n as f64)));
                }
                events.push(Value::obj([
                    ("name", s.stage.name().into()),
                    ("ph", "X".into()),
                    ("ts", s.start_us.into()),
                    ("dur", (s.end_us - s.start_us).into()),
                    ("pid", 1u64.into()),
                    ("tid", id.into()),
                    ("args", Value::Obj(args)),
                ]));
            }
        }
        Value::obj([
            ("traceEvents", Value::Arr(events)),
            ("displayTimeUnit", "ms".into()),
        ])
        .to_string_compact()
    }
}

fn timeline_total_us(spans: &[Span]) -> u64 {
    let start = spans.iter().map(|s| s.start_us).min().unwrap_or(0);
    let end = spans.iter().map(|s| s.end_us).max().unwrap_or(0);
    end.saturating_sub(start)
}

fn timeline_json(id: u64, spans: &[Span]) -> Value {
    let mut spans = spans.to_vec();
    spans.sort_by_key(|s| (s.start_us, s.end_us));
    Value::obj([
        ("trace", id.into()),
        ("total_us", timeline_total_us(&spans).into()),
        ("spans", Value::Arr(spans.iter().map(Span::to_json).collect())),
    ])
}

// ---------------------------------------------------------------------------
// Live-traffic capture into the scenario engine's `trace v1` format.
// ---------------------------------------------------------------------------

/// Reference channel capacity (bps) the scenario replayer scales by
/// `snr_scale` — `paper_request`'s default device profile.
const BASE_CAPACITY_BPS: f64 = 200e6;

#[derive(Clone)]
struct RecordedEvent {
    arrival_s: f64,
    device: usize,
    accuracy_budget: f64,
    snr_scale: f64,
    phase2_uploads: u32,
}

#[derive(Default)]
struct RecorderInner {
    /// Connection key → compact device index.
    devices: HashMap<u64, usize>,
    events: Vec<RecordedEvent>,
    /// Device index → position of its latest event (upload attribution).
    last_event: HashMap<usize, usize>,
}

/// Captures live traffic into the `trace v1` text format bench-serve
/// replays (`--scenario <file>`), so a production session becomes a
/// regression scenario. Devices are compact indices in connection-arrival
/// order; the class column is `live`; `snr_scale` is derived from the
/// request's reported channel capacity relative to the paper-default
/// profile.
pub struct TrafficRecorder {
    epoch: Instant,
    path: String,
    inner: Mutex<RecorderInner>,
}

impl TrafficRecorder {
    pub fn new(path: &str) -> Arc<TrafficRecorder> {
        Arc::new(TrafficRecorder {
            epoch: Instant::now(),
            path: path.to_string(),
            inner: Mutex::new(RecorderInner::default()),
        })
    }

    /// Record one infer arrival on connection `conn_key`.
    pub fn record_infer(&self, conn_key: u64, accuracy_budget: f64, channel_capacity_bps: f64) {
        let arrival_s = self.epoch.elapsed().as_secs_f64();
        let mut g = self.inner.lock().unwrap();
        let n = g.devices.len();
        let device = *g.devices.entry(conn_key).or_insert(n);
        let snr_scale = if channel_capacity_bps > 0.0 {
            channel_capacity_bps / BASE_CAPACITY_BPS
        } else {
            1.0
        };
        let idx = g.events.len();
        g.events.push(RecordedEvent {
            arrival_s,
            device,
            accuracy_budget,
            snr_scale,
            phase2_uploads: 0,
        });
        g.last_event.insert(device, idx);
    }

    /// Attribute a phase-2 activation upload to `conn_key`'s latest event.
    pub fn record_upload(&self, conn_key: u64) {
        let mut g = self.inner.lock().unwrap();
        let Some(&device) = g.devices.get(&conn_key) else { return };
        let Some(&idx) = g.last_event.get(&device) else { return };
        if let Some(e) = g.events.get_mut(idx) {
            e.phase2_uploads += 1;
        }
    }

    /// Render the capture as `trace v1` text (stable event order).
    pub fn to_text(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::from("trace v1\n");
        for e in &g.events {
            out.push_str(&format!(
                "{} {} live {} {} {}\n",
                e.arrival_s,
                e.device,
                e.accuracy_budget,
                e.snr_scale,
                // the replayer treats uploads=0 as "no phase 2"; a live
                // infer with no observed upload records exactly that
                e.phase2_uploads,
            ));
        }
        out
    }

    /// Rewrite the capture file with everything recorded so far. Called
    /// periodically from the GC thread and once at shutdown.
    pub fn flush(&self) -> std::io::Result<()> {
        std::fs::write(&self.path, self.to_text())
    }

    pub fn events(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: u64, stage: Stage, start: u64, end: u64) -> Span {
        Span { trace, stage, start_us: start, end_us: end, worker: 0, notes: Vec::new() }
    }

    #[test]
    fn deterministic_sampler_rates() {
        let sink = TraceSink::new(0.25, 0, 8, 64);
        let taken = (0..100).filter(|_| sink.sample_accept().is_some()).count();
        assert_eq!(taken, 25);

        let all = TraceSink::new(1.0, 0, 8, 64);
        assert!((0..50).all(|_| all.sample_accept().is_some()));

        let none = TraceSink::new(0.0, 0, 8, 64);
        assert!((0..50).all(|_| none.sample_accept().is_none()));
        assert!(!none.sampling());
    }

    #[test]
    fn minted_ids_are_unique_and_grants_echo() {
        let sink = TraceSink::new(1.0, 0, 8, 64);
        let a = sink.sample_accept().unwrap();
        let b = sink.grant();
        assert_ne!(a.id, b.id);
        assert!(!a.echo);
        assert!(b.echo);
        assert_eq!(a.wire_id(), None);
        assert_eq!(b.wire_id(), Some(b.id));
    }

    #[test]
    fn drain_collects_across_rings_and_store_evicts_fifo() {
        let sink = TraceSink::new(1.0, 0, 8, 2);
        let t0 = sink.tracer(0);
        let t1 = sink.tracer(1);
        for id in 1..=3u64 {
            t0.span(JobTrace { id, echo: false }, Stage::Plan, 10, 20);
            t1.span(JobTrace { id, echo: false }, Stage::Encode, 20, 30);
        }
        sink.drain();
        assert_eq!(sink.stored(), 2);
        // trace 1 was evicted (FIFO), 2 and 3 remain
        assert!(sink.trace_json(1).is_none());
        assert!(sink.trace_json(2).is_some());
        assert!(sink.trace_json(3).is_some());
        assert_eq!(sink.spans_dropped(), 2);
    }

    #[test]
    fn timeline_json_is_sorted_and_total_spans_the_envelope() {
        let sink = TraceSink::new(1.0, 0, 8, 64);
        let t = sink.tracer(3);
        t.span_with(JobTrace { id: 9, echo: false }, Stage::Encode, 50, 90, vec![("cache_hit", 1)]);
        t.span(JobTrace { id: 9, echo: false }, Stage::Read, 10, 20);
        let json = sink.trace_json(9).unwrap();
        let v = qpart_core::json::parse(&json).unwrap();
        assert_eq!(v.req_u64("trace").unwrap(), 9);
        assert_eq!(v.req_u64("total_us").unwrap(), 80);
        let spans = v.req_arr("spans").unwrap();
        assert_eq!(spans[0].req_str("stage").unwrap(), "read");
        assert_eq!(spans[1].req_str("stage").unwrap(), "encode");
        assert_eq!(spans[1].get("notes").unwrap().req_u64("cache_hit").unwrap(), 1);
    }

    #[test]
    fn slow_store_keeps_exactly_n_worst() {
        let sink = TraceSink::new(1.0, 100, 2, 64);
        let t = sink.tracer(0);
        // totals: 1→150, 2→500, 3→90 (below threshold), 4→300, 5→200
        for (id, total) in [(1u64, 150u64), (2, 500), (3, 90), (4, 300), (5, 200)] {
            t.span(JobTrace { id, echo: false }, Stage::Plan, 0, total);
        }
        sink.drain();
        let v = qpart_core::json::parse(&sink.slow_json()).unwrap();
        let slow = v.req_arr("slow").unwrap();
        assert_eq!(slow.len(), 2);
        assert_eq!(slow[0].req_u64("trace").unwrap(), 2);
        assert_eq!(slow[1].req_u64("trace").unwrap(), 4);
        // exemplars survive FIFO eviction of the main store
        for id in 10..200u64 {
            t.span(JobTrace { id, echo: false }, Stage::Plan, 0, 1);
        }
        sink.drain();
        assert!(sink.trace_json(2).is_some());
    }

    #[test]
    fn slow_exemplar_grows_with_late_spans() {
        let sink = TraceSink::new(1.0, 50, 4, 64);
        let t = sink.tracer(0);
        t.span(JobTrace { id: 7, echo: false }, Stage::Plan, 0, 60);
        sink.drain();
        t.span(JobTrace { id: 7, echo: false }, Stage::Execute, 60, 400);
        sink.drain();
        let v = qpart_core::json::parse(&sink.slow_json()).unwrap();
        let slow = v.req_arr("slow").unwrap();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].req_u64("total_us").unwrap(), 400);
        assert_eq!(slow[0].req_arr("spans").unwrap().len(), 2);
    }

    #[test]
    fn chrome_export_has_complete_events() {
        let sink = TraceSink::new(1.0, 0, 8, 64);
        let t = sink.tracer(2);
        t.span_with(JobTrace { id: 1, echo: false }, Stage::Execute, 5, 25, vec![("rows", 4)]);
        let json = sink.chrome_trace_json();
        let v = qpart_core::json::parse(&json).unwrap();
        let events = v.req_arr("traceEvents").unwrap();
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.req_str("ph").unwrap(), "X");
        assert_eq!(e.req_str("name").unwrap(), "execute");
        assert_eq!(e.req_u64("ts").unwrap(), 5);
        assert_eq!(e.req_u64("dur").unwrap(), 20);
        assert_eq!(e.req_u64("tid").unwrap(), 1);
        assert_eq!(e.get("args").unwrap().req_u64("rows").unwrap(), 4);
    }

    #[test]
    fn ring_overflow_drops_and_counts() {
        let ring = SpanRing::new(2);
        for i in 0..5 {
            ring.push(span(1, Stage::Read, i, i + 1));
        }
        assert_eq!(ring.dropped(), 3);
        assert_eq!(ring.drain().len(), 2);
    }

    #[test]
    fn recorder_emits_replayable_trace_v1() {
        let rec = TrafficRecorder::new("/dev/null");
        rec.record_infer(100, 0.02, 200e6);
        rec.record_infer(200, 0.05, 100e6);
        rec.record_upload(100);
        rec.record_upload(100);
        rec.record_infer(100, 0.02, 200e6);
        rec.record_upload(200);
        let text = rec.to_text();
        assert!(text.starts_with("trace v1\n"));
        assert_eq!(rec.events(), 3);
        let lines: Vec<&str> = text.lines().skip(1).collect();
        // conn 100 is device 0, conn 200 is device 1; uploads attribute
        // to the latest event of the right device
        let cols: Vec<Vec<&str>> =
            lines.iter().map(|l| l.split_whitespace().collect()).collect();
        assert_eq!(cols[0][1], "0");
        assert_eq!(cols[0][5], "2");
        assert_eq!(cols[1][1], "1");
        assert_eq!(cols[1][2], "live");
        assert_eq!(cols[1][4], "0.5");
        assert_eq!(cols[1][5], "1");
        assert_eq!(cols[2][5], "0");
    }
}
