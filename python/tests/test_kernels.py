"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (including ones that force multi-block grids and
the accumulation path) and value ranges; assert_allclose against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qconv, qlinear, ref
from compile.kernels.qlinear import _block, vmem_footprint_bytes


def _mk(rng, b, d, g, bits):
    x = rng.normal(size=(b, d)).astype(np.float32)
    codes = rng.integers(0, 2**bits, size=(d, g)).astype(np.float32)
    qmin = np.array([[rng.normal() * 0.1 - 0.3]], dtype=np.float32)
    step = np.array([[abs(rng.normal()) * 0.01 + 1e-4]], dtype=np.float32)
    bias = rng.normal(size=(1, g)).astype(np.float32)
    return x, codes, qmin, step, bias


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 7, 32]),
    d=st.sampled_from([8, 60, 256, 784]),
    g=st.sampled_from([4, 10, 130, 512]),
    bits=st.integers(min_value=1, max_value=12),
    relu=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_qlinear_matches_ref(b, d, g, bits, relu, seed):
    rng = np.random.default_rng(seed)
    x, codes, qmin, step, bias = _mk(rng, b, d, g, bits)
    got = qlinear(x, codes, qmin, step, bias, relu=relu)
    want = ref.qlinear_ref(x, codes, qmin, step, bias, relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8]),
    c_in=st.sampled_from([1, 3, 8]),
    c_out=st.sampled_from([4, 16]),
    side=st.sampled_from([8, 16]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_qconv_matches_ref(b, c_in, c_out, side, stride, seed):
    rng = np.random.default_rng(seed)
    k = 3
    x = rng.normal(size=(b, c_in, side, side)).astype(np.float32)
    codes = rng.integers(0, 255, size=(c_in * k * k, c_out)).astype(np.float32)
    qmin = np.array([[-0.4]], dtype=np.float32)
    step = np.array([[0.003]], dtype=np.float32)
    bias = rng.normal(size=(1, c_out)).astype(np.float32)
    got = qconv(x, codes, qmin, step, bias, True, k, stride)
    want = ref.qconv_ref(x, codes, qmin, step, bias, True, k, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_qconv_matches_lax_conv():
    """im2col + matmul formulation == direct lax.conv (dequantized)."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(2, 3, 16, 16)).astype(np.float32)
    codes = rng.integers(0, 63, size=(27, 8)).astype(np.float32)
    qmin = np.array([[-0.2]], dtype=np.float32)
    step = np.array([[0.006]], dtype=np.float32)
    bias = rng.normal(size=(1, 8)).astype(np.float32)
    w = (qmin[0, 0] + codes * step[0, 0]).reshape(3, 3, 3, 8)
    got = qconv(x, codes, qmin, step, bias, True, 3, 2)
    want = ref.conv_ref(x, jnp.asarray(w), bias, True, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_dequant_identity_trick():
    """codes=w, qmin=0, step=1 turns the kernel into a plain linear layer."""
    rng = np.random.default_rng(5)
    x = rng.normal(size=(4, 32)).astype(np.float32)
    w = rng.normal(size=(32, 16)).astype(np.float32)
    bias = rng.normal(size=(1, 16)).astype(np.float32)
    zero = np.zeros((1, 1), np.float32)
    one = np.ones((1, 1), np.float32)
    got = qlinear(x, w, zero, one, bias, relu=False)
    np.testing.assert_allclose(np.asarray(got), x @ w + bias, rtol=2e-4, atol=2e-4)


def test_block_divisor_helper():
    assert _block(784, 256) == 196
    assert _block(512, 256) == 256
    assert _block(10, 256) == 10
    assert _block(1, 128) == 1
    for dim in [7, 12, 100, 784, 4096]:
        b = _block(dim, 256)
        assert dim % b == 0 and b <= max(1, min(dim, 256))


def test_vmem_footprint_within_budget():
    """DESIGN.md §8: per-step VMEM residency must fit a 16 MiB core by a
    wide margin for every layer shape in the zoo."""
    for (b, d, g) in [(1, 784, 512), (32, 4096, 256), (32768, 27, 16),
                      (32, 512, 256), (8192, 576, 64)]:
        fp = vmem_footprint_bytes(b, d, g)
        assert fp["total"] < 2 * 1024 * 1024, (b, d, g, fp)


def test_relu_clamps():
    rng = np.random.default_rng(7)
    x, codes, qmin, step, bias = _mk(rng, 4, 16, 8, 8)
    bias = bias - 10.0  # force negatives
    out = np.asarray(qlinear(x, codes, qmin, step, bias, relu=True))
    assert (out >= 0).all()
