//! Minimal, dependency-free JSON: value type, recursive-descent parser,
//! serializer, and typed accessors.
//!
//! This build runs fully offline (no serde), so QPART carries its own JSON
//! implementation. It is used for the artifact manifest, the calibration
//! table, the layered config system, and the TCP wire protocol.
//!
//! Design notes:
//! * Numbers are kept as `f64` (adequate for every QPART document; integers
//!   up to 2^53 round-trip exactly).
//! * Object key order is preserved (`Vec<(String, Value)>`) so serialized
//!   documents are deterministic and diffable.
//! * The parser enforces a recursion-depth limit so malformed/hostile input
//!   cannot overflow the stack.

mod parse;
mod ser;

pub use parse::parse;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        ser::write_value(self, &mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        ser::write_value(self, &mut out, Some(2), 0);
        out
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer view of a number; `None` if non-integral or out of i64 range.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() <= 9.007_199_254_740_992e15 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Array element lookup.
    pub fn at(&self, idx: usize) -> Option<&Value> {
        match self {
            Value::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    // ----- required-field accessors (schema errors with a path) -----

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::schema(key, "missing required field"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::schema(key, "expected string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::schema(key, "expected number"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        let v = self
            .req(key)?
            .as_i64()
            .ok_or_else(|| Error::schema(key, "expected integer"))?;
        u64::try_from(v).map_err(|_| Error::schema(key, "expected non-negative integer"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_u64(key)? as usize)
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| Error::schema(key, "expected array"))
    }

    pub fn req_obj(&self, key: &str) -> Result<&[(String, Value)]> {
        self.req(key)?
            .as_obj()
            .ok_or_else(|| Error::schema(key, "expected object"))
    }

    /// Required array of numbers.
    pub fn req_f64_arr(&self, key: &str) -> Result<Vec<f64>> {
        self.req_arr(key)?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| Error::schema(key, "expected array of numbers"))
            })
            .collect()
    }

    /// Optional field with default.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    // ----- builders -----

    /// Builder for objects: `Value::obj([("a", 1.0.into()), ...])`.
    pub fn obj<I>(fields: I) -> Value
    where
        I: IntoIterator<Item = (&'static str, Value)>,
    {
        Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Builder for arrays from an iterator of values.
    pub fn arr<I>(items: I) -> Value
    where
        I: IntoIterator<Item = Value>,
    {
        Value::Arr(items.into_iter().collect())
    }

    /// Array of numbers from an f64 slice.
    pub fn num_arr(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    /// In-place object field insertion (replaces existing key).
    pub fn set(&mut self, key: &str, val: Value) {
        if let Value::Obj(fields) = self {
            if let Some(slot) = fields.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val;
            } else {
                fields.push((key.to_string(), val));
            }
        }
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Self {
        Value::Num(x)
    }
}
impl From<usize> for Value {
    fn from(x: usize) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u64> for Value {
    fn from(x: u64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Self {
        Value::Num(x as f64)
    }
}
impl From<u32> for Value {
    fn from(x: u32) -> Self {
        Value::Num(x as f64)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Self {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Self {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Self {
        Value::Str(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": 1, "b": [true, null, "x"], "c": {"d": 2.5}}"#).unwrap();
        assert_eq!(v.req_f64("a").unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().at(0).unwrap().as_bool(), Some(true));
        assert!(v.get("b").unwrap().at(1).unwrap().is_null());
        assert_eq!(v.get("c").unwrap().req_f64("d").unwrap(), 2.5);
        assert!(v.req("zz").is_err());
        assert!(v.req_str("a").is_err());
    }

    #[test]
    fn set_replaces_and_appends() {
        let mut v = Value::obj([("a", 1.0.into())]);
        v.set("a", 2.0.into());
        v.set("b", "x".into());
        assert_eq!(v.req_f64("a").unwrap(), 2.0);
        assert_eq!(v.req_str("b").unwrap(), "x");
    }

    #[test]
    fn i64_boundaries() {
        assert_eq!(Value::Num(3.0).as_i64(), Some(3));
        assert_eq!(Value::Num(3.5).as_i64(), None);
        assert_eq!(Value::Num(-7.0).as_i64(), Some(-7));
    }
}
