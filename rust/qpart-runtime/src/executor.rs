//! Split-inference executor: the serving data path.
//!
//! Given a model instance and a quantization pattern `(b, p)` chosen by the
//! optimizer, this module
//!
//! 1. quantizes the device segment's weights layer-wise (paper Eq. 9–10) —
//!    the codes are what the simulated downlink ships (bit-packed by the
//!    coordinator),
//! 2. runs layers `1..=p` through the **Pallas-kernel executables**
//!    (`q_l{i}`) exactly as the edge device would (dequantize fused into the
//!    matmul),
//! 3. quantizes the boundary activation at `b_x` (the simulated uplink),
//! 4. finishes layers `p+1..=L` in full precision on the server
//!    (`f32_l{i}`), and returns the logits.
//!
//! It also implements the comparison baselines (paper §V): full-precision
//! (“No Optimization”), DeepCOD-style autoencoder offloading, and 2-step
//! structured pruning — plus batched top-1 accuracy evaluation used by the
//! Table III/IV benches.

use crate::bundle::{Bundle, ExecEntry, ModelWeights};
use crate::compile_cache::{
    CompileCache, CompileKey, ServerSegmentPlan, WeightLiterals, SERVER_FINGERPRINT,
};
use crate::engine::{Engine, Exec, HostTensor};
use crate::error::{Error, Result};
use crate::host;
use qpart_core::model::ModelSpec;
use qpart_core::quant::{quantize, quantize_packed, PackedQuantized, QuantPattern, Quantized};
use std::collections::HashMap;
use std::sync::Arc;

/// Eval-batch size (matches the `_b32` executables in the bundle; the top
/// rung of [`BATCH_LADDER`]). Accuracy evaluation and phase-2 chunking
/// work in units of this.
pub const EVAL_BATCH: usize = 32;

/// The eval-batch shape ladder, ascending. Phase-2 execution pads a chunk
/// of N rows up to the **tightest rung ≥ N** instead of always padding to
/// [`EVAL_BATCH`] — a 1-row upload runs a `_b1` executable instead of
/// carrying 31 zero rows. The last rung equals `EVAL_BATCH`, so any chunk
/// the service produces (≤ `EVAL_BATCH` rows) fits some rung.
pub const BATCH_LADDER: [usize; 3] = [1, 8, 32];

/// Tightest [`BATCH_LADDER`] rung that holds `n` rows (callers keep
/// `n <= EVAL_BATCH`; larger `n` returns the top rung).
pub fn ladder_fit(n: usize) -> usize {
    for &b in &BATCH_LADDER {
        if b >= n {
            return b;
        }
    }
    EVAL_BATCH
}

/// One quantized layer ready for the wire / the q-kernel.
#[derive(Debug, Clone)]
pub struct QuantizedLayer {
    /// 1-based layer index.
    pub layer: usize,
    /// Quantized flat weights (codes + grid).
    pub weights: Quantized,
    /// Quantized bias (own grid, same bit-width).
    pub bias: Quantized,
    /// Flat weight dims (`[D, G]` / `[C_in·k·k, C_out]`).
    pub w_dims: Vec<usize>,
}

/// A fully quantized device segment (what the downlink ships).
#[derive(Debug, Clone)]
pub struct QuantizedSegment {
    pub model: String,
    pub pattern: QuantPattern,
    pub layers: Vec<QuantizedLayer>,
}

impl QuantizedSegment {
    /// Exact wire payload in bits: weight+bias codes at their bit-widths
    /// (grid headers are constant-size and ignored, as in paper Eq. 14).
    pub fn weight_payload_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.weights.payload_bits() + l.bias.payload_bits())
            .sum()
    }
}

/// One quantized layer already **bit-packed** for the wire — what the
/// fused downlink path produces ([`Executor::quantize_segment_packed`]).
/// Unlike [`QuantizedLayer`] there is no intermediate code vector: the
/// fused `quantize_packed` kernel streams Eq. 10 codes straight into the
/// packed bytes.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    /// 1-based layer index.
    pub layer: usize,
    /// Packed flat weights (params + packed bytes).
    pub weights: PackedQuantized,
    /// Packed bias (own grid, same bit-width).
    pub bias: PackedQuantized,
    /// Flat weight dims (`[D, G]` / `[C_in·k·k, C_out]`).
    pub w_dims: Vec<usize>,
}

/// A fully quantized-and-packed device segment: the bytes the downlink
/// ships, produced in one pass per layer (no `Vec<u32>` of codes).
#[derive(Debug, Clone)]
pub struct PackedSegment {
    pub model: String,
    pub pattern: QuantPattern,
    pub layers: Vec<PackedLayer>,
}

impl PackedSegment {
    /// Exact wire payload in bits (mirror of
    /// [`QuantizedSegment::weight_payload_bits`]).
    pub fn weight_payload_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.weights.payload_bits() + l.bias.payload_bits())
            .sum()
    }
}

/// Result of one batched phase-2 execution over coalesced rows
/// ([`Executor::run_server_segment_rows`]): the per-row logits plus how
/// the batch ladder shaped the run (occupancy metrics read these).
#[derive(Debug, Clone)]
pub struct RowBatchOutcome {
    /// One logits tensor per input row, in input order.
    pub logits: Vec<HostTensor>,
    /// The [`BATCH_LADDER`] rung the chunk executed at.
    pub run_batch: usize,
    /// Zero rows padded onto the stack to reach `run_batch`.
    pub padded_rows: usize,
}

/// Result of one split inference.
#[derive(Debug, Clone)]
pub struct SplitOutcome {
    pub logits: HostTensor,
    /// Downlink payload (quantized weights) in bits.
    pub weight_bits: u64,
    /// Uplink payload (quantized boundary activation) in bits.
    pub activation_bits: u64,
}

/// A quantized segment converted to executable inputs (codes as f32
/// tensors, dequantized bias) — built once per pattern **per server** (the
/// pool-wide [`CompileCache`] shares it across workers), reused across
/// requests (§Perf: per-request re-quantization was the split-path
/// bottleneck).
pub struct PreparedSegment {
    pub pattern: QuantPattern,
    pub weight_payload_bits: u64,
    layers: Vec<PreparedLayer>,
}

// SAFETY: a prepared segment is immutable after construction; its
// literals are host-side buffers that are only read. Shared read-only
// across pool workers via the compile cache (see `engine::Exec` for the
// matching executable-handle rationale).
unsafe impl Send for PreparedSegment {}
unsafe impl Sync for PreparedSegment {}

struct PreparedLayer {
    layer: usize,
    /// Pre-built XLA literals (codes are the big one — up to MBs); built
    /// once per pattern so per-request execution skips the host->literal
    /// copies (§Perf iteration 5).
    codes: xla::Literal,
    qmin: xla::Literal,
    step: xla::Literal,
    bias: xla::Literal,
}

impl std::fmt::Debug for PreparedLayer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PreparedLayer").field("layer", &self.layer).finish()
    }
}

impl PreparedSegment {
    /// Convert a quantized segment into executable-ready literals.
    pub fn from_segment(seg: &QuantizedSegment) -> Result<PreparedSegment> {
        let mut layers = Vec::with_capacity(seg.layers.len());
        for ql in &seg.layers {
            let codes = HostTensor::new(
                ql.w_dims.clone(),
                ql.weights.codes.iter().map(|&c| c as f32).collect(),
            )?;
            let bias_deq = ql.bias.dequantize();
            let bias = HostTensor::new(vec![1, bias_deq.len()], bias_deq)?;
            layers.push(PreparedLayer {
                layer: ql.layer,
                codes: codes.to_literal()?,
                qmin: HostTensor::scalar2(ql.weights.params.min).to_literal()?,
                step: HostTensor::scalar2(ql.weights.params.step()).to_literal()?,
                bias: bias.to_literal()?,
            });
        }
        Ok(PreparedSegment {
            pattern: seg.pattern.clone(),
            weight_payload_bits: seg.weight_payload_bits(),
            layers,
        })
    }
}

/// The executor: engine + bundle + a handle on the pool-wide
/// [`CompileCache`].
///
/// The bundle is shared via `Arc` — it is immutable after load, so an
/// executor pool keeps **one** resident copy of the weights instead of
/// one per worker. Compiled executables, prepared segments, weight
/// literals, and phase-2 server plans likewise live in the shared
/// compile cache: each is built once per server, whichever worker gets
/// there first. The executor itself stays `!Send` (PJRT clients are
/// single-device); only the bundle and the cache cross threads.
pub struct Executor {
    pub engine: Engine,
    pub bundle: Arc<Bundle>,
    /// Pool-wide compile cache ([`Executor::new`] makes a private one;
    /// pools inject a shared one via [`Executor::with_cache`]).
    cache: Arc<CompileCache>,
    /// Execute server segments with the pure-Rust reference kernels
    /// instead of PJRT (tests / bench-serve; linear archs only).
    host_fallback: bool,
}

fn pattern_fingerprint(p: &QuantPattern) -> String {
    format!("{}:{:?}:{}", p.partition, p.weight_bits, p.activation_bits)
}

impl Executor {
    pub fn new(bundle: Arc<Bundle>) -> Result<Executor> {
        Executor::with_cache(bundle, Arc::new(CompileCache::new()))
    }

    /// Build an executor over a shared compile cache (the executor-pool
    /// entry point: every worker passes the same `Arc`).
    pub fn with_cache(bundle: Arc<Bundle>, cache: Arc<CompileCache>) -> Result<Executor> {
        Ok(Executor { engine: Engine::cpu()?, bundle, cache, host_fallback: false })
    }

    /// The compile cache this executor shares.
    pub fn compile_cache(&self) -> Arc<CompileCache> {
        Arc::clone(&self.cache)
    }

    /// Toggle host-reference phase-2 execution (see [`crate::host`]).
    /// Explicit opt-in only: PJRT-less builds fail loudly otherwise.
    pub fn set_host_fallback(&mut self, on: bool) {
        self.host_fallback = on;
    }

    /// Whether phase-2 runs on the host reference kernels.
    pub fn host_fallback(&self) -> bool {
        self.host_fallback
    }

    /// Fetch a compiled executable from the pool-wide cache, compiling it
    /// on this worker's engine on first use anywhere in the pool.
    fn load_exec(&self, entry: &ExecEntry) -> Result<Arc<Exec>> {
        let path = self.bundle.root.join(&entry.hlo);
        self.cache.exec(&entry.name, || self.engine.compile_file(&path, &entry.name))
    }

    /// Quantize + prepare a segment, cached pool-wide per
    /// `(model, partition, pattern fingerprint)`.
    pub fn prepared_segment(
        &mut self,
        model: &str,
        pattern: &QuantPattern,
    ) -> Result<Arc<PreparedSegment>> {
        let key: CompileKey =
            (model.to_string(), pattern.partition, pattern_fingerprint(pattern));
        let cache = Arc::clone(&self.cache);
        cache.prepared(&key, || {
            let seg = self.quantize_segment(model, pattern)?;
            PreparedSegment::from_segment(&seg)
        })
    }

    /// Number of cached prepared segments (diagnostics; pool-wide).
    pub fn prepared_cached(&self) -> usize {
        self.cache.prepared_len()
    }

    /// Cached weight loading (one resident copy per server).
    pub fn weights(&mut self, model: &str) -> Result<Arc<ModelWeights>> {
        let bundle = Arc::clone(&self.bundle);
        self.cache.weights(model, || bundle.weights(model))
    }

    /// Executable-ready f32 weight literals, cached pool-wide per model.
    pub fn host_weights(&mut self, model: &str) -> Result<Arc<WeightLiterals>> {
        let weights = self.weights(model)?;
        self.cache.weight_literals(model, || {
            let mut v = Vec::with_capacity(weights.layers.len());
            for (w, b) in &weights.layers {
                v.push((
                    HostTensor::new(w.dims().to_vec(), w.data().to_vec())?.to_literal()?,
                    HostTensor::new(vec![1, b.len()], b.data().to_vec())?.to_literal()?,
                ));
            }
            Ok(WeightLiterals { layers: v })
        })
    }

    fn arch_of(&self, model: &str) -> Result<ModelSpec> {
        let m = self.bundle.model(model)?;
        Ok(self.bundle.arch(&m.arch)?.clone())
    }

    // ------------------------------------------------------------------
    // quantization (downlink preparation)
    // ------------------------------------------------------------------

    /// Quantize the device segment per `pattern` (the response payload).
    pub fn quantize_segment(
        &mut self,
        model: &str,
        pattern: &QuantPattern,
    ) -> Result<QuantizedSegment> {
        let weights = self.weights(model)?;
        let mut layers = Vec::with_capacity(pattern.partition);
        for l in 1..=pattern.partition {
            let bits = pattern.weight_bits[l - 1];
            let flat = weights.flat_w(l)?;
            let wq = quantize(flat.data(), bits).map_err(Error::Core)?;
            let bq = quantize(weights.bias(l).data(), bits).map_err(Error::Core)?;
            layers.push(QuantizedLayer {
                layer: l,
                weights: wq,
                bias: bq,
                w_dims: flat.dims().to_vec(),
            });
        }
        Ok(QuantizedSegment { model: model.to_string(), pattern: pattern.clone(), layers })
    }

    /// Fused quantize→pack of the device segment: each layer's weights
    /// and bias go `&[f32]` → packed wire bytes in a single pass
    /// (`qpart_core::quant::quantize_packed`), skipping the per-layer
    /// `Vec<u32>` code allocations [`Executor::quantize_segment`] pays.
    /// The serving encode path (`Service::encoded_for`) uses this; the
    /// output bytes are bit-identical to packing `quantize_segment`'s
    /// codes (the fused kernel is property-tested against the composition).
    pub fn quantize_segment_packed(
        &mut self,
        model: &str,
        pattern: &QuantPattern,
    ) -> Result<PackedSegment> {
        let weights = self.weights(model)?;
        let mut layers = Vec::with_capacity(pattern.partition);
        for l in 1..=pattern.partition {
            let bits = pattern.weight_bits[l - 1];
            let flat = weights.flat_w(l)?;
            let wq = quantize_packed(flat.data(), bits).map_err(Error::Core)?;
            let bq = quantize_packed(weights.bias(l).data(), bits).map_err(Error::Core)?;
            layers.push(PackedLayer {
                layer: l,
                weights: wq,
                bias: bq,
                w_dims: flat.dims().to_vec(),
            });
        }
        Ok(PackedSegment { model: model.to_string(), pattern: pattern.clone(), layers })
    }

    // ------------------------------------------------------------------
    // segment execution
    // ------------------------------------------------------------------

    /// Run the device segment (quantized, Pallas-kernel executables) on
    /// activation `x` (batch must be 1 or [`EVAL_BATCH`]). Returns the
    /// boundary activation *before* uplink quantization.
    pub fn run_device_segment(
        &mut self,
        arch: &ModelSpec,
        seg: &QuantizedSegment,
        x: HostTensor,
    ) -> Result<HostTensor> {
        let batch = x.batch();
        let mut acts: HashMap<usize, HostTensor> = HashMap::new();
        let mut h = x;
        acts.insert(0, h.clone());
        for ql in &seg.layers {
            let l = ql.layer;
            let entry = self.bundle.find_exec(&arch.name, "qlayer", Some(l), batch)?;
            let exec = self.load_exec(entry)?;
            let codes = HostTensor::new(
                ql.w_dims.clone(),
                ql.weights.codes.iter().map(|&c| c as f32).collect(),
            )?;
            let qmin = HostTensor::scalar2(ql.weights.params.min);
            let step = HostTensor::scalar2(ql.weights.params.step());
            let bias_deq = ql.bias.dequantize();
            let bias = HostTensor::new(vec![1, bias_deq.len()], bias_deq)?;
            h = reshape_for_layer(arch, l, h)?;
            let out = if entry.has_skip {
                let src = arch.residual_source(l).ok_or_else(|| {
                    Error::Shape(format!("exec {} expects a skip input", entry.name))
                })?;
                let skip = acts
                    .get(&src)
                    .ok_or_else(|| Error::Shape(format!("skip source {src} unavailable")))?;
                exec.run(&[&h, skip, &codes, &qmin, &step, &bias])?
            } else {
                exec.run(&[&h, &codes, &qmin, &step, &bias])?
            };
            h = out;
            acts.insert(l, h.clone());
        }
        Ok(h)
    }

    /// Run the server segment (full precision) from boundary `start`,
    /// optionally with overridden weights (pruning baseline).
    pub fn run_server_segment(
        &mut self,
        arch: &ModelSpec,
        weights: &ModelWeights,
        mut h: HostTensor,
        start: usize,
    ) -> Result<HostTensor> {
        let batch = h.batch();
        let mut acts: HashMap<usize, HostTensor> = HashMap::new();
        acts.insert(start, h.clone());
        for l in (start + 1)..=arch.num_layers() {
            let entry = self.bundle.find_exec(&arch.name, "f32layer", Some(l), batch)?;
            let exec = self.load_exec(entry)?;
            let (w, b) = &weights.layers[l - 1];
            let wt = HostTensor::new(w.dims().to_vec(), w.data().to_vec())?;
            let bias = HostTensor::new(vec![1, b.len()], b.data().to_vec())?;
            h = reshape_for_layer(arch, l, h)?;
            let out = if entry.has_skip {
                let src = arch.residual_source(l).ok_or_else(|| {
                    Error::Shape(format!("exec {} expects a skip input", entry.name))
                })?;
                let skip = acts
                    .get(&src)
                    .ok_or_else(|| Error::Shape(format!("skip source {src} unavailable")))?;
                exec.run(&[&h, skip, &wt, &bias])?
            } else {
                exec.run(&[&h, &wt, &bias])?
            };
            h = out;
            acts.insert(l, h.clone());
        }
        Ok(h)
    }

    /// Uplink simulation: quantize+dequantize the boundary activation.
    /// Returns (reconstructed activation, payload bits).
    pub fn uplink(&self, h: &HostTensor, bits: u8) -> Result<(HostTensor, u64)> {
        let q = quantize(&h.data, bits).map_err(Error::Core)?;
        let payload = q.payload_bits();
        Ok((HostTensor::new(h.dims.clone(), q.dequantize())?, payload))
    }

    /// Run the device segment from a prepared (cached) segment.
    pub fn run_device_segment_prepared(
        &mut self,
        arch: &ModelSpec,
        prep: &PreparedSegment,
        x: HostTensor,
    ) -> Result<HostTensor> {
        let batch = x.batch();
        let mut acts: HashMap<usize, HostTensor> = HashMap::new();
        let mut h = x;
        acts.insert(0, h.clone());
        for pl in &prep.layers {
            let l = pl.layer;
            let entry = self.bundle.find_exec(&arch.name, "qlayer", Some(l), batch)?;
            let exec = self.load_exec(entry)?;
            h = reshape_for_layer(arch, l, h)?;
            let h_lit = h.to_literal()?;
            let out = if entry.has_skip {
                let src = arch.residual_source(l).ok_or_else(|| {
                    Error::Shape(format!("exec {} expects a skip input", entry.name))
                })?;
                let skip = acts
                    .get(&src)
                    .ok_or_else(|| Error::Shape(format!("skip source {src} unavailable")))?
                    .to_literal()?;
                exec.run_literals(&[&h_lit, &skip, &pl.codes, &pl.qmin, &pl.step, &pl.bias])?
            } else {
                exec.run_literals(&[&h_lit, &pl.codes, &pl.qmin, &pl.step, &pl.bias])?
            };
            h = out;
            acts.insert(l, h.clone());
        }
        Ok(h)
    }

    /// Assemble (or fetch) the pool-shared phase-2 plan for
    /// `(model, start)` — the compile-once unit of server-segment
    /// execution. The execution path (PJRT vs host kernels) is part of
    /// the fingerprint: executors sharing one cache with different
    /// `host_fallback` settings must not serve each other's plans.
    fn server_plan(&mut self, model: &str, start: usize) -> Result<Arc<ServerSegmentPlan>> {
        let host_fallback = self.host_fallback;
        let fingerprint = if host_fallback {
            format!("{SERVER_FINGERPRINT}/host")
        } else {
            SERVER_FINGERPRINT.to_string()
        };
        let key: CompileKey = (model.to_string(), start, fingerprint);
        let cache = Arc::clone(&self.cache);
        cache.plan(&key, || {
            let arch = self.arch_of(model)?;
            let weights = self.weights(model)?;
            let literals =
                if host_fallback { None } else { Some(self.host_weights(model)?) };
            // rung availability is a pure function of the bundle, so the
            // per-execution ladder pick reads this instead of re-scanning
            // the executable manifest on every phase-2 chunk
            let rungs = if literals.is_none() {
                BATCH_LADDER.to_vec()
            } else {
                BATCH_LADDER
                    .iter()
                    .copied()
                    .filter(|&b| {
                        ((start + 1)..=arch.num_layers()).all(|l| {
                            self.bundle.find_exec(&arch.name, "f32layer", Some(l), b).is_ok()
                        })
                    })
                    .collect()
            };
            Ok(ServerSegmentPlan { arch, start, weights, literals, rungs })
        })
    }

    /// Pre-build the phase-2 plan for `(model, partition)` and, on the
    /// PJRT path, pre-compile its layer executables at every
    /// [`BATCH_LADDER`] rung the bundle lowered (the `--warm-cache`
    /// startup hook). Rungs absent from the bundle (e.g. no `_b8`
    /// artifacts) are skipped, not errors — execution falls back up the
    /// ladder the same way.
    pub fn warm_server_segment(&mut self, model: &str, partition: usize) -> Result<()> {
        let plan = self.server_plan(model, partition)?;
        if plan.literals.is_some() {
            for l in (partition + 1)..=plan.arch.num_layers() {
                // the plan's rung list already reflects what the bundle
                // lowered, so every lookup here resolves
                for &batch in &plan.rungs {
                    let entry =
                        self.bundle.find_exec(&plan.arch.name, "f32layer", Some(l), batch)?;
                    self.load_exec(entry)?;
                }
            }
        }
        Ok(())
    }

    /// Tightest rung of the plan's precomputed ladder that holds `n`
    /// rows, falling back to [`EVAL_BATCH`] (the shape every bundle
    /// lowers) when no listed rung fits. Host-fallback plans list every
    /// rung, so the fit is always exact there.
    fn ladder_batch(plan: &ServerSegmentPlan, n: usize) -> usize {
        let fit = ladder_fit(n);
        plan.rungs.iter().copied().find(|&b| b >= fit).unwrap_or(EVAL_BATCH)
    }

    /// Execute a phase-2 plan on one activation tensor (any batch the
    /// bundle has executables for; host fallback takes any batch).
    fn run_plan(&self, plan: &ServerSegmentPlan, h: HostTensor) -> Result<HostTensor> {
        let end = plan.arch.num_layers();
        let lits = match &plan.literals {
            None => return host::run_layers(&plan.arch, &plan.weights, h, plan.start, end),
            Some(l) => l,
        };
        let batch = h.batch();
        let mut h = h;
        let mut acts: HashMap<usize, HostTensor> = HashMap::new();
        acts.insert(plan.start, h.clone());
        for l in (plan.start + 1)..=end {
            let entry = self.bundle.find_exec(&plan.arch.name, "f32layer", Some(l), batch)?;
            let exec = self.load_exec(entry)?;
            let (wt, bias) = &lits.layers[l - 1];
            h = reshape_for_layer(&plan.arch, l, h)?;
            let h_lit = h.to_literal()?;
            let out = if entry.has_skip {
                let src = plan.arch.residual_source(l).ok_or_else(|| {
                    Error::Shape(format!("exec {} expects a skip input", entry.name))
                })?;
                let skip = acts
                    .get(&src)
                    .ok_or_else(|| Error::Shape(format!("skip source {src} unavailable")))?
                    .to_literal()?;
                exec.run_literals(&[&h_lit, &skip, wt, bias])?
            } else {
                exec.run_literals(&[&h_lit, wt, bias])?
            };
            h = out;
            acts.insert(l, h.clone());
        }
        Ok(h)
    }

    /// Server segment over the pool-shared plan (the serving hot path;
    /// `run_server_segment` remains for overridden weights).
    pub fn run_server_segment_cached(
        &mut self,
        model: &str,
        h: HostTensor,
        start: usize,
    ) -> Result<HostTensor> {
        let plan = self.server_plan(model, start)?;
        self.run_plan(&plan, h)
    }

    /// **One** batched server-segment execution over up to [`EVAL_BATCH`]
    /// boundary rows of the same `(model, partition)` — the phase-2 half
    /// of the coalescing dataplane. Rows (each batch-1) are stacked,
    /// zero-padded up to the **tightest [`BATCH_LADDER`] rung** the plan
    /// can execute (a 1-row chunk runs a `_b1` executable; 2–8 rows a
    /// `_b8` when the bundle lowered one), and the logits are split back
    /// per row. Callers chunk larger groups into `⌈N / EVAL_BATCH⌉`
    /// calls; the outcome reports the rung used and the rows padded so
    /// occupancy metrics can account for the waste.
    pub fn run_server_segment_rows(
        &mut self,
        model: &str,
        rows: &[HostTensor],
        start: usize,
    ) -> Result<RowBatchOutcome> {
        if rows.is_empty() {
            return Ok(RowBatchOutcome { logits: Vec::new(), run_batch: 0, padded_rows: 0 });
        }
        if rows.len() > EVAL_BATCH {
            return Err(Error::Shape(format!(
                "{} rows exceed EVAL_BATCH {EVAL_BATCH}; chunk before calling",
                rows.len()
            )));
        }
        if let Some(bad) = rows.iter().find(|r| r.batch() != 1) {
            return Err(Error::Shape(format!(
                "phase-2 rows must be batch-1, got {:?}",
                bad.dims
            )));
        }
        let n = rows.len();
        let stacked = HostTensor::stack(rows)?;
        let plan = self.server_plan(model, start)?;
        let run_batch = Self::ladder_batch(&plan, n);
        let padded =
            if n == run_batch { stacked } else { stacked.slice_rows_padded(0, n, run_batch) };
        let logits = self.run_plan(&plan, padded)?;
        if logits.batch() < n {
            return Err(Error::Shape(format!(
                "plan returned {} logits rows for {n} inputs",
                logits.batch()
            )));
        }
        Ok(RowBatchOutcome {
            logits: (0..n).map(|i| logits.slice_rows(i, i + 1)).collect(),
            run_batch,
            padded_rows: run_batch - n,
        })
    }

    /// The full QPART split-inference path (prepared-segment cached).
    pub fn run_split(
        &mut self,
        model: &str,
        pattern: &QuantPattern,
        x: HostTensor,
    ) -> Result<SplitOutcome> {
        let arch = self.arch_of(model)?;
        let prep = self.prepared_segment(model, pattern)?;
        let boundary = self.run_device_segment_prepared(&arch, &prep, x)?;
        let (boundary, act_bits) = self.uplink(&boundary, pattern.activation_bits)?;
        let logits = self.run_server_segment_cached(model, boundary, pattern.partition)?;
        Ok(SplitOutcome {
            logits,
            weight_bits: prep.weight_payload_bits,
            activation_bits: act_bits,
        })
    }

    /// Full-precision single-shot inference via the `full_*` executable.
    pub fn run_full(&mut self, model: &str, x: HostTensor) -> Result<HostTensor> {
        let arch = self.arch_of(model)?;
        let weights = self.weights(model)?;
        let entry = self.bundle.find_exec(&arch.name, "full", None, x.batch())?;
        let exec = self.load_exec(entry)?;
        let mut inputs: Vec<HostTensor> = vec![x];
        for l in 1..=arch.num_layers() {
            let (w, b) = &weights.layers[l - 1];
            inputs.push(HostTensor::new(w.dims().to_vec(), w.data().to_vec())?);
            inputs.push(HostTensor::new(vec![1, b.len()], b.data().to_vec())?);
        }
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        exec.run(&refs)
    }

    /// “No Optimization” baseline: f32 layers on device and server (same
    /// numerics as [`run_full`]); payload = f32 weights + f32 activation.
    pub fn run_split_f32(&mut self, model: &str, p: usize, x: HostTensor) -> Result<SplitOutcome> {
        let arch = self.arch_of(model)?;
        let weights = self.weights(model)?;
        // run 0..p then p..L through the f32 path (device == server numerics)
        let batch = x.batch() as u64;
        let mid = self.run_server_segment_upto(&arch, &weights, x, 0, p)?;
        let logits = self.run_server_segment(&arch, &weights, mid, p)?;
        Ok(SplitOutcome {
            logits,
            // f32 weights (incl. bias, counted by weight_params)
            weight_bits: arch.segment_weight_bits_f32(p),
            activation_bits: 32 * batch * arch.activation_elems(p),
        })
    }

    /// Pruning baseline: zero the lowest-norm output neurons of device-side
    /// layers (`ratio` of them), rescale nothing, run f32 split. Returns the
    /// outcome with the payload reduced by the kept fraction.
    pub fn run_split_pruned(
        &mut self,
        model: &str,
        p: usize,
        ratio: f64,
        x: HostTensor,
    ) -> Result<SplitOutcome> {
        let arch = self.arch_of(model)?;
        let weights = self.weights(model)?;
        let pruned = prune_weights(&arch, &weights, p, ratio).map_err(Error::Core)?;
        let x_batch = x.batch() as u64;
        let mid = self.run_server_segment_upto(&arch, &pruned, x, 0, p)?;
        let logits = self.run_server_segment(&arch, &pruned, mid, p)?;
        let kept = 1.0 - ratio;
        let weight_bits = (arch.segment_weight_bits_f32(p) as f64 * kept) as u64;
        Ok(SplitOutcome {
            logits,
            weight_bits,
            activation_bits: 32 * x_batch * arch.activation_elems(p),
        })
    }

    /// Autoencoder (DeepCOD-style) baseline: f32 device segment, encode the
    /// boundary activation (uplink ships the bottleneck code), decode on
    /// the server, continue. Only valid at boundaries the bundle trained.
    pub fn run_split_ae(&mut self, model: &str, p: usize, x: HostTensor) -> Result<SplitOutcome> {
        let arch = self.arch_of(model)?;
        let weights = self.weights(model)?;
        let ab = *self
            .bundle
            .model(model)?
            .ae_boundaries
            .iter()
            .find(|b| b.boundary == p)
            .ok_or_else(|| Error::NotInBundle(format!("AE at boundary {p} of {model}")))?;
        let [we, be, wd, bd] = self.bundle.ae_params(model, p)?;
        let batch = x.batch();
        let h = self.run_server_segment_upto(&arch, &weights, x, 0, p)?;
        // flatten for the linear AE
        let h = HostTensor::new(vec![batch, h.row_elems()], h.data.clone())?;
        let enc_e = self.bundle.find_exec(&arch.name, "ae_enc", Some(p), batch)?;
        let enc = self.load_exec(enc_e)?;
        let we_t = HostTensor::new(we.dims().to_vec(), we.data().to_vec())?;
        let be_t = HostTensor::new(vec![1, be.len()], be.data().to_vec())?;
        let z = enc.run(&[&h, &we_t, &be_t])?;
        let dec_e = self.bundle.find_exec(&arch.name, "ae_dec", Some(p), batch)?;
        let dec = self.load_exec(dec_e)?;
        let wd_t = HostTensor::new(wd.dims().to_vec(), wd.data().to_vec())?;
        let bd_t = HostTensor::new(vec![1, bd.len()], bd.data().to_vec())?;
        let rec = dec.run(&[&z, &wd_t, &bd_t])?;
        // reshape back to the layer's natural activation shape
        let shape = activation_shape(&arch, p, batch);
        let rec = HostTensor::new(shape, rec.data)?;
        let logits = self.run_server_segment(&arch, &weights, rec, p)?;
        // payload: f32 weights of the segment + f32 encoder (shipped to the
        // device) + f32 bottleneck code uplink (per sample)
        let enc_params = (we.len() + be.len()) as u64;
        Ok(SplitOutcome {
            logits,
            weight_bits: arch.segment_weight_bits_f32(p) + 32 * enc_params,
            activation_bits: 32 * batch as u64 * ab.bottleneck as u64,
        })
    }

    /// Run f32 layers `start+1..=end` (helper for baselines).
    fn run_server_segment_upto(
        &mut self,
        arch: &ModelSpec,
        weights: &ModelWeights,
        mut h: HostTensor,
        start: usize,
        end: usize,
    ) -> Result<HostTensor> {
        let batch = h.batch();
        let mut acts: HashMap<usize, HostTensor> = HashMap::new();
        acts.insert(start, h.clone());
        for l in (start + 1)..=end {
            let entry = self.bundle.find_exec(&arch.name, "f32layer", Some(l), batch)?;
            let exec = self.load_exec(entry)?;
            let (w, b) = &weights.layers[l - 1];
            let wt = HostTensor::new(w.dims().to_vec(), w.data().to_vec())?;
            let bias = HostTensor::new(vec![1, b.len()], b.data().to_vec())?;
            h = reshape_for_layer(arch, l, h)?;
            let out = if entry.has_skip {
                let src = arch.residual_source(l).unwrap_or(start);
                let skip = acts.get(&src).unwrap_or(&h);
                exec.run(&[&h, skip, &wt, &bias])?
            } else {
                exec.run(&[&h, &wt, &bias])?
            };
            h = out;
            acts.insert(l, h.clone());
        }
        Ok(h)
    }

    // ------------------------------------------------------------------
    // accuracy evaluation (Table III / Table IV)
    // ------------------------------------------------------------------

    /// Top-1 accuracy of `run` over a dataset, in EVAL_BATCH chunks with
    /// zero-padding on the tail.
    pub fn eval_accuracy<F>(&mut self, x: &HostTensor, y: &[i32], mut run: F) -> Result<f64>
    where
        F: FnMut(&mut Self, HostTensor) -> Result<HostTensor>,
    {
        let n = x.batch();
        if n == 0 || n != y.len() {
            return Err(Error::Shape(format!("{} samples vs {} labels", n, y.len())));
        }
        let mut correct = 0usize;
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + EVAL_BATCH).min(n);
            let chunk = x.slice_rows_padded(lo, hi, EVAL_BATCH);
            let logits = run(self, chunk)?;
            let classes = logits.row_elems();
            for (i, &label) in y[lo..hi].iter().enumerate() {
                let row = &logits.data[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as i32)
                    .unwrap();
                if pred == label {
                    correct += 1;
                }
            }
            lo = hi;
        }
        Ok(correct as f64 / n as f64)
    }
}

/// Reshape `h` to what layer `l` expects (flatten conv → linear boundary).
fn reshape_for_layer(arch: &ModelSpec, l: usize, h: HostTensor) -> Result<HostTensor> {
    use qpart_core::model::LayerKind;
    let batch = h.batch();
    match arch.layers[l - 1].kind {
        LayerKind::Linear { d_in, .. } => {
            if h.row_elems() != d_in {
                return Err(Error::Shape(format!(
                    "layer {l} expects {d_in} inputs, activation has {}",
                    h.row_elems()
                )));
            }
            HostTensor::new(vec![batch, d_in], h.data)
        }
        LayerKind::Conv2d { c_in, in_side, .. } => {
            if h.row_elems() != c_in * in_side * in_side {
                return Err(Error::Shape(format!(
                    "layer {l} expects {}x{}x{} input, activation has {}",
                    c_in,
                    in_side,
                    in_side,
                    h.row_elems()
                )));
            }
            HostTensor::new(vec![batch, c_in, in_side, in_side], h.data)
        }
    }
}

/// Activation shape at boundary `l` with the given batch.
fn activation_shape(arch: &ModelSpec, l: usize, batch: usize) -> Vec<usize> {
    use qpart_core::model::LayerKind;
    if l == 0 {
        let mut v = vec![batch];
        v.extend_from_slice(&arch.input_shape);
        return v;
    }
    match arch.layers[l - 1].kind {
        LayerKind::Linear { d_out, .. } => vec![batch, d_out],
        LayerKind::Conv2d { c_out, out_side, .. } => vec![batch, c_out, out_side, out_side],
    }
}

/// Structured pruning of device-side layers 1..=p: zero the `ratio`
/// lowest-L2 output neurons of each layer (and the corresponding input
/// rows of the next layer). Functionally equivalent to removing them; the
/// payload accounting charges only the kept fraction.
pub fn prune_weights(
    arch: &ModelSpec,
    weights: &ModelWeights,
    p: usize,
    ratio: f64,
) -> qpart_core::Result<ModelWeights> {
    use qpart_core::model::LayerKind;
    if !(0.0..1.0).contains(&ratio) {
        return Err(qpart_core::Error::InvalidArg(format!("prune ratio {ratio}")));
    }
    let mut out = weights.clone();
    for l in 1..=p {
        let (w, b) = &mut out.layers[l - 1];
        let (rows, cols) = match arch.layers[l - 1].kind {
            LayerKind::Linear { d_in, d_out } => (d_in, d_out),
            LayerKind::Conv2d { c_in, c_out, k, .. } => (c_in * k * k, c_out),
        };
        // column norms
        let mut norms: Vec<(usize, f64)> = (0..cols)
            .map(|c| {
                let s: f64 = (0..rows)
                    .map(|r| {
                        let v = w.data()[r * cols + c] as f64;
                        v * v
                    })
                    .sum();
                (c, s)
            })
            .collect();
        norms.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let n_prune = ((cols as f64) * ratio).floor() as usize;
        let pruned: Vec<usize> = norms[..n_prune].iter().map(|&(c, _)| c).collect();
        let data = w.data_mut();
        for &c in &pruned {
            for r in 0..rows {
                data[r * cols + c] = 0.0;
            }
            b.data_mut()[c] = 0.0;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_core::model::mlp6;
    use qpart_core::tensor::Tensor;

    fn toy_weights(arch: &ModelSpec) -> ModelWeights {
        let layers = (1..=arch.num_layers())
            .map(|l| {
                use qpart_core::model::LayerKind;
                let (w_dims, g) = match arch.layers[l - 1].kind {
                    LayerKind::Linear { d_in, d_out } => (vec![d_in, d_out], d_out),
                    LayerKind::Conv2d { c_in, c_out, k, .. } => {
                        (vec![c_in, k, k, c_out], c_out)
                    }
                };
                let n: usize = w_dims.iter().product();
                let data: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
                (Tensor::new(w_dims, data).unwrap(), Tensor::zeros(vec![g]))
            })
            .collect();
        ModelWeights { layers }
    }

    #[test]
    fn prune_zeroes_expected_fraction() {
        let arch = mlp6();
        let w = toy_weights(&arch);
        let pruned = prune_weights(&arch, &w, 2, 0.5).unwrap();
        for l in 1..=2usize {
            let cols = match arch.layers[l - 1].kind {
                qpart_core::model::LayerKind::Linear { d_out, .. } => d_out,
                _ => unreachable!(),
            };
            let w_t = pruned.flat_w(l).unwrap();
            let zero_cols = (0..cols)
                .filter(|&c| {
                    (0..w_t.dims()[0]).all(|r| w_t.data()[r * cols + c] == 0.0)
                })
                .count();
            assert_eq!(zero_cols, cols / 2, "layer {l}");
        }
        // untouched layers unchanged
        assert_eq!(pruned.layers[3].0, w.layers[3].0);
    }

    #[test]
    fn prune_rejects_bad_ratio() {
        let arch = mlp6();
        let w = toy_weights(&arch);
        assert!(prune_weights(&arch, &w, 1, 1.0).is_err());
        assert!(prune_weights(&arch, &w, 1, -0.1).is_err());
    }

    #[test]
    fn activation_shapes() {
        let arch = mlp6();
        assert_eq!(activation_shape(&arch, 0, 4), vec![4, 784]);
        assert_eq!(activation_shape(&arch, 3, 2), vec![2, 128]);
    }

    #[test]
    fn ladder_fit_picks_tightest_rung() {
        assert_eq!(ladder_fit(1), 1);
        assert_eq!(ladder_fit(2), 8);
        assert_eq!(ladder_fit(7), 8);
        assert_eq!(ladder_fit(8), 8);
        assert_eq!(ladder_fit(9), 32);
        assert_eq!(ladder_fit(32), 32);
        // over-the-top requests clamp to the EVAL_BATCH rung (callers
        // chunk to ≤ EVAL_BATCH before execution)
        assert_eq!(ladder_fit(40), EVAL_BATCH);
        // structural invariants the service relies on
        assert_eq!(*BATCH_LADDER.last().unwrap(), EVAL_BATCH);
        assert!(BATCH_LADDER.windows(2).all(|w| w[0] < w[1]), "ascending");
    }

    // PJRT-backed executor tests live in rust/qpart/tests/ (need artifacts).
}
