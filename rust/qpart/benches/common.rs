//! Shared setup for the paper-figure/table benches.
//!
//! Every bench works against the real artifact bundle when present
//! (`make artifacts`), and falls back to the synthetic calibration tables
//! for the descriptor-only figures so `cargo bench` never hard-fails.

#![allow(dead_code)]

use qpart::prelude::*;
use std::sync::Arc;

pub const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

/// Locate the artifacts directory relative to the workspace.
pub fn artifacts_dir() -> Option<&'static str> {
    for dir in ["artifacts", "../artifacts", "../../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(dir);
        }
    }
    None
}

pub fn load_bundle() -> Option<Arc<Bundle>> {
    artifacts_dir().and_then(|d| Bundle::load(d).ok()).map(Arc::new)
}

/// The mlp6 arch + calibration (+ pattern set), bundle-backed when possible.
pub struct Mlp6Setup {
    pub arch: ModelSpec,
    pub calib: CalibrationTable,
    pub patterns: PatternSet,
    pub bundle: Option<Arc<Bundle>>,
    /// true when the calibration came from the real noise-injection pass
    pub calibrated: bool,
}

pub fn mlp6_setup() -> Mlp6Setup {
    let bundle = load_bundle();
    let arch = qpart::core::model::mlp6();
    let (calib, calibrated) = match &bundle {
        Some(b) => match b.calibration("mlp6") {
            Ok(c) => (c, true),
            Err(_) => (CalibrationTable::synthetic(&arch, &LEVELS, 1), false),
        },
        None => (CalibrationTable::synthetic(&arch, &LEVELS, 1), false),
    };
    let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
    Mlp6Setup { arch, calib, patterns, bundle, calibrated }
}

/// Index of the 1% accuracy level.
pub const LEVEL_1PCT: usize = 2;

/// The four compared schemes with the parameters used across the figures.
pub fn schemes() -> Vec<Scheme> {
    vec![
        Scheme::Qpart,
        Scheme::NoOpt,
        Scheme::Pruning { ratio: 0.05 },
        Scheme::Autoencoder { compress: 4.0 },
    ]
}

pub fn banner(name: &str, calibrated: bool) {
    println!("\n### {name} ###");
    if calibrated {
        println!("(using build-time noise-injection calibration from artifacts/)");
    } else {
        println!("(artifacts/ missing — using synthetic calibration; run `make artifacts`)");
    }
}
