//! **Fig. 8** — Layer-wise Energy Consumption Comparison (4 schemes).
//!
//! Paper: QPART has the lowest device energy at every partition point;
//! the autoencoder pays extra encode compute (and its f32 weights), so it
//! is the worst; pruning lies between.

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::{fmt_si, Table};

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 8 — layer-wise device energy, 4 schemes (mlp6)", setup.calibrated);
    let cost = CostModel::paper_default();
    let arch = &setup.arch;
    let list = schemes();

    let mut table = Table::new(
        "device energy (J) vs partition point",
        &["p", "QPART", "No Optimization", "Model Pruning", "Auto-Encoder"],
    );
    let mut qpart_lowest = 0usize;
    for p in 0..=arch.num_layers() {
        let vals: Vec<f64> = list
            .iter()
            .map(|&s| {
                let r = scheme_cost(s, arch, &cost, p, Some(&setup.patterns), LEVEL_1PCT)
                    .unwrap();
                r.breakdown.total_energy_j()
            })
            .collect();
        if vals[0] <= vals.iter().cloned().fold(f64::INFINITY, f64::min) + 1e-18 {
            qpart_lowest += 1;
        }
        table.row(
            std::iter::once(p.to_string())
                .chain(vals.iter().map(|&v| fmt_si(v)))
                .collect(),
        );
    }
    table.print();
    println!(
        "\npaper shape: QPART lowest energy everywhere — holds at {}/{} points.",
        qpart_lowest,
        arch.num_layers() + 1
    );
}
