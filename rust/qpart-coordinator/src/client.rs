//! The device side of the protocol, for examples / CLI / tests.
//!
//! `DeviceClient` plays a faithful edge device: it requests a segment,
//! **executes the received quantized layers locally** through its own PJRT
//! engine (the same Pallas-kernel executables a real deployment would ship
//! in the device image), quantizes + bit-packs the boundary activation,
//! uploads it, and receives the prediction. It can negotiate binary
//! frames ([`DeviceClient::negotiate_binary`]) — the read path accepts
//! either framing transparently, and a granted negotiation is symmetric:
//! segment replies arrive as binary frames and activation uploads are
//! sent as binary request frames (no base64 on the uplink).

use crate::service::boundary_dims;
use qpart_core::model::ModelSpec;
use qpart_core::quant::{pack_bits, quantize, QuantPattern};
use qpart_proto::frame::{read_any_frame, write_binary_frame, write_frame};
use qpart_proto::messages::{
    ActivationUpload, HelloRequest, InferReply, InferRequest, Request, Response, SimulateRequest,
};
use qpart_runtime::executor::{QuantizedLayer, QuantizedSegment};
use qpart_runtime::{Bundle, Error, Executor, HostTensor, Result};
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Arc;

/// Blocking protocol client + local (device-side) executor.
pub struct DeviceClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Device-side runtime (needs the bundle for the HLO executables — in
    /// a real deployment these ship in the device image).
    executor: Executor,
    bundle: Arc<Bundle>,
    /// Whether the server granted binary segment frames for this session.
    binary_frames: bool,
}

impl DeviceClient {
    pub fn connect(addr: &str, bundle: Arc<Bundle>) -> Result<DeviceClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?; // request/response over loopback: no Nagle
        let writer = stream.try_clone()?;
        Ok(DeviceClient {
            reader: BufReader::new(stream),
            writer,
            executor: Executor::new(Arc::clone(&bundle))?,
            bundle,
            binary_frames: false,
        })
    }

    /// Send one request and read one response (either framing). After a
    /// granted [`DeviceClient::negotiate_binary`], activation uploads go
    /// out as binary request frames; everything else stays JSON.
    pub fn call(&mut self, req: &Request) -> Result<Response> {
        match req {
            Request::Activation(a) if self.binary_frames => {
                let (header, blob) = a.to_binary();
                write_binary_frame(&mut self.writer, &header, &blob)
                    .map_err(|e| Error::Xla(format!("write: {e}")))?;
            }
            _ => write_frame(&mut self.writer, &req.to_line())
                .map_err(|e| Error::Xla(format!("write: {e}")))?,
        }
        let frame =
            read_any_frame(&mut self.reader).map_err(|e| Error::Xla(format!("read: {e}")))?;
        Response::from_frame(&frame).map_err(Error::Core)
    }

    pub fn ping(&mut self) -> Result<bool> {
        Ok(matches!(self.call(&Request::Ping)?, Response::Pong))
    }

    /// Ask the server for binary segment frames; returns what was granted
    /// (false when the server has `--binary-frames false`).
    pub fn negotiate_binary(&mut self) -> Result<bool> {
        match self.call(&Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() }))? {
            Response::Hello(h) => {
                self.binary_frames = h.binary_frames;
                Ok(h.binary_frames)
            }
            other => Err(Error::Xla(format!("unexpected hello response {other:?}"))),
        }
    }

    /// Whether this session negotiated binary segment frames.
    pub fn binary_frames(&self) -> bool {
        self.binary_frames
    }

    /// Full two-phase inference for input `x` (batch 1).
    /// Returns (prediction, logits, reply-pattern-partition).
    pub fn infer(
        &mut self,
        req: InferRequest,
        x: HostTensor,
    ) -> Result<(i32, Vec<f64>, usize)> {
        let model = req.model.clone();
        let reply = match self.call(&Request::Infer(req))? {
            Response::Segment(r) => r,
            Response::Error(e) => {
                return Err(Error::Xla(format!("server error {}: {}", e.code, e.message)))
            }
            other => return Err(Error::Xla(format!("unexpected response {other:?}"))),
        };
        let m = self.bundle.model(&model)?;
        let arch = self.bundle.arch(&m.arch)?.clone();
        // rebuild the quantized segment from the wire blobs
        let seg = segment_from_reply(&reply)?;
        // device-side inference through the Pallas-kernel executables
        let boundary = self.executor.run_device_segment(&arch, &seg, x)?;
        // quantize + pack the uplink activation
        let bits = reply.pattern.activation_bits;
        let q = quantize(&boundary.data, bits).map_err(Error::Core)?;
        let packed = pack_bits(&q.codes, bits).map_err(Error::Core)?;
        let upload = ActivationUpload {
            session: reply.session,
            bits,
            qmin: q.params.min,
            step: q.params.step(),
            dims: boundary_dims(&arch, reply.pattern.partition, 1),
            packed,
        };
        match self.call(&Request::Activation(upload))? {
            Response::Result(r) => Ok((r.prediction, r.logits, reply.pattern.partition)),
            Response::Error(e) => {
                Err(Error::Xla(format!("server error {}: {}", e.code, e.message)))
            }
            other => Err(Error::Xla(format!("unexpected response {other:?}"))),
        }
    }

    /// One-shot simulate call (server plays both roles).
    pub fn simulate(&mut self, req: InferRequest, x: &HostTensor) -> Result<Response> {
        self.call(&Request::Simulate(SimulateRequest {
            req,
            input: x.data.clone(),
            input_dims: x.dims.clone(),
        }))
    }
}

/// Reconstruct a [`QuantizedSegment`] from the wire reply (device side).
pub fn segment_from_reply(reply: &InferReply) -> Result<QuantizedSegment> {
    use qpart_core::quant::{unpack_bits, QuantParams, Quantized};
    let mut layers = Vec::with_capacity(reply.segment.layers.len());
    for blob in &reply.segment.layers {
        let n: usize = blob.w_dims.iter().product();
        let w_codes = unpack_bits(&blob.w_packed, n, blob.bits).map_err(Error::Core)?;
        let b_codes = unpack_bits(&blob.b_packed, blob.b_len, blob.bits).map_err(Error::Core)?;
        let levels = ((1u32 << blob.bits) - 1) as f32;
        let w_params =
            QuantParams::from_range(blob.bits, blob.w_qmin, blob.w_qmin + blob.w_step * levels)
                .map_err(Error::Core)?;
        let b_params =
            QuantParams::from_range(blob.bits, blob.b_qmin, blob.b_qmin + blob.b_step * levels)
                .map_err(Error::Core)?;
        layers.push(QuantizedLayer {
            layer: blob.layer,
            weights: Quantized { params: w_params, codes: w_codes },
            bias: Quantized { params: b_params, codes: b_codes },
            w_dims: blob.w_dims.clone(),
        });
    }
    let pattern = QuantPattern {
        partition: reply.pattern.partition,
        weight_bits: reply.pattern.weight_bits.clone(),
        activation_bits: reply.pattern.activation_bits,
        accuracy_level: reply.pattern.accuracy_level,
        predicted_degradation: reply.pattern.predicted_degradation,
    };
    Ok(QuantizedSegment { model: reply.model.clone(), pattern, layers })
}

/// Convenience: the paper's Table II device profile as an [`InferRequest`].
pub fn paper_request(model: &str, accuracy_budget: f64) -> InferRequest {
    InferRequest {
        model: model.to_string(),
        accuracy_budget,
        channel_capacity_bps: 200e6,
        tx_power_w: 1.0,
        clock_hz: 200e6,
        cycles_per_mac: 5.0,
        kappa: 3e-27,
        memory_bits: 256 * 1024 * 1024 * 8,
        weights: None,
        deadline_ms: None,
    }
}

/// Helper for tests: a ModelSpec-consistent random input (batch 1).
pub fn random_input(arch: &ModelSpec, seed: u64) -> HostTensor {
    let mut rng = qpart_core::rng::Rng::new(seed);
    let mut dims = vec![1usize];
    dims.extend_from_slice(&arch.input_shape);
    let n: usize = dims.iter().product();
    let data: Vec<f32> = (0..n).map(|_| rng.range_f64(0.0, 1.0) as f32).collect();
    HostTensor { dims, data }
}
