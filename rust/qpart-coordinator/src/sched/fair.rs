//! Per-connection fair queuing: a token-bucket rate limiter applied at the
//! front end before a request is enqueued for the worker pool.
//!
//! Motivation (paper §I): the edge fleet is heterogeneous — one hot phone
//! issuing requests in a tight loop can monopolise the queue and starve a
//! slow sensor whose requests are rare but latency-critical. The bucket is
//! keyed by connection: each key accrues `rate` tokens/s up to a burst cap,
//! one request spends one token, and a request arriving to an empty bucket
//! is refused with a `throttled` error (counted in `sched_throttled_total`)
//! instead of occupying queue capacity.
//!
//! `rate == 0` disables the limiter entirely (the default), so existing
//! deployments are unaffected unless `--fair-rate`/`serving.fair_rate` is
//! set.
//!
//! **Class weights**: a key's sustained rate and burst scale by its
//! device-class weight (`hello.weight`, from `DeviceClass.weight`), so
//! `--fair-rate` sets the *base* (weight-1.0) rate and a 0.5-weight
//! watch class accrues tokens half as fast as a 1.0-weight phone class.
//! Weights are clamped server-side — a client cannot grant itself an
//! unbounded rate — and default to 1.0, which reproduces the unweighted
//! behavior exactly.

use super::batch::lock_recover;
use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

/// Burst headroom: a fresh key may burst this many seconds' worth of
/// tokens before the steady-state rate applies.
const BURST_SECS: f64 = 2.0;

/// Clamp bounds for per-key class weights: a device may declare itself
/// rarer (slower) or hotter than the base rate, within reason.
const MIN_WEIGHT: f64 = 0.01;
const MAX_WEIGHT: f64 = 100.0;

/// Token-bucket state for one key.
#[derive(Debug, Clone, Copy)]
struct Bucket {
    tokens: f64,
    last_s: f64,
    /// Class weight scaling this key's rate and burst (1.0 = base).
    weight: f64,
}

/// A token-bucket rate limiter keyed by connection/session id.
#[derive(Debug)]
pub struct FairQueue {
    /// Sustained admission rate per key (requests/s); 0 disables.
    rate: f64,
    /// Bucket capacity (tokens).
    burst: f64,
    epoch: Instant,
    buckets: Mutex<HashMap<u64, Bucket>>,
}

impl FairQueue {
    /// Create a limiter admitting `rate` requests/s per key with a burst
    /// of `max(1, rate * 2s)` tokens. `rate <= 0` disables the limiter.
    pub fn new(rate: f64) -> FairQueue {
        let rate = if rate.is_finite() && rate > 0.0 { rate } else { 0.0 };
        FairQueue {
            rate,
            burst: (rate * BURST_SECS).max(1.0),
            epoch: Instant::now(),
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Whether the limiter is active.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// The configured base (weight-1.0) per-key rate (requests/s); 0 when
    /// disabled.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Bucket capacity for a key of the given weight.
    fn burst_for(&self, weight: f64) -> f64 {
        (self.rate * weight * BURST_SECS).max(1.0)
    }

    /// Set `key`'s class weight (from its `hello`): the key's sustained
    /// rate becomes `rate * weight` and its burst scales to match.
    /// Non-positive / non-finite weights fall back to 1.0; the rest are
    /// clamped to `[0.01, 100]` so a client cannot grant itself an
    /// unbounded rate. No-op while the limiter is disabled.
    pub fn set_weight(&self, key: u64, weight: f64) {
        self.set_weight_at(key, weight, self.epoch.elapsed().as_secs_f64());
    }

    /// Deterministic core of [`Self::set_weight`].
    pub fn set_weight_at(&self, key: u64, weight: f64, now_s: f64) {
        if self.rate <= 0.0 {
            return;
        }
        let weight = if weight.is_finite() && weight > 0.0 {
            weight.clamp(MIN_WEIGHT, MAX_WEIGHT)
        } else {
            1.0
        };
        let burst = self.burst_for(weight);
        let mut buckets = lock_recover(&self.buckets);
        let b = buckets.entry(key).or_insert(Bucket { tokens: burst, last_s: now_s, weight });
        b.weight = weight;
        // a weight drop mid-connection shrinks an over-cap balance too
        b.tokens = b.tokens.min(burst);
    }

    /// Try to admit one request for `key` now.
    pub fn try_admit(&self, key: u64) -> bool {
        self.admit_at(key, self.epoch.elapsed().as_secs_f64())
    }

    /// Deterministic core: try to admit one request for `key` at time
    /// `now_s` (seconds from an arbitrary epoch; must be monotone per key).
    pub fn admit_at(&self, key: u64, now_s: f64) -> bool {
        if self.rate <= 0.0 {
            return true;
        }
        let mut buckets = lock_recover(&self.buckets);
        let b = buckets
            .entry(key)
            .or_insert(Bucket { tokens: self.burst, last_s: now_s, weight: 1.0 });
        let burst = self.burst_for(b.weight);
        // Only advance the per-key clock forward: crediting a backwards
        // timestamp and then re-crediting the same interval would mint
        // tokens.
        let dt = (now_s - b.last_s).max(0.0);
        b.tokens = (b.tokens + dt * self.rate * b.weight).min(burst);
        b.last_s = b.last_s.max(now_s);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Drop per-key state for a closed connection so the map does not grow
    /// with connection churn.
    pub fn forget(&self, key: u64) {
        lock_recover(&self.buckets).remove(&key);
    }

    /// Number of tracked keys (for tests/diagnostics).
    pub fn tracked(&self) -> usize {
        lock_recover(&self.buckets).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_admits_everything() {
        let q = FairQueue::new(0.0);
        assert!(!q.enabled());
        for i in 0..10_000u64 {
            assert!(q.admit_at(1, i as f64 * 1e-6));
        }
        // disabled limiter tracks no state
        assert_eq!(q.tracked(), 0);
        // negative / non-finite rates are treated as disabled
        assert!(!FairQueue::new(-5.0).enabled());
        assert!(!FairQueue::new(f64::NAN).enabled());
        assert!(!FairQueue::new(f64::INFINITY).enabled());
    }

    #[test]
    fn burst_then_steady_rate() {
        // 10 req/s, burst 20: a hot key gets the burst, then one token
        // every 100 ms.
        let q = FairQueue::new(10.0);
        let mut admitted = 0;
        for _ in 0..100 {
            if q.admit_at(7, 0.0) {
                admitted += 1;
            }
        }
        assert_eq!(admitted, 20, "burst cap should bound instantaneous admission");
        // refill: 0.05 s → 0.5 token, still refused
        assert!(!q.admit_at(7, 0.05));
        // 0.1 s total → 1 token
        assert!(q.admit_at(7, 0.1));
        assert!(!q.admit_at(7, 0.1));
    }

    #[test]
    fn bucket_saturates_at_burst() {
        let q = FairQueue::new(10.0);
        // drain the burst
        let mut n = 0;
        while q.admit_at(1, 0.0) {
            n += 1;
        }
        assert_eq!(n, 20);
        // a very long idle period refills to the cap, not beyond
        let mut refilled = 0;
        while q.admit_at(1, 1e6) {
            refilled += 1;
        }
        assert_eq!(refilled, 20, "idle refill must saturate at burst");
    }

    #[test]
    fn keys_are_independent() {
        let q = FairQueue::new(1.0);
        // key 1 exhausts its bucket; key 2 is untouched
        while q.admit_at(1, 0.0) {}
        assert!(q.admit_at(2, 0.0));
        assert_eq!(q.tracked(), 2);
        q.forget(1);
        assert_eq!(q.tracked(), 1);
    }

    #[test]
    fn clock_going_backwards_is_safe() {
        let q = FairQueue::new(10.0);
        assert!(q.admit_at(1, 5.0));
        // out-of-order timestamp must not mint or destroy tokens
        assert!(q.admit_at(1, 4.0));
        let mut n = 2;
        while q.admit_at(1, 5.0) {
            n += 1;
        }
        assert!(n <= 21, "backwards clock minted tokens: {n}");
    }

    #[test]
    fn weights_scale_burst_and_refill() {
        // base 10 req/s: a 2.0-weight key gets burst 40 and 20 tokens/s,
        // a 0.5-weight key gets burst 10 and 5 tokens/s
        let q = FairQueue::new(10.0);
        q.set_weight_at(1, 2.0, 0.0);
        q.set_weight_at(2, 0.5, 0.0);
        let drain = |key| {
            let mut n = 0;
            while q.admit_at(key, 0.0) {
                n += 1;
            }
            n
        };
        assert_eq!(drain(1), 40, "heavy class bursts 2x the base 20");
        assert_eq!(drain(2), 10, "light class bursts half the base 20");
        // one second of refill at the weighted rates
        let refill = |key| {
            let mut n = 0;
            while q.admit_at(key, 1.0) {
                n += 1;
            }
            n
        };
        assert_eq!(refill(1), 20);
        assert_eq!(refill(2), 5);
    }

    #[test]
    fn default_weight_matches_unweighted_behavior() {
        let q = FairQueue::new(10.0);
        q.set_weight_at(1, 1.0, 0.0);
        let mut weighted = 0;
        while q.admit_at(1, 0.0) {
            weighted += 1;
        }
        let mut plain = 0;
        while q.admit_at(2, 0.0) {
            plain += 1;
        }
        assert_eq!(weighted, plain, "weight 1.0 must change nothing");
    }

    #[test]
    fn hostile_weights_are_clamped() {
        let q = FairQueue::new(10.0);
        // absurd, zero, and non-finite weights cannot buy unbounded rate
        q.set_weight_at(1, 1e18, 0.0);
        let mut n = 0;
        while q.admit_at(1, 0.0) {
            n += 1;
        }
        assert_eq!(n, 2000, "clamped at rate 10 x MAX_WEIGHT 100 x BURST_SECS 2");
        for (key, w) in [(2, 0.0), (3, -4.0), (4, f64::NAN), (5, f64::INFINITY)] {
            q.set_weight_at(key, w, 0.0);
            let mut n = 0;
            while q.admit_at(key, 0.0) {
                n += 1;
            }
            assert_eq!(n, 20, "weight {w} must fall back to 1.0");
        }
        // a disabled limiter ignores weights entirely
        let off = FairQueue::new(0.0);
        off.set_weight_at(9, 3.0, 0.0);
        assert_eq!(off.tracked(), 0);
    }

    #[test]
    fn weight_drop_shrinks_an_over_cap_balance() {
        let q = FairQueue::new(10.0);
        q.set_weight_at(1, 2.0, 0.0); // burst 40, full
        q.set_weight_at(1, 0.5, 0.0); // cap now 10: balance must shrink
        let mut n = 0;
        while q.admit_at(1, 0.0) {
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn steady_state_matches_rate() {
        // admit attempts at 100/s against a 10/s bucket for 10 s of
        // simulated time → ~burst + 10*10 admissions.
        let q = FairQueue::new(10.0);
        let mut admitted = 0;
        for i in 0..1000 {
            if q.admit_at(3, i as f64 * 0.01) {
                admitted += 1;
            }
        }
        let expected = 20 + 100; // burst + rate * 10 s
        assert!(
            (admitted as i64 - expected as i64).abs() <= 2,
            "admitted={admitted} expected≈{expected}"
        );
    }
}
