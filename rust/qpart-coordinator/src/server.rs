//! TCP front-end: JSON-lines (+ negotiated binary frames) over TCP, a
//! bounded job queue, and a configurable **executor pool** of inference
//! workers fed by the batch-aware serving dataplane ([`crate::sched`]).
//!
//! Topology: N connection threads (one per accepted socket) parse frames
//! and submit [`Job`]s into a **bounded** channel — the admission-control
//! point: when the queue is full the request is shed immediately with an
//! `overloaded` error instead of growing latency unboundedly. `workers`
//! inference threads each own a full [`Service`] (Algorithm 1 tables +
//! PJRT executor — PJRT clients are single-device and not `Send`, so
//! per-worker ownership is the honest parallelism model) and **drain the
//! queue in batches** ([`crate::sched::drain_batch`]): same-(model,
//! accuracy level, partition) `infer` requests in a batch are planned and
//! encoded once, and the shared [`qpart_proto::EncodedSegmentBody`] fans
//! out to every waiting connection. One `Arc<Bundle>` backs the whole
//! pool (a single resident copy of the weights), one
//! [`EncodedReplyCache`] keeps encoded replies across batches, and a GC
//! thread expires sessions whose device never uploaded. Sessions live in
//! one sharded [`SharedSessionTable`] so the two protocol phases may be
//! handled by different workers; per-worker metrics are aggregated by a
//! [`MetricsHub`] into one logical [`MetricsSnapshot`].
//!
//! `workers` mirrors the simulator's `FleetConfig::server_slots` knob
//! (qpart-sim), so modeled and live serving share one parallelism model.

use crate::decision::DecisionCache;
use crate::metrics::{Metrics, MetricsHub, MetricsSnapshot};
use crate::sched::{drain_batch, BatchPolicy, DrainOutcome, EncodedReplyCache, Job, WireReply};
use crate::service::{Service, ServiceOptions};
use crate::session::SharedSessionTable;
use qpart_proto::frame::{read_any_frame, write_binary_frame, write_frame, Frame, FrameError};
use qpart_proto::messages::{ErrorReply, HelloReply, Request, Response};
use qpart_runtime::{Bundle, CompileCache};
use std::io::BufReader;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Server configuration.
///
/// Knobs and what they control:
///
/// * `listen` — TCP listen address; port `0` binds an ephemeral port
///   (the bound address is reported in [`ServerHandle::addr`]).
/// * `workers` — size of the executor pool: how many inference threads
///   (each owning its own PJRT executor + Algorithm 1 tables) drain the
///   job queue concurrently. `1` reproduces the classic single-inference-
///   thread coordinator; the default (`4`) mirrors the simulator's
///   `FleetConfig::server_slots` default so modeled and live serving agree.
///   Caution for `real-xla` builds: the pool shares compiled executables
///   through the compile cache; if the swapped-in bindings' handles are
///   not thread-safe for concurrent execution, run `workers = 1` (see the
///   README's "Real XLA" notes — the offline stub and PJRT CPU are safe).
/// * `queue_capacity` — **admission control**: the bounded depth of the
///   shared job queue. When all workers are busy and the queue is full,
///   new requests are shed immediately with an `overloaded` error rather
///   than queuing unboundedly (tail latency stays bounded under overload;
///   sheds are counted in `shed_total`).
/// * `session_capacity` — total capacity of the sharded session table for
///   the two-phase protocol. Oldest sessions are evicted first when a
///   shard fills (devices that never upload their activation must not
///   leak memory).
/// * `session_ttl` — age bound on open sessions: a GC thread sweeps
///   sessions older than this (counted in `sessions_expired`). Zero
///   disables the sweep (capacity eviction still applies).
/// * `batch_window` — the coalescing window: after a worker dequeues its
///   first job it waits up to this long for more, so concurrent
///   same-pattern requests share one encode. Zero (the default) still
///   coalesces whatever is already queued, adding no latency.
/// * `batch_max` — cap on jobs per drained batch.
/// * `cache_bytes` — byte budget of the encoded-reply cache (LRU beyond
///   it). The most recent entry always stays resident.
/// * `binary_frames` — allow connections to negotiate length-prefixed
///   binary frames via `hello` (JSON-lines stays the default and the
///   fallback for peers that never negotiate). The grant is symmetric:
///   segment replies go out as binary frames and activation uploads may
///   come in as binary request frames.
/// * `warm_cache` — pre-warm the shared caches at startup: one worker
///   encodes the most-likely `(model, level, partition)` reply keys
///   (Algorithm 1 enumerates them; Algorithm 2 under the paper-default
///   profile picks per level) and pre-builds their phase-2 plans, so the
///   first requests hit warm caches (`warmed_total` in stats).
/// * `host_fallback` — run phase 2 on the pure-Rust reference kernels
///   (linear architectures only). For tests and `bench-serve`; a PJRT
///   deployment leaves this off.
/// * `artifacts_dir` — artifact bundle directory (`make artifacts`);
///   loaded **once** and shared across the pool via `Arc`.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7878` (port 0 = ephemeral).
    pub listen: String,
    /// Executor-pool size (inference worker threads, each owning a PJRT
    /// executor). Values < 1 are treated as 1.
    pub workers: usize,
    /// Bounded job-queue depth (admission control).
    pub queue_capacity: usize,
    /// Session-table capacity (total across shards).
    pub session_capacity: usize,
    /// Session age bound for the GC sweep (zero = no TTL sweep).
    pub session_ttl: Duration,
    /// Coalescing window per drained batch (zero = opportunistic only).
    pub batch_window: Duration,
    /// Max jobs per drained batch (values < 1 behave as 1).
    pub batch_max: usize,
    /// Encoded-reply cache byte budget.
    pub cache_bytes: usize,
    /// Allow binary-frame negotiation (symmetric: segment replies
    /// downlink AND activation uploads uplink).
    pub binary_frames: bool,
    /// Pre-warm the encoded-reply and compile caches at startup: one
    /// worker encodes the most-likely reply keys and pre-builds their
    /// phase-2 plans before the server accepts traffic.
    pub warm_cache: bool,
    /// Execute phase 2 with the pure-Rust host reference kernels instead
    /// of PJRT (tests / bench-serve; linear architectures only).
    pub host_fallback: bool,
    /// Artifact bundle directory.
    pub artifacts_dir: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            // mirrors FleetConfig::default().server_slots (qpart-sim)
            workers: 4,
            // mirrors the config system's serving.queue_capacity default
            queue_capacity: 1024,
            session_capacity: 4096,
            session_ttl: Duration::from_secs(600),
            batch_window: Duration::ZERO,
            batch_max: 32,
            cache_bytes: 64 << 20,
            binary_frames: true,
            warm_cache: false,
            host_fallback: false,
            artifacts_dir: "artifacts".into(),
        }
    }
}

/// Handle to a running server (for tests/examples).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    /// Aggregated + per-worker metrics.
    pub hub: Arc<MetricsHub>,
    /// The shared session table (observability in tests/examples).
    pub sessions: Arc<SharedSessionTable>,
    /// The shared encoded-reply cache (observability in tests/examples).
    pub cache: Arc<EncodedReplyCache>,
    /// The pool-wide compile cache (observability in tests/examples).
    pub compile_cache: Arc<CompileCache>,
    /// The server-wide Algorithm-2 decision cache (observability in
    /// tests/examples).
    pub decision_cache: Arc<DecisionCache>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    gc_thread: Option<JoinHandle<()>>,
    worker_threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Signal shutdown and join the threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor so it re-checks the stop flag
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.gc_thread.take() {
            let _ = t.join();
        }
        for t in self.worker_threads.drain(..) {
            let _ = t.join();
        }
    }

    /// One aggregated snapshot across the front-end and all workers.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.hub.snapshot()
    }

    /// Per-worker snapshots (diagnostics / load-balance checks).
    pub fn worker_snapshots(&self) -> Vec<MetricsSnapshot> {
        self.hub.worker_snapshots()
    }
}

/// Start the server; returns once the listener is bound, the bundle is
/// loaded (once, shared), and **every** worker's service (Algorithm 1
/// tables + PJRT) is initialized.
pub fn serve(cfg: ServerConfig) -> Result<ServerHandle, String> {
    let listener = TcpListener::bind(&cfg.listen).map_err(|e| format!("bind {}: {e}", cfg.listen))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    let workers = cfg.workers.max(1);
    let hub = Arc::new(MetricsHub::new());
    let sessions = Arc::new(SharedSessionTable::new(cfg.session_capacity, workers));
    let cache = Arc::new(EncodedReplyCache::new(cfg.cache_bytes));
    // one compile cache for the whole pool: executables / prepared
    // segments / phase-2 plans build once per server, not once per worker
    let compile_cache = Arc::new(CompileCache::new());
    // one Algorithm-2 decision cache for the whole pool: repeat
    // (model, level, profile) requests skip planning on every worker
    let decision_cache = Arc::new(DecisionCache::new());
    let stop = Arc::new(AtomicBool::new(false));

    // one resident bundle for the whole pool (weights are immutable)
    let bundle =
        Arc::new(Bundle::load(&cfg.artifacts_dir).map_err(|e| format!("bundle: {e}"))?);

    let (job_tx, job_rx): (SyncSender<Job>, Receiver<Job>) = sync_channel(cfg.queue_capacity);
    // Work-stealing hand-off: workers take turns locking the receiver to
    // drain the next *batch* (everything queued, plus up to
    // `batch_window` of stragglers in short interleavable lock slices —
    // see `drain_batch`). Handling happens outside the lock, so up to
    // `workers` batches are in flight concurrently.
    let job_rx = Arc::new(Mutex::new(job_rx));
    let policy = BatchPolicy { window: cfg.batch_window, max_batch: cfg.batch_max };

    // Inference workers: each owns a (non-Send) service over the shared
    // bundle. Algorithm 1 initialization happens inside; readiness is
    // reported via a channel so `serve` fails fast if any worker cannot
    // start.
    let (ready_tx, ready_rx) = sync_channel::<Result<(), String>>(workers);
    let mut worker_threads = Vec::with_capacity(workers);
    for w in 0..workers {
        let worker_hub = Arc::clone(&hub);
        let worker_sessions = Arc::clone(&sessions);
        let worker_cache = Arc::clone(&cache);
        let worker_compile = Arc::clone(&compile_cache);
        let worker_decisions = Arc::clone(&decision_cache);
        let worker_bundle = Arc::clone(&bundle);
        let worker_stop = Arc::clone(&stop);
        let worker_rx = Arc::clone(&job_rx);
        let ready_tx = ready_tx.clone();
        // one worker warms the shared caches; its peers see the results
        let warm = cfg.warm_cache && w == 0;
        let host_fallback = cfg.host_fallback;
        let t = std::thread::Builder::new()
            .name(format!("qpart-worker-{w}"))
            .spawn(move || {
                let opts = ServiceOptions {
                    compile_cache: worker_compile,
                    decision_cache: worker_decisions,
                    host_fallback,
                };
                let service = Service::with_options(
                    worker_bundle,
                    worker_hub,
                    worker_sessions,
                    worker_cache,
                    opts,
                )
                .map_err(|e| e.to_string());
                let mut service = match service {
                    Ok(mut s) => {
                        if warm {
                            // warm before reporting ready: serve() returns
                            // with the caches populated, deterministically
                            s.warm_cache();
                        }
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("worker {w}: {e}")));
                        return;
                    }
                };
                // Drop our readiness sender now: if another worker panics
                // during init (sending nothing), serve()'s readiness loop
                // must observe disconnection instead of hanging on workers
                // that hold their clones for the whole job loop.
                drop(ready_tx);
                while !worker_stop.load(Ordering::SeqCst) {
                    // drain_batch locks the receiver only per dequeue, so
                    // a long coalescing window never serializes the pool
                    match drain_batch(&worker_rx, &policy, Duration::from_millis(100)) {
                        DrainOutcome::Batch(batch) => service.handle_batch(batch),
                        DrainOutcome::TimedOut => continue,
                        DrainOutcome::Disconnected => break,
                    }
                }
            })
            .map_err(|e| e.to_string())?;
        worker_threads.push(t);
    }
    drop(ready_tx);

    for _ in 0..workers {
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(format!("service init failed: {e}")),
            Err(_) => return Err("a worker thread died during init".into()),
        }
    }

    // Session GC: expire sessions whose device never uploaded.
    let gc_thread = if cfg.session_ttl > Duration::ZERO {
        let gc_sessions = Arc::clone(&sessions);
        let gc_stop = Arc::clone(&stop);
        let ttl = cfg.session_ttl;
        let interval = (ttl / 4).clamp(Duration::from_millis(10), Duration::from_secs(1));
        Some(
            std::thread::Builder::new()
                .name("qpart-session-gc".into())
                .spawn(move || {
                    // sleep in short ticks so shutdown joins promptly even
                    // with a long sweep interval
                    let tick = Duration::from_millis(10).min(interval);
                    let mut slept = Duration::ZERO;
                    while !gc_stop.load(Ordering::SeqCst) {
                        std::thread::sleep(tick);
                        slept += tick;
                        if slept >= interval {
                            slept = Duration::ZERO;
                            gc_sessions.sweep_expired(ttl);
                        }
                    }
                })
                .map_err(|e| e.to_string())?,
        )
    } else {
        None
    };

    // Acceptor thread: one connection thread per client.
    let accept_stop = Arc::clone(&stop);
    let accept_metrics = hub.front();
    let binary_allowed = cfg.binary_frames;
    let accept_thread = std::thread::Builder::new()
        .name("qpart-accept".into())
        .spawn(move || {
            for stream in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                let stream = match stream {
                    Ok(s) => s,
                    Err(_) => continue,
                };
                // request/response protocol: Nagle + delayed-ACK adds
                // ~40-200 ms per round trip without this
                let _ = stream.set_nodelay(true);
                let job_tx = job_tx.clone();
                let metrics = Arc::clone(&accept_metrics);
                let conn_stop = Arc::clone(&accept_stop);
                let _ = std::thread::Builder::new().name("qpart-conn".into()).spawn(move || {
                    connection_loop(stream, job_tx, metrics, conn_stop, binary_allowed)
                });
            }
        })
        .map_err(|e| e.to_string())?;

    Ok(ServerHandle {
        addr,
        hub,
        sessions,
        cache,
        compile_cache,
        decision_cache,
        stop,
        accept_thread: Some(accept_thread),
        gc_thread,
        worker_threads,
    })
}

/// Serialize one reply in the connection's negotiated framing. Segment
/// replies are a splice of the shared encoded body — the payload was
/// serialized once for the whole batch group / cache lifetime.
fn write_reply(
    writer: &mut TcpStream,
    reply: WireReply,
    binary: bool,
) -> Result<(), FrameError> {
    match reply {
        WireReply::Msg(resp) => write_frame(writer, &resp.to_line()),
        WireReply::Segment(s) => {
            if binary {
                write_binary_frame(
                    writer,
                    &s.body.binary_header(s.session, s.objective),
                    s.body.blob(),
                )
            } else {
                write_frame(writer, &s.body.json_line(s.session, s.objective))
            }
        }
    }
}

fn connection_loop(
    stream: TcpStream,
    job_tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    binary_allowed: bool,
) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    // negotiated per session via `hello`; symmetric: grants binary
    // segment replies downlink AND binary activation uploads uplink
    let mut binary = false;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let frame = match read_any_frame(&mut reader) {
            Ok(f) => f,
            Err(FrameError::Closed) => break,
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_frame".into(),
                    message: e.to_string(),
                });
                let _ = write_frame(&mut writer, &resp.to_line());
                break;
            }
        };
        // a binary request frame is only valid after a granted hello —
        // the server must not silently accept what it did not grant
        if matches!(frame, Frame::Binary(_)) && !binary {
            Metrics::inc(&metrics.errors_total);
            let resp = Response::Error(ErrorReply {
                code: "bad_frame".into(),
                message: "binary frame before negotiation (send hello first)".into(),
            });
            if write_frame(&mut writer, &resp.to_line()).is_err() {
                break;
            }
            continue;
        }
        let req = match Request::from_frame(&frame) {
            Ok(r) => r,
            Err(e) => {
                Metrics::inc(&metrics.errors_total);
                let resp = Response::Error(ErrorReply {
                    code: "bad_request".into(),
                    message: e.to_string(),
                });
                if write_frame(&mut writer, &resp.to_line()).is_err() {
                    break;
                }
                continue;
            }
        };
        // framing negotiation is connection state — answered here, never
        // queued (the hello reply itself is always a JSON frame); counted
        // in the front-end's metrics so protocol traffic still adds up
        if let Request::Hello(h) = &req {
            Metrics::inc(&metrics.requests_total);
            binary = h.binary_frames && binary_allowed;
            let resp = Response::Hello(HelloReply { binary_frames: binary });
            if write_frame(&mut writer, &resp.to_line()).is_err() {
                break;
            }
            continue;
        }
        let (reply_tx, reply_rx) = sync_channel::<WireReply>(1);
        let reply = match job_tx.try_send(Job::new(req, reply_tx)) {
            Ok(()) => match reply_rx.recv() {
                Ok(r) => r,
                Err(_) => WireReply::Msg(Response::Error(ErrorReply {
                    code: "internal".into(),
                    message: "inference worker gone".into(),
                })),
            },
            Err(TrySendError::Full(_)) => {
                Metrics::inc(&metrics.shed_total);
                WireReply::Msg(Response::Error(ErrorReply {
                    code: "overloaded".into(),
                    message: "admission control: job queue full".into(),
                }))
            }
            Err(TrySendError::Disconnected(_)) => WireReply::Msg(Response::Error(ErrorReply {
                code: "shutdown".into(),
                message: "server stopping".into(),
            })),
        };
        if write_reply(&mut writer, reply, binary).is_err() {
            break;
        }
    }
}
