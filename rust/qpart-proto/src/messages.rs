//! Protocol messages (manual JSON mapping, tagged by a `"type"` field).
//!
//! Serving flow (two-phase, mirroring Fig. 1/2 of the paper):
//!
//! 1. device → `infer` (model, accuracy budget, channel + compute profile)
//! 2. server → `segment` (the quantized, bit-packed model segment + the
//!    chosen pattern) — the downlink the paper's Eq. 14 charges for
//! 3. device runs layers `1..=p` locally, → `activation` (quantized,
//!    bit-packed boundary activation) — the uplink
//! 4. server finishes layers `p+1..=L`, → `result` (prediction + logits)
//!
//! `simulate` collapses 1–4 into one message for load generation: the
//! server plays both roles and reports the cost breakdown.

use crate::base64;
use qpart_core::json::{parse, Value};
use qpart_core::{Error, Result};

/// Requests a client can send.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Ping,
    ListModels,
    Stats,
    Infer(InferRequest),
    Activation(ActivationUpload),
    Simulate(SimulateRequest),
}

/// Paper Algorithm 2's Require-tuple.
#[derive(Debug, Clone, PartialEq)]
pub struct InferRequest {
    pub model: String,
    /// Max acceptable accuracy degradation `a` (fraction).
    pub accuracy_budget: f64,
    /// Reported channel capacity `r` (bit/s).
    pub channel_capacity_bps: f64,
    /// Transmit power `π` (W).
    pub tx_power_w: f64,
    /// `f_local` (Hz).
    pub clock_hz: f64,
    /// `γ_local` (cycles/MAC).
    pub cycles_per_mac: f64,
    /// `κ` energy-efficiency parameter.
    pub kappa: f64,
    /// Device memory capacity (bits).
    pub memory_bits: u64,
    /// Objective weights ω/τ/η (None → server defaults).
    pub weights: Option<(f64, f64, f64)>,
}

/// Quantized boundary activation upload.
#[derive(Debug, Clone, PartialEq)]
pub struct ActivationUpload {
    pub session: u64,
    pub bits: u8,
    pub qmin: f32,
    pub step: f32,
    pub dims: Vec<usize>,
    /// Bit-packed codes.
    pub packed: Vec<u8>,
}

/// One-shot request: the server simulates the device side too.
#[derive(Debug, Clone, PartialEq)]
pub struct SimulateRequest {
    pub req: InferRequest,
    /// Raw f32 input (little-endian bytes).
    pub input: Vec<f32>,
    pub input_dims: Vec<usize>,
}

/// Responses.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Pong,
    Models(Vec<ModelInfo>),
    Stats(Value),
    Segment(InferReply),
    Result(ResultReply),
    Error(ErrorReply),
}

#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub name: String,
    pub arch: String,
    pub dataset: String,
    pub layers: usize,
    pub params: u64,
    pub test_accuracy: f64,
}

/// The chosen pattern, reported back to the device.
#[derive(Debug, Clone, PartialEq)]
pub struct PatternInfo {
    pub partition: usize,
    pub weight_bits: Vec<u8>,
    pub activation_bits: u8,
    pub accuracy_level: f64,
    pub predicted_degradation: f64,
    pub objective: f64,
}

/// One quantized layer on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerBlob {
    pub layer: usize,
    pub bits: u8,
    pub w_dims: Vec<usize>,
    pub w_qmin: f32,
    pub w_step: f32,
    pub w_packed: Vec<u8>,
    pub b_qmin: f32,
    pub b_step: f32,
    pub b_len: usize,
    pub b_packed: Vec<u8>,
}

/// The shipped model segment.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SegmentBlob {
    pub layers: Vec<LayerBlob>,
}

/// Phase-1 reply: session + pattern + segment.
#[derive(Debug, Clone, PartialEq)]
pub struct InferReply {
    pub session: u64,
    pub model: String,
    pub pattern: PatternInfo,
    pub segment: SegmentBlob,
}

/// Phase-2 (or simulate) reply.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultReply {
    pub session: u64,
    pub prediction: i32,
    pub logits: Vec<f64>,
    /// Cost breakdown (simulate only): the Eq. 17 terms.
    pub costs: Option<Value>,
    /// Server-side wall-clock microseconds spent on this request.
    pub server_us: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReply {
    pub code: String,
    pub message: String,
}

// ---------------------------------------------------------------------------
// f32 <-> bytes helpers
// ---------------------------------------------------------------------------

/// Encode f32s as base64(LE bytes).
pub fn f32s_to_b64(xs: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    base64::encode(&bytes)
}

/// Decode base64(LE bytes) to f32s.
pub fn b64_to_f32s(s: &str) -> Result<Vec<f32>> {
    let bytes = base64::decode(s).map_err(|e| Error::InvalidArg(format!("base64: {e}")))?;
    if bytes.len() % 4 != 0 {
        return Err(Error::InvalidArg("f32 payload not a multiple of 4 bytes".into()));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn usize_arr(v: &Value, key: &str) -> Result<Vec<usize>> {
    v.req_arr(key)?
        .iter()
        .map(|x| {
            x.as_i64()
                .and_then(|i| usize::try_from(i).ok())
                .ok_or_else(|| Error::schema(key, "expected index array"))
        })
        .collect()
}

fn dims_json(dims: &[usize]) -> Value {
    Value::Arr(dims.iter().map(|&d| d.into()).collect())
}

fn bytes_field(v: &Value, key: &str) -> Result<Vec<u8>> {
    base64::decode(v.req_str(key)?).map_err(|e| Error::schema(key, format!("base64: {e}")))
}

// ---------------------------------------------------------------------------
// Request (de)serialization
// ---------------------------------------------------------------------------

impl Request {
    pub fn to_json(&self) -> Value {
        match self {
            Request::Ping => Value::obj([("type", "ping".into())]),
            Request::ListModels => Value::obj([("type", "list_models".into())]),
            Request::Stats => Value::obj([("type", "stats".into())]),
            Request::Infer(r) => {
                let mut v = r.to_json();
                v.set("type", "infer".into());
                v
            }
            Request::Activation(a) => Value::obj([
                ("type", "activation".into()),
                ("session", a.session.into()),
                ("bits", (a.bits as u64).into()),
                ("qmin", (a.qmin as f64).into()),
                ("step", (a.step as f64).into()),
                ("dims", dims_json(&a.dims)),
                ("packed", base64::encode(&a.packed).into()),
            ]),
            Request::Simulate(s) => {
                let mut v = s.req.to_json();
                v.set("type", "simulate".into());
                v.set("input", f32s_to_b64(&s.input).into());
                v.set("input_dims", dims_json(&s.input_dims));
                v
            }
        }
    }

    pub fn from_json(v: &Value) -> Result<Request> {
        match v.req_str("type")? {
            "ping" => Ok(Request::Ping),
            "list_models" => Ok(Request::ListModels),
            "stats" => Ok(Request::Stats),
            "infer" => Ok(Request::Infer(InferRequest::from_json(v)?)),
            "activation" => Ok(Request::Activation(ActivationUpload {
                session: v.req_u64("session")?,
                bits: v.req_u64("bits")? as u8,
                qmin: v.req_f64("qmin")? as f32,
                step: v.req_f64("step")? as f32,
                dims: usize_arr(v, "dims")?,
                packed: bytes_field(v, "packed")?,
            })),
            "simulate" => Ok(Request::Simulate(SimulateRequest {
                req: InferRequest::from_json(v)?,
                input: b64_to_f32s(v.req_str("input")?)?,
                input_dims: usize_arr(v, "input_dims")?,
            })),
            other => Err(Error::schema("type", format!("unknown request '{other}'"))),
        }
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_line(line: &str) -> Result<Request> {
        Request::from_json(&parse(line)?)
    }
}

impl InferRequest {
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj([
            ("model", self.model.as_str().into()),
            ("accuracy_budget", self.accuracy_budget.into()),
            ("channel_capacity_bps", self.channel_capacity_bps.into()),
            ("tx_power_w", self.tx_power_w.into()),
            ("clock_hz", self.clock_hz.into()),
            ("cycles_per_mac", self.cycles_per_mac.into()),
            ("kappa", self.kappa.into()),
            ("memory_bits", self.memory_bits.into()),
        ]);
        if let Some((o, t, e)) = self.weights {
            v.set("weights", Value::num_arr(&[o, t, e]));
        }
        v
    }

    pub fn from_json(v: &Value) -> Result<InferRequest> {
        let weights = match v.get("weights") {
            Some(w) => {
                let arr = w
                    .as_arr()
                    .ok_or_else(|| Error::schema("weights", "expected [omega, tau, eta]"))?;
                if arr.len() != 3 {
                    return Err(Error::schema("weights", "expected 3 numbers"));
                }
                Some((
                    arr[0].as_f64().ok_or_else(|| Error::schema("weights", "numbers"))?,
                    arr[1].as_f64().ok_or_else(|| Error::schema("weights", "numbers"))?,
                    arr[2].as_f64().ok_or_else(|| Error::schema("weights", "numbers"))?,
                ))
            }
            None => None,
        };
        Ok(InferRequest {
            model: v.req_str("model")?.to_string(),
            accuracy_budget: v.req_f64("accuracy_budget")?,
            channel_capacity_bps: v.req_f64("channel_capacity_bps")?,
            tx_power_w: v.opt_f64("tx_power_w", 1.0),
            clock_hz: v.opt_f64("clock_hz", 200e6),
            cycles_per_mac: v.opt_f64("cycles_per_mac", 5.0),
            kappa: v.opt_f64("kappa", 3e-27),
            memory_bits: v.opt_f64("memory_bits", 2.147_483_648e9) as u64,
            weights,
        })
    }
}

// ---------------------------------------------------------------------------
// Response (de)serialization
// ---------------------------------------------------------------------------

impl Response {
    pub fn to_json(&self) -> Value {
        match self {
            Response::Pong => Value::obj([("type", "pong".into())]),
            Response::Models(models) => Value::obj([
                ("type", "models".into()),
                (
                    "models",
                    Value::Arr(
                        models
                            .iter()
                            .map(|m| {
                                Value::obj([
                                    ("name", m.name.as_str().into()),
                                    ("arch", m.arch.as_str().into()),
                                    ("dataset", m.dataset.as_str().into()),
                                    ("layers", m.layers.into()),
                                    ("params", m.params.into()),
                                    ("test_accuracy", m.test_accuracy.into()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Response::Stats(v) => {
                let mut o = Value::obj([("type", "stats".into())]);
                o.set("stats", v.clone());
                o
            }
            Response::Segment(r) => {
                let layers: Vec<Value> = r
                    .segment
                    .layers
                    .iter()
                    .map(|l| {
                        Value::obj([
                            ("layer", l.layer.into()),
                            ("bits", (l.bits as u64).into()),
                            ("w_dims", dims_json(&l.w_dims)),
                            ("w_qmin", (l.w_qmin as f64).into()),
                            ("w_step", (l.w_step as f64).into()),
                            ("w_packed", base64::encode(&l.w_packed).into()),
                            ("b_qmin", (l.b_qmin as f64).into()),
                            ("b_step", (l.b_step as f64).into()),
                            ("b_len", l.b_len.into()),
                            ("b_packed", base64::encode(&l.b_packed).into()),
                        ])
                    })
                    .collect();
                Value::obj([
                    ("type", "segment".into()),
                    ("session", r.session.into()),
                    ("model", r.model.as_str().into()),
                    ("pattern", r.pattern.to_json()),
                    ("layers", Value::Arr(layers)),
                ])
            }
            Response::Result(r) => {
                let mut v = Value::obj([
                    ("type", "result".into()),
                    ("session", r.session.into()),
                    ("prediction", (r.prediction as i64).into()),
                    ("logits", Value::num_arr(&r.logits)),
                    ("server_us", r.server_us.into()),
                ]);
                if let Some(c) = &r.costs {
                    v.set("costs", c.clone());
                }
                v
            }
            Response::Error(e) => Value::obj([
                ("type", "error".into()),
                ("code", e.code.as_str().into()),
                ("message", e.message.as_str().into()),
            ]),
        }
    }

    pub fn from_json(v: &Value) -> Result<Response> {
        match v.req_str("type")? {
            "pong" => Ok(Response::Pong),
            "models" => {
                let mut models = Vec::new();
                for m in v.req_arr("models")? {
                    models.push(ModelInfo {
                        name: m.req_str("name")?.to_string(),
                        arch: m.req_str("arch")?.to_string(),
                        dataset: m.req_str("dataset")?.to_string(),
                        layers: m.req_usize("layers")?,
                        params: m.req_u64("params")?,
                        test_accuracy: m.opt_f64("test_accuracy", f64::NAN),
                    });
                }
                Ok(Response::Models(models))
            }
            "stats" => Ok(Response::Stats(v.req("stats")?.clone())),
            "segment" => {
                let mut layers = Vec::new();
                for l in v.req_arr("layers")? {
                    layers.push(LayerBlob {
                        layer: l.req_usize("layer")?,
                        bits: l.req_u64("bits")? as u8,
                        w_dims: usize_arr(l, "w_dims")?,
                        w_qmin: l.req_f64("w_qmin")? as f32,
                        w_step: l.req_f64("w_step")? as f32,
                        w_packed: bytes_field(l, "w_packed")?,
                        b_qmin: l.req_f64("b_qmin")? as f32,
                        b_step: l.req_f64("b_step")? as f32,
                        b_len: l.req_usize("b_len")?,
                        b_packed: bytes_field(l, "b_packed")?,
                    });
                }
                Ok(Response::Segment(InferReply {
                    session: v.req_u64("session")?,
                    model: v.req_str("model")?.to_string(),
                    pattern: PatternInfo::from_json(v.req("pattern")?)?,
                    segment: SegmentBlob { layers },
                }))
            }
            "result" => Ok(Response::Result(ResultReply {
                session: v.req_u64("session")?,
                prediction: v.req_f64("prediction")? as i32,
                logits: v.req_f64_arr("logits")?,
                costs: v.get("costs").cloned(),
                server_us: v.opt_f64("server_us", 0.0) as u64,
            })),
            "error" => Ok(Response::Error(ErrorReply {
                code: v.req_str("code")?.to_string(),
                message: v.req_str("message")?.to_string(),
            })),
            other => Err(Error::schema("type", format!("unknown response '{other}'"))),
        }
    }

    pub fn to_line(&self) -> String {
        self.to_json().to_string_compact()
    }

    pub fn from_line(line: &str) -> Result<Response> {
        Response::from_json(&parse(line)?)
    }
}

impl PatternInfo {
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("partition", self.partition.into()),
            (
                "weight_bits",
                Value::Arr(self.weight_bits.iter().map(|&b| (b as u64).into()).collect()),
            ),
            ("activation_bits", (self.activation_bits as u64).into()),
            ("accuracy_level", self.accuracy_level.into()),
            ("predicted_degradation", self.predicted_degradation.into()),
            ("objective", self.objective.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<PatternInfo> {
        Ok(PatternInfo {
            partition: v.req_usize("partition")?,
            weight_bits: v
                .req_arr("weight_bits")?
                .iter()
                .map(|b| {
                    b.as_i64()
                        .and_then(|x| u8::try_from(x).ok())
                        .ok_or_else(|| Error::schema("weight_bits", "expected bytes"))
                })
                .collect::<Result<_>>()?,
            activation_bits: v.req_u64("activation_bits")? as u8,
            accuracy_level: v.req_f64("accuracy_level")?,
            predicted_degradation: v.opt_f64("predicted_degradation", 0.0),
            objective: v.opt_f64("objective", f64::NAN),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn infer_req() -> InferRequest {
        InferRequest {
            model: "mlp6".into(),
            accuracy_budget: 0.01,
            channel_capacity_bps: 200e6,
            tx_power_w: 1.0,
            clock_hz: 200e6,
            cycles_per_mac: 5.0,
            kappa: 3e-27,
            memory_bits: 1 << 31,
            weights: Some((1.0, 1.0, 1.0)),
        }
    }

    #[test]
    fn request_roundtrips() {
        for req in [
            Request::Ping,
            Request::ListModels,
            Request::Stats,
            Request::Infer(infer_req()),
            Request::Activation(ActivationUpload {
                session: 42,
                bits: 6,
                qmin: -1.5,
                step: 0.01,
                dims: vec![1, 128],
                packed: vec![1, 2, 3, 255],
            }),
            Request::Simulate(SimulateRequest {
                req: infer_req(),
                input: vec![0.5, -0.25, 1e-3],
                input_dims: vec![1, 3],
            }),
        ] {
            let line = req.to_line();
            assert!(!line.contains('\n'));
            let back = Request::from_line(&line).unwrap();
            assert_eq!(back, req, "line: {line}");
        }
    }

    #[test]
    fn response_roundtrips() {
        let seg = Response::Segment(InferReply {
            session: 7,
            model: "mlp6".into(),
            pattern: PatternInfo {
                partition: 3,
                weight_bits: vec![4, 5, 6],
                activation_bits: 7,
                accuracy_level: 0.01,
                predicted_degradation: 0.009,
                objective: 0.123,
            },
            segment: SegmentBlob {
                layers: vec![LayerBlob {
                    layer: 1,
                    bits: 4,
                    w_dims: vec![784, 512],
                    w_qmin: -0.3,
                    w_step: 0.004,
                    w_packed: vec![0xDE, 0xAD],
                    b_qmin: -0.1,
                    b_step: 0.002,
                    b_len: 512,
                    b_packed: vec![0xBE, 0xEF],
                }],
            },
        });
        for resp in [
            Response::Pong,
            seg,
            Response::Result(ResultReply {
                session: 7,
                prediction: 3,
                logits: vec![0.1, 0.9],
                costs: Some(Value::obj([("objective", 1.5.into())])),
                server_us: 1234,
            }),
            Response::Error(ErrorReply { code: "infeasible".into(), message: "x".into() }),
            Response::Models(vec![ModelInfo {
                name: "mlp6".into(),
                arch: "mlp6".into(),
                dataset: "digits".into(),
                layers: 6,
                params: 567434,
                test_accuracy: 0.97,
            }]),
        ] {
            let line = resp.to_line();
            let back = Response::from_line(&line).unwrap();
            assert_eq!(back, resp, "line: {line}");
        }
    }

    #[test]
    fn f32_b64_roundtrip() {
        let xs = vec![0.0f32, -1.5, 3.25e7, f32::MIN_POSITIVE];
        assert_eq!(b64_to_f32s(&f32s_to_b64(&xs)).unwrap(), xs);
        assert!(b64_to_f32s("AAA").is_err()); // 2 bytes
    }

    #[test]
    fn unknown_types_rejected() {
        assert!(Request::from_line(r#"{"type":"warp"}"#).is_err());
        assert!(Response::from_line(r#"{"type":"warp"}"#).is_err());
        assert!(Request::from_line("not json").is_err());
    }
}
