//! Cost model: device/server compute time and energy, server monetary cost,
//! the Eq. 17 objective and its Eq. 23 per-MAC/per-bit coefficients.
//!
//! * `T_local = O1·γ_local/f_local` (Eq. 5)
//! * `E_local = κ·f_local²·O1·γ_local` (Eq. 6)
//! * `T_server = O2·γ_server/f_server` (Eq. 7)
//! * `C = O2·γ_server·ζ/f_server` (Eq. 8)
//! * `J = ω(T_local+T_tran+T_server) + τ(E_local+E_tran) + η·C` (Eq. 17)
//! * `ξ, δ, ε` coefficients (Eq. 24–26) so that
//!   `J = ξ·O1 + δ·O2 + ε·Z` — linear in MACs and payload bits.

use crate::channel::Channel;
use crate::json::Value;
use crate::model::ModelSpec;
use crate::error::Result;

/// Edge-device execution profile (paper Table II symbols).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceProfile {
    /// Clock rate `f_local` in Hz.
    pub clock_hz: f64,
    /// Average clock cycles per MAC, `γ_local`.
    pub cycles_per_mac: f64,
    /// Energy-efficiency parameter `κ` (J/cycle/Hz² — energy per cycle is
    /// `κ·f²`).
    pub kappa: f64,
    /// Device memory capacity in bits (constraint on the shipped segment).
    pub memory_bits: u64,
}

impl DeviceProfile {
    /// Paper Table II mobile device: 200 MHz, γ=5, κ=3e-27.
    pub fn paper_default() -> DeviceProfile {
        DeviceProfile {
            clock_hz: 200e6,
            cycles_per_mac: 5.0,
            kappa: 3e-27,
            memory_bits: 256 * 1024 * 1024 * 8, // 256 MiB
        }
    }

    /// Local inference time for `macs` (Eq. 5).
    pub fn compute_time_s(&self, macs: u64) -> f64 {
        macs as f64 * self.cycles_per_mac / self.clock_hz
    }

    /// Local inference energy for `macs` (Eq. 6): `κ·f²·O·γ`.
    pub fn compute_energy_j(&self, macs: u64) -> f64 {
        self.kappa * self.clock_hz * self.clock_hz * macs as f64 * self.cycles_per_mac
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("clock_hz", self.clock_hz.into()),
            ("cycles_per_mac", self.cycles_per_mac.into()),
            ("kappa", self.kappa.into()),
            ("memory_bits", self.memory_bits.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<DeviceProfile> {
        let d = DeviceProfile::paper_default();
        Ok(DeviceProfile {
            clock_hz: v.opt_f64("clock_hz", d.clock_hz),
            cycles_per_mac: v.opt_f64("cycles_per_mac", d.cycles_per_mac),
            kappa: v.opt_f64("kappa", d.kappa),
            memory_bits: v.opt_f64("memory_bits", d.memory_bits as f64) as u64,
        })
    }
}

/// Server execution profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerProfile {
    /// Clock rate `f_server` in Hz.
    pub clock_hz: f64,
    /// Average clock cycles per MAC, `γ_server`.
    pub cycles_per_mac: f64,
    /// Price of server compute, `ζ` (cost units per second, Eq. 8).
    pub price_per_s: f64,
    /// Server energy-efficiency `η_m` (appears in Eq. 25; the Eq. 17
    /// objective excludes server energy — kept for the δ coefficient).
    pub eta_m: f64,
}

impl ServerProfile {
    /// Paper Table II server: 3 GHz, γ=1.25 ("5/4"), η_m=3.75e-27.
    pub fn paper_default() -> ServerProfile {
        ServerProfile { clock_hz: 3e9, cycles_per_mac: 1.25, price_per_s: 0.01, eta_m: 3.75e-27 }
    }

    /// Server inference time for `macs` (Eq. 7).
    pub fn compute_time_s(&self, macs: u64) -> f64 {
        macs as f64 * self.cycles_per_mac / self.clock_hz
    }

    /// Monetary cost of running `macs` (Eq. 8).
    pub fn compute_cost(&self, macs: u64) -> f64 {
        self.compute_time_s(macs) * self.price_per_s
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("clock_hz", self.clock_hz.into()),
            ("cycles_per_mac", self.cycles_per_mac.into()),
            ("price_per_s", self.price_per_s.into()),
            ("eta_m", self.eta_m.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<ServerProfile> {
        let d = ServerProfile::paper_default();
        Ok(ServerProfile {
            clock_hz: v.opt_f64("clock_hz", d.clock_hz),
            cycles_per_mac: v.opt_f64("cycles_per_mac", d.cycles_per_mac),
            price_per_s: v.opt_f64("price_per_s", d.price_per_s),
            eta_m: v.opt_f64("eta_m", d.eta_m),
        })
    }
}

/// Significance weights of Eq. 17 (`ω` time, `τ` energy, `η` cost).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TradeoffWeights {
    pub omega: f64,
    pub tau: f64,
    pub eta: f64,
}

impl TradeoffWeights {
    /// Paper Table II: ω = τ = 1 (η unspecified; 1 keeps cost visible).
    pub fn paper_default() -> TradeoffWeights {
        TradeoffWeights { omega: 1.0, tau: 1.0, eta: 1.0 }
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("omega", self.omega.into()),
            ("tau", self.tau.into()),
            ("eta", self.eta.into()),
        ])
    }

    pub fn from_json(v: &Value) -> Result<TradeoffWeights> {
        let d = TradeoffWeights::paper_default();
        Ok(TradeoffWeights {
            omega: v.opt_f64("omega", d.omega),
            tau: v.opt_f64("tau", d.tau),
            eta: v.opt_f64("eta", d.eta),
        })
    }
}

/// Full cost context for one request: device, server, channel, weights.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub device: DeviceProfile,
    pub server: ServerProfile,
    pub channel: Channel,
    pub weights: TradeoffWeights,
}

/// Per-component breakdown of one evaluation of Eq. 17.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostBreakdown {
    pub t_local_s: f64,
    pub t_server_s: f64,
    pub t_tran_s: f64,
    pub e_local_j: f64,
    pub e_tran_j: f64,
    pub server_cost: f64,
    /// The Eq. 17 objective value.
    pub objective: f64,
}

impl CostBreakdown {
    /// End-to-end latency (the time part of the objective).
    pub fn total_time_s(&self) -> f64 {
        self.t_local_s + self.t_server_s + self.t_tran_s
    }

    /// Device energy.
    pub fn total_energy_j(&self) -> f64 {
        self.e_local_j + self.e_tran_j
    }

    pub fn to_json(&self) -> Value {
        Value::obj([
            ("t_local_s", self.t_local_s.into()),
            ("t_server_s", self.t_server_s.into()),
            ("t_tran_s", self.t_tran_s.into()),
            ("e_local_j", self.e_local_j.into()),
            ("e_tran_j", self.e_tran_j.into()),
            ("server_cost", self.server_cost.into()),
            ("objective", self.objective.into()),
        ])
    }
}

impl CostModel {
    /// Paper Table II configuration end to end.
    pub fn paper_default() -> CostModel {
        CostModel {
            device: DeviceProfile::paper_default(),
            server: ServerProfile::paper_default(),
            channel: Channel::fixed(200e6, 1.0),
            weights: TradeoffWeights::paper_default(),
        }
    }

    /// Per-device-MAC coefficient ξ (Eq. 24):
    /// `ξ = ω·γ_l/f_l + τ·γ_l·κ·f_l²`.
    pub fn xi(&self) -> f64 {
        let d = &self.device;
        self.weights.omega * d.cycles_per_mac / d.clock_hz
            + self.weights.tau * d.cycles_per_mac * d.kappa * d.clock_hz * d.clock_hz
    }

    /// Per-server-MAC coefficient δ (Eq. 25):
    /// `δ = (ω + η·ζ)·γ_s/f_s` (server energy excluded from Eq. 17).
    pub fn delta(&self) -> f64 {
        let s = &self.server;
        (self.weights.omega + self.weights.eta * s.price_per_s) * s.cycles_per_mac / s.clock_hz
    }

    /// Per-payload-bit coefficient ε (Eq. 26): `ε = (ω + π·τ)/r`.
    pub fn epsilon(&self) -> f64 {
        (self.weights.omega + self.channel.tx_power_w * self.weights.tau)
            / self.channel.capacity_bps
    }

    /// Evaluate Eq. 17 for a partition `p` and payload of `payload_bits`.
    pub fn evaluate(&self, model: &ModelSpec, p: usize, payload_bits: u64) -> CostBreakdown {
        let o1 = model.device_macs(p);
        let o2 = model.server_macs(p);
        let t_local_s = self.device.compute_time_s(o1);
        let t_server_s = self.server.compute_time_s(o2);
        let t_tran_s = self.channel.tx_latency_s(payload_bits);
        let e_local_j = self.device.compute_energy_j(o1);
        let e_tran_j = self.channel.tx_energy_j(payload_bits);
        let server_cost = self.server.compute_cost(o2);
        let objective = self.weights.omega * (t_local_s + t_server_s + t_tran_s)
            + self.weights.tau * (e_local_j + e_tran_j)
            + self.weights.eta * server_cost;
        CostBreakdown { t_local_s, t_server_s, t_tran_s, e_local_j, e_tran_j, server_cost, objective }
    }

    /// Whether a segment of `segment_bits` fits the device memory.
    pub fn fits_memory(&self, segment_bits: u64) -> bool {
        segment_bits <= self.device.memory_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::mlp6;
    use crate::testing::assert_close;

    #[test]
    fn eq5_eq6_local() {
        let d = DeviceProfile::paper_default();
        // 1e6 MACs at γ=5, 200 MHz → 25 ms
        assert_close(d.compute_time_s(1_000_000), 0.025, 1e-12, 1e-12);
        // E = κ f² O γ = 3e-27 · 4e16 · 1e6 · 5 = 6e-4 J
        assert_close(d.compute_energy_j(1_000_000), 6e-4, 1e-12, 1e-9);
    }

    #[test]
    fn eq7_eq8_server() {
        let s = ServerProfile::paper_default();
        // 3e9 Hz, γ=1.25: 3e9 MACs → 1.25 s; cost = 1.25·ζ
        assert_close(s.compute_time_s(3_000_000_000), 1.25, 1e-12, 1e-12);
        assert_close(s.compute_cost(3_000_000_000), 1.25 * 0.01, 1e-12, 1e-12);
    }

    #[test]
    fn objective_linear_decomposition_eq23() {
        // J must equal ξ·O1 + δ·O2 + ε·Z exactly (that is Eq. 23's point).
        let cm = CostModel::paper_default();
        let m = mlp6();
        for p in 0..=m.num_layers() {
            let z = m.payload_bits(p, &vec![8u8; p], 8);
            let b = cm.evaluate(&m, p, z);
            let linear = cm.xi() * m.device_macs(p) as f64
                + cm.delta() * m.server_macs(p) as f64
                + cm.epsilon() * z as f64;
            assert_close(b.objective, linear, 1e-15, 1e-9);
        }
    }

    #[test]
    fn weights_steer_objective() {
        let m = mlp6();
        let mut latency_first = CostModel::paper_default();
        latency_first.weights = TradeoffWeights { omega: 10.0, tau: 0.0, eta: 0.0 };
        let mut energy_first = CostModel::paper_default();
        energy_first.weights = TradeoffWeights { omega: 0.0, tau: 10.0, eta: 0.0 };
        let z = m.payload_bits(3, &[8, 8, 8], 8);
        let bl = latency_first.evaluate(&m, 3, z);
        let be = energy_first.evaluate(&m, 3, z);
        assert_close(bl.objective, 10.0 * bl.total_time_s(), 1e-15, 1e-12);
        assert_close(be.objective, 10.0 * be.total_energy_j(), 1e-15, 1e-12);
    }

    #[test]
    fn breakdown_components_nonnegative() {
        let cm = CostModel::paper_default();
        let m = mlp6();
        let b = cm.evaluate(&m, 2, m.payload_bits(2, &[6, 6], 6));
        for v in [b.t_local_s, b.t_server_s, b.t_tran_s, b.e_local_j, b.e_tran_j, b.server_cost] {
            assert!(v >= 0.0);
        }
        assert!(b.objective > 0.0);
    }

    #[test]
    fn server_cost_decreases_with_p() {
        // Fig. 5's third panel: more local work → less server cost.
        let cm = CostModel::paper_default();
        let m = mlp6();
        let mut prev = f64::INFINITY;
        for p in 0..=m.num_layers() {
            let b = cm.evaluate(&m, p, 0);
            assert!(b.server_cost <= prev);
            prev = b.server_cost;
        }
    }

    #[test]
    fn profiles_json_roundtrip() {
        let d = DeviceProfile::paper_default();
        assert_eq!(DeviceProfile::from_json(&d.to_json()).unwrap(), d);
        let s = ServerProfile::paper_default();
        assert_eq!(ServerProfile::from_json(&s.to_json()).unwrap(), s);
        let w = TradeoffWeights::paper_default();
        assert_eq!(TradeoffWeights::from_json(&w.to_json()).unwrap(), w);
        // defaults fill missing fields
        let partial = crate::json::parse(r#"{"clock_hz": 1e9}"#).unwrap();
        let dp = DeviceProfile::from_json(&partial).unwrap();
        assert_eq!(dp.clock_hz, 1e9);
        assert_eq!(dp.cycles_per_mac, d.cycles_per_mac);
    }

    #[test]
    fn memory_constraint() {
        let mut cm = CostModel::paper_default();
        cm.device.memory_bits = 1000;
        assert!(cm.fits_memory(1000));
        assert!(!cm.fits_memory(1001));
    }
}
