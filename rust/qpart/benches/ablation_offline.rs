//! **Ablation** — losslessness of the offline/online split.
//!
//! The paper precomputes bit-widths offline (Algorithm 1) and only selects
//! `(level, partition)` online (Algorithm 2). That is optimal *only*
//! because the closed-form bit-widths are independent of the per-bit price
//! ε (the channel): this bench verifies it empirically by re-solving the
//! bit-widths **online** under wildly different channels and comparing
//! against the offline table — the patterns must coincide, and the online
//! objective cannot improve.

mod common;

use common::*;
use qpart::core::optimizer::{solve_pattern, BitBounds};
use qpart::core::quant::PatternKey;
use qpart::prelude::*;
use qpart_bench::Table;

fn main() {
    let setup = mlp6_setup();
    banner("ablation — offline table vs online re-solving (mlp6)", setup.calibrated);
    let arch = &setup.arch;
    let calib = &setup.calib;

    let channels = [("10 kbps", 1e4), ("1 Mbps", 1e6), ("200 Mbps", 2e8), ("10 Gbps", 1e10)];
    let mut table = Table::new(
        "chosen pattern per channel (a = 1%)",
        &["channel", "p*", "bits (offline)", "re-solved == offline?", "objective"],
    );
    let mut all_match = true;
    for (name, bps) in channels {
        let mut cost = CostModel::paper_default();
        cost.channel = Channel::fixed(bps, 1.0);
        let d = serve_request(
            arch,
            &setup.patterns,
            &RequestParams { cost, accuracy_budget: 0.01 },
        )
        .unwrap();
        // re-solve the bit-widths fresh at this partition — ε plays no role
        let fresh = solve_pattern(arch, calib, LEVEL_1PCT, d.pattern.partition, BitBounds::default())
            .unwrap();
        let same = fresh.weight_bits == d.pattern.weight_bits
            && fresh.activation_bits == d.pattern.activation_bits;
        all_match &= same;
        table.row(vec![
            name.into(),
            d.pattern.partition.to_string(),
            format!("{:?}/{}", d.pattern.weight_bits, d.pattern.activation_bits),
            if same { "yes".into() } else { "NO".into() },
            format!("{:.6}", d.cost.objective),
        ]);
    }
    table.print();
    println!(
        "\nbit-widths are ε-independent (paper's offline precomputation is lossless): {}",
        if all_match { "CONFIRMED" } else { "VIOLATED" }
    );

    // also show that the online partition choice *does* move with the channel
    let mut t2 = Table::new("partition choice vs channel (a = 5%)", &["channel", "p*"]);
    for (name, bps) in channels {
        let mut cost = CostModel::paper_default();
        cost.channel = Channel::fixed(bps, 1.0);
        let d = serve_request(
            arch,
            &setup.patterns,
            &RequestParams { cost, accuracy_budget: 0.05 },
        )
        .unwrap();
        t2.row(vec![name.into(), d.pattern.partition.to_string()]);
    }
    t2.print();
    let _ = PatternKey { level_idx: 0, partition: 0 };
}
