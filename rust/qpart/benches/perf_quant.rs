//! **§Perf** — quantize + bit-pack hot loop.
//!
//! This is the per-request O(params) work on the serving path: quantizing
//! the device segment's weights to the pattern's bit-widths and packing
//! the codes for the wire. Target (DESIGN.md §8): ≥200 MB/s/core.

mod common;

use common::*;
use qpart::core::quant::{pack_bits, quantize, unpack_bits};
use qpart_bench::{black_box, fmt_ns, quick, Table};

fn main() {
    let setup = mlp6_setup();
    banner("perf — quantize / pack / unpack / dequantize", setup.calibrated);
    // layer-1 of mlp6: 784×512 weights (the biggest single buffer)
    let n = 784 * 512;
    let data: Vec<f32> = (0..n).map(|i| ((i as f32) * 0.61803).sin()).collect();
    let mbytes = (n * 4) as f64 / 1e6;

    let mut table = Table::new(
        "hot-loop throughput (784×512 f32 weights)",
        &["op", "bits", "mean", "p99", "MB/s (f32 in)"],
    );
    for bits in [4u8, 8, 12] {
        let s = quick(|| {
            black_box(quantize(black_box(&data), bits).unwrap());
        });
        table.row(vec![
            "quantize".into(),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
        ]);

        let q = quantize(&data, bits).unwrap();
        let s = quick(|| {
            black_box(pack_bits(black_box(&q.codes), bits).unwrap());
        });
        table.row(vec![
            "pack".into(),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
        ]);

        let packed = pack_bits(&q.codes, bits).unwrap();
        let s = quick(|| {
            black_box(unpack_bits(black_box(&packed), n, bits).unwrap());
        });
        table.row(vec![
            "unpack".into(),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
        ]);

        let s = quick(|| {
            black_box(q.dequantize());
        });
        table.row(vec![
            "dequantize".into(),
            bits.to_string(),
            fmt_ns(s.mean_ns),
            fmt_ns(s.p99_ns),
            format!("{:.0}", s.per_second(mbytes)),
        ]);
    }
    table.print();

    // whole-segment quantization through the executor (bundle-backed)
    if let Some(bundle) = setup.bundle.clone() {
        use qpart::prelude::*;
        use std::sync::Arc;
        let mut ex = Executor::new(Arc::clone(&bundle)).unwrap();
        let pat = setup
            .patterns
            .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: 6 })
            .unwrap()
            .clone();
        let s = quick(|| {
            black_box(ex.quantize_segment("mlp6", &pat).unwrap());
        });
        let total_mb = setup.arch.total_params() as f64 * 4.0 / 1e6;
        println!(
            "\nfull-segment quantize (mlp6, p=6, {:.1} MB of weights): mean {} → {:.0} MB/s",
            total_mb,
            fmt_ns(s.mean_ns),
            s.per_second(total_mb),
        );
    }
}
