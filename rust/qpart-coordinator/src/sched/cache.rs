//! The encoded-reply cache: fully serialized segment replies per
//! `(model, accuracy level, partition)`.
//!
//! A segment reply is the coordinator's most expensive artifact: quantize
//! + bit-pack every device-side layer, then serialize megabytes of
//! payload (base64 + JSON, or the binary frame body). All of that is a
//! pure function of the coalescing key — only the session id and the
//! request's objective value differ between devices — so the cache stores
//! one [`EncodedSegmentBody`] per key and replies become a string splice.
//!
//! Eviction is LRU under a **byte budget** (encoded replies are large and
//! few; counting entries would let a handful of big models blow the
//! memory bound). The most recently inserted entry is never evicted, so a
//! budget smaller than one reply still serves (with zero reuse across
//! keys). Hit / miss / bytes-saved / eviction counters are surfaced
//! through `MetricsHub` into the `stats` document's `segment_cache`
//! section.
//!
//! Since the store tier landed, this type is a typed **facade** over
//! [`CacheCore`]: the eviction engine and counters live there (shared
//! with the decision cache), and when a [`StoreTier`] is attached every
//! insert stages the body — plus a phase-2 plan fingerprint — for the
//! segment log, so a `--warm log` restart replays the live reply set.

use super::batch::lock_recover;
use crate::store::{keys, CacheCore, CacheStats, Column, EvictPolicy, StoreTier};
use qpart_core::json::Value;
use qpart_proto::messages::EncodedSegmentBody;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: (model, accuracy-level index, partition point).
pub type SegmentKey = (String, usize, usize);

/// Shared, thread-safe encoded-reply cache (one per server).
pub struct EncodedReplyCache {
    budget_bytes: usize,
    core: CacheCore<SegmentKey, Arc<EncodedSegmentBody>>,
    /// Serialized-body bytes served from cache instead of re-encoded,
    /// measured as the JSON-form body length per hit. For binary-framed
    /// sessions (which skip the JSON body entirely) this is an upper
    /// bound — see [`EncodedSegmentBody::encoded_len`].
    bytes_saved: AtomicU64,
    /// Durable tier, when serving with `--store-dir`.
    store: Mutex<Option<Arc<StoreTier>>>,
}

impl std::fmt::Debug for EncodedReplyCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EncodedReplyCache")
            .field("budget_bytes", &self.budget_bytes)
            .field("entries", &self.len())
            .finish()
    }
}

impl EncodedReplyCache {
    /// A cache bounded to ~`budget_bytes` of resident encoded replies.
    pub fn new(budget_bytes: usize) -> EncodedReplyCache {
        EncodedReplyCache {
            budget_bytes,
            core: CacheCore::new(EvictPolicy::LruBytes { budget: budget_bytes as u64 }),
            bytes_saved: AtomicU64::new(0),
            store: Mutex::new(None),
        }
    }

    /// Attach the durable tier: subsequent inserts stage their bodies
    /// (and plan fingerprints) for the segment log, evictions stage
    /// deletes.
    pub fn attach_store(&self, tier: Arc<StoreTier>) {
        *lock_recover(&self.store) = Some(tier);
    }

    /// Look up a key, counting the hit/miss and touching LRU recency.
    pub fn get(&self, key: &SegmentKey) -> Option<Arc<EncodedSegmentBody>> {
        let got = self.core.get(key);
        if let Some(body) = &got {
            self.bytes_saved.fetch_add(body.encoded_len(), Ordering::Relaxed);
        }
        got
    }

    /// Insert (or replace — two workers may race to encode the same key)
    /// and evict least-recently-used entries past the byte budget. The
    /// entry just inserted is never evicted. With a store attached, the
    /// body and its `(model, partition)` plan fingerprint are staged for
    /// the log; evicted keys stage deletes (plan fingerprints stay —
    /// they are tiny and shared across levels).
    pub fn insert(&self, key: SegmentKey, body: Arc<EncodedSegmentBody>) {
        self.insert_inner(key, body, true)
    }

    /// Insert an entry replayed *from* the log (`--warm log`): identical
    /// residency semantics, but the body is not re-staged.
    pub fn insert_warm(&self, key: SegmentKey, body: Arc<EncodedSegmentBody>) {
        self.insert_inner(key, body, false)
    }

    fn insert_inner(&self, key: SegmentKey, body: Arc<EncodedSegmentBody>, persist: bool) {
        let store = lock_recover(&self.store).clone();
        if persist {
            if let Some(tier) = &store {
                let encoded = keys::encode_reply_body(&body);
                tier.stage_put(Column::Reply, keys::encode_reply_key(&key), encoded);
                tier.stage_put(Column::Plan, keys::encode_plan_key(&key.0, key.2), Vec::new());
            }
        }
        let cost = body.cost_bytes() as u64;
        let evicted = self.core.insert(key, body, cost);
        if let Some(tier) = &store {
            for victim in &evicted {
                tier.stage_delete(Column::Reply, keys::encode_reply_key(victim));
            }
        }
    }

    pub fn hits(&self) -> u64 {
        self.core.hits()
    }

    pub fn misses(&self) -> u64 {
        self.core.misses()
    }

    pub fn bytes_saved(&self) -> u64 {
        self.bytes_saved.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.core.evictions()
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.core.len()
    }

    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// Resident bytes (cost accounting, see `EncodedSegmentBody::cost_bytes`).
    pub fn bytes(&self) -> usize {
        self.core.bytes() as usize
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Hit rate over lookups so far (NaN before the first lookup).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits() as f64;
        let m = self.misses() as f64;
        h / (h + m)
    }

    /// The unified stats shape (the `caches.reply` section).
    pub fn stats(&self) -> CacheStats {
        self.core.stats()
    }

    /// The `segment_cache` section of the stats document (legacy shape,
    /// kept as an alias for one release).
    pub fn to_json(&self) -> Value {
        Value::obj([
            ("entries", self.len().into()),
            ("bytes", self.bytes().into()),
            ("budget_bytes", self.budget_bytes.into()),
            ("hits", self.hits().into()),
            ("misses", self.misses().into()),
            ("bytes_saved", self.bytes_saved().into()),
            ("evictions", self.evictions().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_proto::messages::{LayerBlob, PatternInfo, SegmentBlob};

    fn body(payload_bytes: usize) -> Arc<EncodedSegmentBody> {
        let segment = SegmentBlob {
            layers: vec![LayerBlob {
                layer: 1,
                bits: 8,
                w_dims: vec![1, payload_bytes.max(1)],
                w_qmin: 0.0,
                w_step: 0.1,
                w_packed: vec![0xAB; payload_bytes],
                b_qmin: 0.0,
                b_step: 0.1,
                b_len: 1,
                b_packed: vec![0xCD],
            }],
        };
        let pattern = PatternInfo {
            partition: 1,
            weight_bits: vec![8],
            activation_bits: 8,
            accuracy_level: 0.01,
            predicted_degradation: 0.0,
            objective: f64::NAN,
        };
        Arc::new(EncodedSegmentBody::new("m", pattern, segment))
    }

    fn key(i: usize) -> SegmentKey {
        ("m".to_string(), 0, i)
    }

    #[test]
    fn hit_miss_and_bytes_saved_counters() {
        let c = EncodedReplyCache::new(1 << 20);
        assert!(c.get(&key(1)).is_none());
        assert_eq!(c.misses(), 1);
        let b = body(100);
        c.insert(key(1), Arc::clone(&b));
        let got = c.get(&key(1)).unwrap();
        assert!(Arc::ptr_eq(&got, &b), "cache returns the shared body");
        assert_eq!(c.hits(), 1);
        assert_eq!(c.bytes_saved(), b.encoded_len());
        assert!(c.hit_rate() > 0.49 && c.hit_rate() < 0.51);
    }

    #[test]
    fn byte_budget_evicts_lru_first() {
        let one = body(1000).cost_bytes();
        // room for two entries, not three
        let c = EncodedReplyCache::new(2 * one + one / 2);
        c.insert(key(1), body(1000));
        c.insert(key(2), body(1000));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 0);
        // touch key 1 so key 2 becomes the LRU victim
        assert!(c.get(&key(1)).is_some());
        c.insert(key(3), body(1000));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert!(c.get(&key(2)).is_none(), "LRU entry evicted");
        assert!(c.get(&key(1)).is_some(), "recently used entry kept");
        assert!(c.get(&key(3)).is_some(), "newest entry kept");
        assert!(c.bytes() <= c.budget_bytes());
    }

    #[test]
    fn tiny_budget_keeps_only_the_newest() {
        // budget smaller than a single reply: the newest entry must still
        // be resident (serving always works), everything else evicts
        let c = EncodedReplyCache::new(1);
        for i in 0..5 {
            c.insert(key(i), body(500));
        }
        assert_eq!(c.len(), 1);
        assert_eq!(c.evictions(), 4);
        assert!(c.get(&key(4)).is_some());
    }

    #[test]
    fn replacing_a_key_does_not_leak_bytes() {
        let c = EncodedReplyCache::new(1 << 20);
        c.insert(key(1), body(1000));
        let after_first = c.bytes();
        c.insert(key(1), body(1000));
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), after_first, "replacement is not additive");
    }

    #[test]
    fn attached_store_stages_bodies_plans_and_evict_deletes() {
        let dir =
            std::env::temp_dir().join(format!("qpart-rcache-{}-stage", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let tier = StoreTier::open(&dir).unwrap();
        let one = body(1000).cost_bytes();
        let c = EncodedReplyCache::new(one + one / 2); // room for one entry
        c.attach_store(Arc::clone(&tier));
        c.insert(key(1), body(1000));
        c.insert(key(2), body(1000)); // evicts key 1
        tier.flush();
        assert_eq!(tier.get(Column::Reply, &keys::encode_reply_key(&key(1))), None);
        let persisted =
            tier.get(Column::Reply, &keys::encode_reply_key(&key(2))).expect("reply persisted");
        let replayed = keys::decode_reply_body(&persisted).expect("persisted body decodes");
        assert_eq!(&*replayed.layers_json_shared(), &*body(1000).layers_json_shared());
        // both partitions left plan fingerprints (plans are never deleted)
        assert!(tier.get(Column::Plan, &keys::encode_plan_key("m", 1)).is_some());
        assert!(tier.get(Column::Plan, &keys::encode_plan_key("m", 2)).is_some());
        // warm inserts don't re-stage
        let c2 = EncodedReplyCache::new(1 << 20);
        c2.attach_store(Arc::clone(&tier));
        c2.insert_warm(key(3), body(10));
        assert_eq!(tier.staged_len(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
