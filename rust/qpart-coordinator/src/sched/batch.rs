//! Batch draining and pre-encoded replies.
//!
//! Workers no longer pop one request per dequeue: [`drain_batch`] pulls
//! everything already queued (and, with a non-zero coalescing window,
//! waits briefly for stragglers) so the service can group `infer`
//! requests by `(model, accuracy level, partition)` and encode each group
//! **once**. The window trades a bounded latency add for fewer encodes —
//! `queue_wait` in the stats document makes that cost measurable.
//!
//! Replies travel back to connection threads as [`WireReply`]: either a
//! plain [`Response`], or a [`SegmentReply`] carrying the shared
//! [`EncodedSegmentBody`] plus the per-request session id and objective —
//! the connection thread stamps those into the negotiated framing (JSON
//! line or binary frame) without re-encoding the payload.

use crate::metrics::ClassCounts;
use crate::obs::{JobTrace, TraceStamp};
use qpart_proto::messages::{EncodedSegmentBody, Request, Response};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Take a mutex even if a previous holder panicked: every guarded
/// structure here (reply queues, the job receiver) is valid after any
/// partial operation, so recovering the data beats wedging the pool.
/// Worker panics are caught and converted into error replies by the
/// supervisor; a poisoned flag must not turn one bad request into a
/// permanently dead serving path.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A reply paired with its optional trace stamp: the stamp lets the
/// front-end measure completion-queue latency (the Route span) and
/// decide whether to echo the trace id on the wire.
pub type StampedReply = (WireReply, Option<TraceStamp>);

/// One queued request plus its reply path and enqueue timestamp.
#[derive(Debug)]
pub struct Job {
    pub req: Request,
    pub reply: ReplySink,
    /// When the front-end enqueued the job (→ `queue_wait`).
    pub enqueued: Instant,
    /// Trace identity when this request is sampled or hello-negotiated
    /// (`None` on the untraced fast path).
    pub trace: Option<JobTrace>,
    /// The connection's hello-declared device-class counters (`None` for
    /// unlabeled peers): deadline sheds and brownout degradations on this
    /// job are attributed there.
    pub class: Option<Arc<ClassCounts>>,
}

impl Job {
    /// A job replying over a dedicated channel (thread-per-connection
    /// front-end, in-process callers, tests).
    pub fn new(req: Request, reply_tx: SyncSender<StampedReply>) -> Job {
        Job {
            req,
            reply: ReplySink::channel(reply_tx),
            enqueued: Instant::now(),
            trace: None,
            class: None,
        }
    }

    /// A job replying through a [`ReplyRouter`] completion queue (the
    /// evented front-end: `token` names the connection the reactor
    /// routes the reply back to).
    pub fn routed(req: Request, token: u64, router: Arc<ReplyRouter>) -> Job {
        Job {
            req,
            reply: ReplySink::routed(token, router),
            enqueued: Instant::now(),
            trace: None,
            class: None,
        }
    }

    /// Attach a trace identity (builder style).
    pub fn with_trace(mut self, trace: Option<JobTrace>) -> Job {
        self.trace = trace;
        self
    }

    /// Attach the connection's device-class counters (builder style).
    pub fn with_class(mut self, class: Option<Arc<ClassCounts>>) -> Job {
        self.class = class;
        self
    }
}

/// Where a worker sends a finished [`WireReply`].
///
/// The executor pool is agnostic to the front-end's I/O model: a
/// thread-per-connection front-end blocks on a per-request channel, while
/// the poll-based reactor cannot block anywhere — its replies go onto a
/// shared completion queue ([`ReplyRouter`]) tagged with the connection
/// token, and the router's wake hook nudges the reactor out of `poll`.
#[derive(Clone, Debug)]
pub struct ReplySink {
    target: SinkTarget,
    /// Exactly-once delivery latch. The supervisor replies `internal` to
    /// every sink of a panicked batch; this flag makes that a no-op for
    /// jobs the worker had already answered before dying — a double send
    /// would block a full per-request channel or double-decrement the
    /// reactor's per-connection `in_flight` accounting.
    sent: Arc<AtomicBool>,
}

#[derive(Clone, Debug)]
enum SinkTarget {
    /// Dedicated per-request channel; the receiver blocks until the
    /// reply arrives (connection threads, in-process callers, tests).
    Channel(SyncSender<StampedReply>),
    /// Completion-queue routing for the evented front-end.
    Routed { token: u64, router: Arc<ReplyRouter> },
}

impl ReplySink {
    /// A sink delivering over a dedicated channel.
    pub fn channel(tx: SyncSender<StampedReply>) -> ReplySink {
        ReplySink { target: SinkTarget::Channel(tx), sent: Arc::new(AtomicBool::new(false)) }
    }

    /// A sink delivering through a [`ReplyRouter`] completion queue.
    pub fn routed(token: u64, router: Arc<ReplyRouter>) -> ReplySink {
        ReplySink {
            target: SinkTarget::Routed { token, router },
            sent: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Deliver an untraced reply. Delivery is best-effort in both
    /// flavors: a hung-up channel or a since-closed connection drops the
    /// reply, exactly like a connection thread whose peer vanished.
    pub fn send(&self, reply: WireReply) {
        self.send_with(reply, None);
    }

    /// Deliver the reply with an optional trace stamp. Only the first
    /// send per sink (across all clones) delivers; later sends are
    /// silently dropped — see the `sent` latch.
    pub fn send_with(&self, reply: WireReply, stamp: Option<TraceStamp>) {
        if self.sent.swap(true, Ordering::AcqRel) {
            return;
        }
        match &self.target {
            SinkTarget::Channel(tx) => {
                let _ = tx.send((reply, stamp));
            }
            SinkTarget::Routed { token, router } => router.push(*token, reply, stamp),
        }
    }

    /// Whether some clone of this sink already delivered a reply.
    pub fn already_sent(&self) -> bool {
        self.sent.load(Ordering::Acquire)
    }
}

/// The completion queue between the executor pool and an evented
/// front-end: workers [`push`](ReplyRouter::push) finished replies tagged
/// with their connection token; the reactor [`drain`](ReplyRouter::drain)s
/// them from its event loop and stamps each into the owning connection's
/// outbox. `wake` is called after every push so a reactor parked in
/// `poll(2)` learns about completions immediately (it must be cheap,
/// non-blocking, and safe from any worker thread).
pub struct ReplyRouter {
    queue: Mutex<Vec<(u64, WireReply, Option<TraceStamp>)>>,
    wake: Box<dyn Fn() + Send + Sync>,
}

impl std::fmt::Debug for ReplyRouter {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let depth = lock_recover(&self.queue).len();
        f.debug_struct("ReplyRouter").field("queued", &depth).finish()
    }
}

impl ReplyRouter {
    pub fn new(wake: Box<dyn Fn() + Send + Sync>) -> ReplyRouter {
        ReplyRouter { queue: Mutex::new(Vec::new()), wake }
    }

    /// Queue one finished reply for connection `token` and wake the
    /// reactor.
    pub fn push(&self, token: u64, reply: WireReply, stamp: Option<TraceStamp>) {
        lock_recover(&self.queue).push((token, reply, stamp));
        (self.wake)();
    }

    /// Take every queued completion (reactor thread).
    pub fn drain(&self) -> Vec<(u64, WireReply, Option<TraceStamp>)> {
        std::mem::take(&mut *lock_recover(&self.queue))
    }
}

/// A reply on its way back to a connection thread.
#[derive(Debug)]
pub enum WireReply {
    /// An ordinary response — serialized by the connection per its framing.
    Msg(Response),
    /// A segment reply sharing a pre-encoded body with its batch group.
    Segment(SegmentReply),
}

/// Per-connection stamp over a shared encoded segment body.
#[derive(Debug)]
pub struct SegmentReply {
    pub session: u64,
    /// Echoed trace id (`Some` only for hello-negotiated traces).
    pub trace: Option<u64>,
    /// Brownout marker: this request was planned at a coarser accuracy
    /// level than its nominal choice (still within its budget).
    pub degraded: bool,
    /// This request's Eq. 17 objective (the only per-request pattern field).
    pub objective: f64,
    pub body: Arc<EncodedSegmentBody>,
}

impl WireReply {
    /// Decode into a full [`Response`] (in-process callers and tests; the
    /// wire path stamps strings instead — see the connection loop).
    pub fn into_response(self) -> Response {
        match self {
            WireReply::Msg(r) => r,
            WireReply::Segment(s) => {
                let mut reply = s.body.to_reply(s.session, s.objective);
                reply.trace = s.trace;
                reply.degraded = s.degraded;
                Response::Segment(reply)
            }
        }
    }
}

/// How a worker drains the shared queue.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// After the first job arrives, wait up to this long for more jobs to
    /// coalesce with it. Zero = drain only what is already queued.
    pub window: Duration,
    /// Batch size cap (values < 1 behave as 1).
    pub max_batch: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { window: Duration::ZERO, max_batch: 32 }
    }
}

/// Result of one drain attempt.
#[derive(Debug)]
pub enum DrainOutcome {
    /// One or more jobs, coalesced per the policy.
    Batch(Vec<Job>),
    /// Nothing arrived within `idle_timeout` (caller re-checks stop flags).
    TimedOut,
    /// The queue's senders are gone; the worker should exit.
    Disconnected,
}

/// Whether a request can amortize work by coalescing with same-key peers
/// in a batch (and is therefore worth holding the window open for).
fn coalescible(req: &Request) -> bool {
    matches!(req, Request::Infer(_) | Request::Activation(_))
}

/// Greedily take everything already queued, up to `max_batch` total.
fn top_up(rx: &Receiver<Job>, batch: &mut Vec<Job>, max_batch: usize) {
    while batch.len() < max_batch {
        match rx.try_recv() {
            Ok(j) => batch.push(j),
            Err(_) => break,
        }
    }
}

/// Drain the next batch: block up to `idle_timeout` for the first job,
/// greedily take whatever is already queued, then — if the batch is not
/// full and the policy has a window — wait out the coalescing window for
/// stragglers, up to `max_batch` jobs.
///
/// The receiver lock is held only for the actual dequeues. During the
/// window the lock is re-taken in ≤ 1 ms slices, so an idle worker can
/// interleave and pick up (different-key) work instead of the whole pool
/// serializing behind one worker's wait — the window costs latency on the
/// coalesced requests, never pool-wide dequeue throughput.
pub fn drain_batch(
    rx: &Mutex<Receiver<Job>>,
    policy: &BatchPolicy,
    idle_timeout: Duration,
) -> DrainOutcome {
    let max_batch = policy.max_batch.max(1);
    // phase 1: wait for the first job and sweep the backlog, one lock hold
    let mut batch = {
        let guard = lock_recover(rx);
        let first = match guard.recv_timeout(idle_timeout) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => return DrainOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => return DrainOutcome::Disconnected,
        };
        let mut batch = vec![first];
        top_up(&guard, &mut batch, max_batch);
        batch
    };
    // phase 2: coalescing window — short lock slices, interleavable.
    // Only coalescible requests benefit from waiting: `infer` requests
    // share one encode per (model, level, partition) group, and
    // `activation` uploads row-stack into one server-segment execution
    // per (model, partition) group. A batch with neither (ping/stats)
    // skips the window entirely — it must not pay latency for zero
    // batching benefit.
    if !batch.iter().any(|j| coalescible(&j.req)) {
        return DrainOutcome::Batch(batch);
    }
    let deadline = Instant::now() + policy.window;
    while batch.len() < max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        let slice = (deadline - now).min(Duration::from_millis(1));
        let got = {
            let guard = lock_recover(rx);
            let got = guard.recv_timeout(slice);
            if got.is_ok() {
                top_up(&guard, &mut batch, max_batch.saturating_sub(1));
            }
            got
        };
        match got {
            Ok(j) => batch.push(j),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    DrainOutcome::Batch(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use qpart_proto::messages::InferRequest;
    use std::sync::mpsc::sync_channel;

    fn job() -> (Job, Receiver<StampedReply>) {
        let (tx, rx) = sync_channel(1);
        (Job::new(Request::Ping, tx), rx)
    }

    /// An infer job (coalescible: same-key requests share one encode, so
    /// it opts a batch into the coalescing window).
    fn infer_job() -> (Job, Receiver<StampedReply>) {
        let (tx, rx) = sync_channel(1);
        let req = InferRequest {
            model: "tinymlp".into(),
            accuracy_budget: 0.02,
            channel_capacity_bps: 200e6,
            tx_power_w: 1.0,
            clock_hz: 200e6,
            cycles_per_mac: 5.0,
            kappa: 3e-27,
            memory_bits: 1 << 31,
            weights: None,
            deadline_ms: None,
        };
        (Job::new(Request::Infer(req), tx), rx)
    }

    #[test]
    fn drains_everything_already_queued() {
        let (tx, rx) = sync_channel::<Job>(16);
        let rx = Mutex::new(rx);
        let mut reply_rxs = Vec::new();
        for _ in 0..5 {
            let (j, r) = job();
            tx.send(j).unwrap();
            reply_rxs.push(r);
        }
        let policy = BatchPolicy { window: Duration::ZERO, max_batch: 32 };
        match drain_batch(&rx, &policy, Duration::from_millis(100)) {
            DrainOutcome::Batch(b) => assert_eq!(b.len(), 5),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn max_batch_caps_the_drain() {
        let (tx, rx) = sync_channel::<Job>(16);
        let rx = Mutex::new(rx);
        let mut reply_rxs = Vec::new();
        for _ in 0..5 {
            let (j, r) = job();
            tx.send(j).unwrap();
            reply_rxs.push(r);
        }
        let policy = BatchPolicy { window: Duration::ZERO, max_batch: 3 };
        match drain_batch(&rx, &policy, Duration::from_millis(100)) {
            DrainOutcome::Batch(b) => assert_eq!(b.len(), 3),
            other => panic!("unexpected {other:?}"),
        }
        match drain_batch(&rx, &policy, Duration::from_millis(100)) {
            DrainOutcome::Batch(b) => assert_eq!(b.len(), 2, "remainder drained next"),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn window_waits_for_stragglers_without_monopolizing_the_lock() {
        let (tx, rx) = sync_channel::<Job>(16);
        let rx = std::sync::Arc::new(Mutex::new(rx));
        let (j, _r0) = infer_job();
        tx.send(j).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (j, r) = infer_job();
            tx.send(j).unwrap();
            r
        });
        // a competing thread must be able to take the lock mid-window
        let contender = {
            let rx = std::sync::Arc::clone(&rx);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(5));
                let locked_at = Instant::now();
                drop(rx.lock().unwrap());
                locked_at.elapsed()
            })
        };
        let policy = BatchPolicy { window: Duration::from_millis(500), max_batch: 2 };
        match drain_batch(&rx, &policy, Duration::from_millis(100)) {
            DrainOutcome::Batch(b) => {
                assert_eq!(b.len(), 2, "straggler coalesced within the window")
            }
            other => panic!("unexpected {other:?}"),
        }
        let lock_wait = contender.join().unwrap();
        assert!(
            lock_wait < Duration::from_millis(100),
            "window wait must not hold the receiver lock: contender waited {lock_wait:?}"
        );
        drop(sender.join().unwrap());
    }

    /// An activation job (coalescible: uploads row-stack into batched
    /// phase-2 executions, so they opt into the window like infers).
    fn activation_job() -> (Job, Receiver<StampedReply>) {
        let (tx, rx) = sync_channel(1);
        let req = qpart_proto::messages::ActivationUpload {
            session: 1,
            bits: 8,
            qmin: 0.0,
            step: 0.01,
            dims: vec![1, 4],
            packed: vec![0u8; 4],
        };
        (Job::new(Request::Activation(req), tx), rx)
    }

    #[test]
    fn activation_batches_wait_out_the_window_for_stragglers() {
        // concurrent uploads must be able to coalesce into one batched
        // server-segment execution: an activation opens the window
        let (tx, rx) = sync_channel::<Job>(16);
        let (j, _r0) = activation_job();
        tx.send(j).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            let (j, r) = activation_job();
            tx.send(j).unwrap();
            r
        });
        let rx = Mutex::new(rx);
        let policy = BatchPolicy { window: Duration::from_millis(500), max_batch: 2 };
        match drain_batch(&rx, &policy, Duration::from_millis(100)) {
            DrainOutcome::Batch(b) => {
                assert_eq!(b.len(), 2, "straggling upload coalesced within the window")
            }
            other => panic!("unexpected {other:?}"),
        }
        drop(sender.join().unwrap());
    }

    #[test]
    fn non_infer_batches_skip_the_window() {
        // a ping/stats-only batch must not pay the coalescing window:
        // nothing in it can amortize work by waiting
        let (tx, rx) = sync_channel::<Job>(16);
        let rx = Mutex::new(rx);
        let (j, _r) = job();
        tx.send(j).unwrap();
        let policy = BatchPolicy { window: Duration::from_millis(500), max_batch: 8 };
        let t0 = Instant::now();
        match drain_batch(&rx, &policy, Duration::from_millis(100)) {
            DrainOutcome::Batch(b) => assert_eq!(b.len(), 1),
            other => panic!("unexpected {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(200),
            "non-infer batch waited out the window: {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn reply_router_queues_wakes_and_drains() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let wakes = Arc::new(AtomicUsize::new(0));
        let w = Arc::clone(&wakes);
        let router = Arc::new(ReplyRouter::new(Box::new(move || {
            w.fetch_add(1, Ordering::SeqCst);
        })));
        let sink = ReplySink::routed(42, Arc::clone(&router));
        sink.send(WireReply::Msg(Response::Pong));
        router.push(7, WireReply::Msg(Response::Pong), None);
        assert_eq!(wakes.load(Ordering::SeqCst), 2, "every push wakes the reactor");
        let drained = router.drain();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].0, 42);
        assert_eq!(drained[1].0, 7);
        assert!(router.drain().is_empty(), "drain takes everything");
    }

    #[test]
    fn reply_sink_delivers_exactly_once_across_clones() {
        // the supervisor's blanket `internal` reply after a worker panic
        // must not double-deliver to jobs already answered
        let (tx, rx) = sync_channel::<StampedReply>(1);
        let sink = ReplySink::channel(tx);
        let clone = sink.clone();
        sink.send(WireReply::Msg(Response::Pong));
        assert!(clone.already_sent());
        // second send (via the clone) is a no-op: it neither blocks the
        // full channel nor queues a second reply
        clone.send(WireReply::Msg(Response::Pong));
        assert!(rx.try_recv().is_ok());
        assert!(rx.try_recv().is_err(), "only one reply delivered");

        // routed flavor: one push total
        let router = Arc::new(ReplyRouter::new(Box::new(|| {})));
        let sink = ReplySink::routed(9, Arc::clone(&router));
        let clone = sink.clone();
        sink.send(WireReply::Msg(Response::Pong));
        clone.send(WireReply::Msg(Response::Pong));
        assert_eq!(router.drain().len(), 1);
    }

    #[test]
    fn timeout_and_disconnect_are_distinguished() {
        let (tx, rx) = sync_channel::<Job>(4);
        let rx = Mutex::new(rx);
        let policy = BatchPolicy::default();
        assert!(matches!(
            drain_batch(&rx, &policy, Duration::from_millis(10)),
            DrainOutcome::TimedOut
        ));
        drop(tx);
        assert!(matches!(
            drain_batch(&rx, &policy, Duration::from_millis(10)),
            DrainOutcome::Disconnected
        ));
    }
}
