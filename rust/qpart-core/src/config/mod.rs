//! Layered configuration system.
//!
//! QPART binaries read JSON config files (there is no TOML crate offline;
//! JSON keeps one parser for config + manifests + wire). Configuration is
//! resolved in layers, later layers overriding earlier ones key-by-key:
//!
//! 1. built-in defaults ([`Config::default_value`]),
//! 2. a config file (`--config path.json`),
//! 3. `--set dotted.path=value` CLI overrides.
//!
//! [`Config`] then exposes typed views (`system()`, `serving()`) consumed
//! by the coordinator and the simulator.

use crate::channel::Channel;
use crate::cost::{CostModel, DeviceProfile, ServerProfile, TradeoffWeights};
use crate::error::{Error, Result};
use crate::json::{parse, Value};
use crate::optimizer::BitBounds;

/// Merged configuration tree.
#[derive(Debug, Clone)]
pub struct Config {
    root: Value,
}

/// System-level (paper Table II) parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SystemConfig {
    pub device: DeviceProfile,
    pub server: ServerProfile,
    pub weights: TradeoffWeights,
    pub channel: Channel,
    pub bounds: BitBounds,
}

impl SystemConfig {
    pub fn cost_model(&self) -> CostModel {
        CostModel {
            device: self.device,
            server: self.server,
            channel: self.channel,
            weights: self.weights,
        }
    }
}

/// Serving-stack parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// TCP listen address, e.g. "127.0.0.1:7878".
    pub listen: String,
    /// Worker threads handling requests.
    pub workers: usize,
    /// Maximum queued requests before admission control sheds load.
    pub queue_capacity: usize,
    /// Session TTL in seconds for the GC sweep (0 = no age-based expiry).
    pub session_ttl_secs: u64,
    /// Batch coalescing window in microseconds (0 = drain-only).
    pub batch_window_us: u64,
    /// Encoded-reply cache byte budget.
    pub cache_bytes: usize,
    /// Allow binary-frame negotiation on the wire.
    pub binary_frames: bool,
    /// Front-end accept gate: refuse connections beyond this many.
    pub max_conns: usize,
    /// Idle/slow-client connection timeout in seconds (0 = never;
    /// default matches `session_ttl_secs` — a device may legitimately
    /// be silent for its whole device-side compute window).
    pub conn_idle_secs: u64,
    /// Per-connection fair-queuing rate in requests/s (0 = disabled):
    /// each connection may sustain this many admissions per second (with
    /// a 2 s burst allowance); excess requests are refused with a
    /// `throttled` error instead of occupying queue capacity.
    pub fair_rate: f64,
    /// Plaintext metrics-scrape listen address ("" = disabled).
    pub metrics_listen: String,
    /// Deprecated boolean alias for `warm` (one release): `true` means
    /// `warm = "paper"` when no explicit `warm` key is set.
    pub warm_cache: bool,
    /// Cache pre-warm mode at startup (`serving.warm`): `"off"`,
    /// `"paper"` (encode the most-likely reply keys under the
    /// paper-default profile and pre-build their phase-2 plans — what
    /// the deprecated `warm_cache` boolean meant), or `"log"` (replay
    /// the durable segment log under `store_dir`).
    pub warm: String,
    /// Durable warm-state directory (`""` = disabled): cache inserts are
    /// persisted to an append-only segment log so a restart with
    /// `warm = "log"` comes up hot.
    pub store_dir: String,
    /// Artifact bundle directory.
    pub artifacts_dir: String,
    /// Default accuracy levels when no calibration file provides them.
    pub accuracy_levels: Vec<f64>,
}

impl Config {
    /// Built-in defaults (paper Table II + sensible serving values).
    pub fn default_value() -> Value {
        Value::obj([
            (
                "system",
                Value::obj([
                    ("device", DeviceProfile::paper_default().to_json()),
                    ("server", ServerProfile::paper_default().to_json()),
                    ("weights", TradeoffWeights::paper_default().to_json()),
                    (
                        "channel",
                        Value::obj([
                            ("capacity_bps", 200e6.into()),
                            ("tx_power_w", 1.0.into()),
                        ]),
                    ),
                    ("min_bits", 2u64.into()),
                    ("max_bits", 16u64.into()),
                ]),
            ),
            (
                "serving",
                Value::obj([
                    ("listen", "127.0.0.1:7878".into()),
                    ("workers", 4u64.into()),
                    ("queue_capacity", 1024u64.into()),
                    ("session_ttl_secs", 600u64.into()),
                    ("batch_window_us", 0u64.into()),
                    ("cache_bytes", (64u64 << 20).into()),
                    ("binary_frames", true.into()),
                    ("max_conns", 4096u64.into()),
                    ("conn_idle_secs", 600u64.into()),
                    ("fair_rate", 0u64.into()),
                    ("metrics_listen", "".into()),
                    ("warm_cache", false.into()),
                    // NOTE: no "warm" default here — `serving()` derives
                    // it from the deprecated warm_cache alias when the
                    // key is absent
                    ("store_dir", "".into()),
                    ("artifacts_dir", "artifacts".into()),
                    (
                        "accuracy_levels",
                        Value::num_arr(&[0.0025, 0.005, 0.01, 0.02, 0.05]),
                    ),
                ]),
            ),
        ])
    }

    /// Start from defaults only.
    pub fn defaults() -> Config {
        Config { root: Self::default_value() }
    }

    /// Defaults + a JSON file layer.
    pub fn from_file(path: &str) -> Result<Config> {
        let mut cfg = Config::defaults();
        let text = std::fs::read_to_string(path)?;
        let layer = parse(&text)?;
        cfg.merge(&layer);
        Ok(cfg)
    }

    /// Defaults + an in-memory layer (tests).
    pub fn from_value(layer: &Value) -> Config {
        let mut cfg = Config::defaults();
        cfg.merge(layer);
        cfg
    }

    /// Deep-merge `layer` over the current tree: objects merge recursively,
    /// everything else replaces.
    pub fn merge(&mut self, layer: &Value) {
        fn merge_into(dst: &mut Value, src: &Value) {
            match (dst, src) {
                (Value::Obj(d), Value::Obj(s)) => {
                    for (k, sv) in s {
                        if let Some(slot) = d.iter_mut().find(|(dk, _)| dk == k) {
                            merge_into(&mut slot.1, sv);
                        } else {
                            d.push((k.clone(), sv.clone()));
                        }
                    }
                }
                (d, s) => *d = s.clone(),
            }
        }
        merge_into(&mut self.root, layer);
    }

    /// Apply a `dotted.path=value` override (value parsed as JSON, falling
    /// back to a bare string).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let (path, raw) = spec
            .split_once('=')
            .ok_or_else(|| Error::InvalidArg(format!("override '{spec}' must be path=value")))?;
        let val = parse(raw).unwrap_or_else(|_| Value::Str(raw.to_string()));
        let mut layer = val;
        for seg in path.split('.').rev() {
            if seg.is_empty() {
                return Err(Error::InvalidArg(format!("empty path segment in '{spec}'")));
            }
            layer = Value::Obj(vec![(seg.to_string(), layer)]);
        }
        self.merge(&layer);
        Ok(())
    }

    /// Raw tree access.
    pub fn root(&self) -> &Value {
        &self.root
    }

    /// Dotted-path lookup.
    pub fn lookup(&self, path: &str) -> Option<&Value> {
        let mut cur = &self.root;
        for seg in path.split('.') {
            cur = cur.get(seg)?;
        }
        Some(cur)
    }

    /// Typed system view.
    pub fn system(&self) -> Result<SystemConfig> {
        let sys = self.root.req("system")?;
        let device = DeviceProfile::from_json(sys.req("device")?)?;
        let server = ServerProfile::from_json(sys.req("server")?)?;
        let weights = TradeoffWeights::from_json(sys.req("weights")?)?;
        let ch = sys.req("channel")?;
        let channel = Channel::fixed(
            ch.opt_f64("capacity_bps", 200e6),
            ch.opt_f64("tx_power_w", 1.0),
        );
        let min_bits = sys.opt_f64("min_bits", 2.0) as u8;
        let max_bits = sys.opt_f64("max_bits", 16.0) as u8;
        if min_bits == 0 || max_bits > 24 || min_bits > max_bits {
            return Err(Error::InvalidArg(format!(
                "invalid bit bounds [{min_bits}, {max_bits}]"
            )));
        }
        Ok(SystemConfig {
            device,
            server,
            weights,
            channel,
            bounds: BitBounds { min_bits, max_bits },
        })
    }

    /// Typed serving view.
    pub fn serving(&self) -> Result<ServingConfig> {
        let srv = self.root.req("serving")?;
        let warm_cache = srv.opt_bool("warm_cache", false);
        // an explicit `warm` key wins; otherwise the deprecated
        // warm_cache boolean maps true → "paper"
        let warm = srv
            .opt_str("warm", if warm_cache { "paper" } else { "off" })
            .to_string();
        Ok(ServingConfig {
            listen: srv.opt_str("listen", "127.0.0.1:7878").to_string(),
            workers: srv.opt_f64("workers", 4.0) as usize,
            queue_capacity: srv.opt_f64("queue_capacity", 1024.0) as usize,
            session_ttl_secs: srv.opt_f64("session_ttl_secs", 600.0) as u64,
            batch_window_us: srv.opt_f64("batch_window_us", 0.0) as u64,
            cache_bytes: srv.opt_f64("cache_bytes", (64u64 << 20) as f64) as usize,
            binary_frames: srv.opt_bool("binary_frames", true),
            max_conns: srv.opt_f64("max_conns", 4096.0) as usize,
            conn_idle_secs: srv.opt_f64("conn_idle_secs", 600.0) as u64,
            fair_rate: srv.opt_f64("fair_rate", 0.0),
            metrics_listen: srv.opt_str("metrics_listen", "").to_string(),
            warm_cache,
            warm,
            store_dir: srv.opt_str("store_dir", "").to_string(),
            artifacts_dir: srv.opt_str("artifacts_dir", "artifacts").to_string(),
            accuracy_levels: srv
                .req_f64_arr("accuracy_levels")
                .unwrap_or_else(|_| vec![0.0025, 0.005, 0.01, 0.02, 0.05]),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_parse_to_paper_table2() {
        let cfg = Config::defaults();
        let sys = cfg.system().unwrap();
        assert_eq!(sys.device, DeviceProfile::paper_default());
        assert_eq!(sys.server, ServerProfile::paper_default());
        assert_eq!(sys.channel.capacity_bps, 200e6);
        assert_eq!(sys.bounds, BitBounds::default());
        let srv = cfg.serving().unwrap();
        assert_eq!(srv.accuracy_levels.len(), 5);
    }

    #[test]
    fn file_layer_overrides() {
        let layer = parse(r#"{"system": {"device": {"clock_hz": 1e9}}}"#).unwrap();
        let cfg = Config::from_value(&layer);
        let sys = cfg.system().unwrap();
        assert_eq!(sys.device.clock_hz, 1e9);
        // untouched keys keep defaults
        assert_eq!(sys.device.cycles_per_mac, 5.0);
        assert_eq!(sys.server.clock_hz, 3e9);
    }

    #[test]
    fn dotted_overrides() {
        let mut cfg = Config::defaults();
        cfg.set_override("system.channel.capacity_bps=1e6").unwrap();
        cfg.set_override("serving.listen=0.0.0.0:9000").unwrap();
        cfg.set_override("serving.workers=8").unwrap();
        assert_eq!(cfg.system().unwrap().channel.capacity_bps, 1e6);
        let srv = cfg.serving().unwrap();
        assert_eq!(srv.listen, "0.0.0.0:9000");
        assert_eq!(srv.workers, 8);
    }

    #[test]
    fn serving_dataplane_knobs_default_and_override() {
        let cfg = Config::defaults();
        let srv = cfg.serving().unwrap();
        assert_eq!(srv.session_ttl_secs, 600);
        assert_eq!(srv.batch_window_us, 0);
        assert_eq!(srv.cache_bytes, 64 << 20);
        assert!(srv.binary_frames);
        assert!(!srv.warm_cache, "warming is opt-in");
        assert_eq!(srv.warm, "off", "warming is opt-in");
        assert_eq!(srv.store_dir, "", "durable store is opt-in");
        assert_eq!(srv.max_conns, 4096);
        assert_eq!(srv.conn_idle_secs, 600);
        assert_eq!(srv.fair_rate, 0.0, "fair queuing is opt-in");
        assert_eq!(srv.metrics_listen, "", "scrape listener is opt-in");
        let mut cfg = Config::defaults();
        cfg.set_override("serving.batch_window_us=2500").unwrap();
        cfg.set_override("serving.cache_bytes=1048576").unwrap();
        cfg.set_override("serving.binary_frames=false").unwrap();
        cfg.set_override("serving.session_ttl_secs=30").unwrap();
        cfg.set_override("serving.warm_cache=true").unwrap();
        cfg.set_override("serving.max_conns=128").unwrap();
        cfg.set_override("serving.conn_idle_secs=5").unwrap();
        cfg.set_override("serving.fair_rate=2.5").unwrap();
        cfg.set_override("serving.metrics_listen=127.0.0.1:9100").unwrap();
        let srv = cfg.serving().unwrap();
        assert_eq!(srv.batch_window_us, 2500);
        assert_eq!(srv.cache_bytes, 1 << 20);
        assert!(!srv.binary_frames);
        assert_eq!(srv.session_ttl_secs, 30);
        assert!(srv.warm_cache);
        assert_eq!(srv.warm, "paper", "warm_cache=true aliases to warm=paper");
        assert_eq!(srv.max_conns, 128);
        assert_eq!(srv.conn_idle_secs, 5);
        assert_eq!(srv.fair_rate, 2.5);
        assert_eq!(srv.metrics_listen, "127.0.0.1:9100");
    }

    #[test]
    fn warm_key_wins_over_the_deprecated_alias() {
        let mut cfg = Config::defaults();
        cfg.set_override("serving.warm=log").unwrap();
        cfg.set_override("serving.warm_cache=true").unwrap();
        cfg.set_override("serving.store_dir=/tmp/qpart-store").unwrap();
        let srv = cfg.serving().unwrap();
        assert_eq!(srv.warm, "log", "explicit warm key beats the alias");
        assert_eq!(srv.store_dir, "/tmp/qpart-store");
    }

    #[test]
    fn bad_overrides_rejected() {
        let mut cfg = Config::defaults();
        assert!(cfg.set_override("no_equals_sign").is_err());
        assert!(cfg.set_override("a..b=1").is_err());
    }

    #[test]
    fn bad_bounds_rejected() {
        let mut cfg = Config::defaults();
        cfg.set_override("system.min_bits=20").unwrap();
        cfg.set_override("system.max_bits=4").unwrap();
        assert!(cfg.system().is_err());
    }

    #[test]
    fn lookup_paths() {
        let cfg = Config::defaults();
        assert!(cfg.lookup("system.device.kappa").is_some());
        assert!(cfg.lookup("system.nope").is_none());
    }
}
