//! The joint quantization + partitioning optimizer (paper §IV).
//!
//! * [`solver`] — the closed-form layer-wise bit-width solution (Eq. 27/40
//!   via KKT water-filling) with bound handling and integer rounding.
//! * [`offline`] — paper **Algorithm 1**: enumerate partition points ×
//!   accuracy levels, solve bit-widths, emit the pattern set `{(b_a^p, p)}`.
//! * [`online`] — paper **Algorithm 2**: per-request selection of the
//!   accuracy level and the objective-minimizing partition point.

mod offline;
mod online;
mod solver;

pub use offline::{offline_quantize, OfflineConfig};
pub use online::{serve_request, serve_request_fast, Decision, RequestParams};
pub use solver::{solve_bits, solve_pattern, BitBounds, SolveItem, Solution};
