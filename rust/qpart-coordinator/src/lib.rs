//! # qpart-coordinator
//!
//! The Layer-3 serving stack — the QPART server an edge fleet talks to:
//!
//! * [`service`] — the request brain: per-model offline pattern tables
//!   (Algorithm 1 at startup), per-request decisions (Algorithm 2),
//!   segment quantization + bit-packing, session state for the two-phase
//!   protocol, PJRT execution of the server-side segment.
//! * [`server`] — TCP front-end: JSON-lines framing, a bounded job queue
//!   with admission control (overload sheds with an `overloaded` error),
//!   and a dedicated inference thread (PJRT is single-device; requests
//!   serialize there by design).
//! * [`client`] — the device side for examples/CLI: sends requests,
//!   executes the received quantized segment locally through its own PJRT
//!   engine, uploads the quantized boundary activation.
//! * [`metrics`] — counters + histograms surfaced via the `stats` request.
//! * [`session`] — session table with capacity-bounded GC.
//!
//! Python never appears anywhere on these paths.

pub mod client;
pub mod metrics;
pub mod server;
pub mod service;
pub mod session;

pub use client::DeviceClient;
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{serve, ServerConfig, ServerHandle};
pub use service::Service;
pub use session::{Session, SessionTable};
