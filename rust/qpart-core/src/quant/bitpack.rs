//! Arbitrary-bit-width bit-packing.
//!
//! The paper charges the channel `b` bits per quantized parameter (Eq. 14).
//! A real deployment has to actually put `b`-bit codes on the wire, so the
//! coordinator bit-packs code streams LSB-first into a byte buffer. This is
//! on the serving hot path (every response ships a packed segment) and is
//! benchmarked by `perf_quant`.
//!
//! The hot entry points ([`pack_bits`] / [`unpack_bits`]) dispatch once
//! per process (see [`crate::quant::simd`]) between SIMD kernels and the
//! word-wise implementations kept here as [`pack_bits_wordwise`] /
//! [`unpack_bits_wordwise`]: codes are validated in one upfront scan,
//! then the inner loops emit/consume multi-byte chunks through a 64-bit
//! accumulator instead of dribbling single bytes. The original
//! byte-at-a-time implementations are kept as [`pack_bits_scalar`] /
//! [`unpack_bits_scalar`] — the reference every faster kernel is
//! property-tested against byte-for-byte, and the baseline `perf_quant`
//! reports speedups over.

use crate::error::{Error, Result};
use crate::quant::simd;

/// Bytes needed to pack `n` codes at `bits` bits each.
pub fn packed_len_bytes(n: usize, bits: u8) -> usize {
    ((n as u64 * bits as u64).div_ceil(8)) as usize
}

pub(crate) fn check_bits(op: &str, bits: u8) -> Result<()> {
    if !(1..=24).contains(&bits) {
        return Err(Error::InvalidArg(format!("{op}: bits must be 1..=24, got {bits}")));
    }
    Ok(())
}

/// LSB-first `u64` word accumulator — the ONE copy of the word-wise
/// flush/recovery bit-twiddling, shared by [`pack_bits`] and the fused
/// quantize→pack kernel so the two emit paths cannot diverge (their
/// byte-identity is what the property tests guarantee).
///
/// Contract: `out` is exactly `packed_len_bytes(n_codes, bits)` long,
/// every pushed code fits its `bits ≤ 24`, and `finish` runs once after
/// the last push.
pub(crate) struct WordPacker<'a> {
    out: &'a mut [u8],
    acc: u64,
    acc_bits: u32,
    pos: usize,
}

impl<'a> WordPacker<'a> {
    pub(crate) fn new(out: &'a mut [u8]) -> WordPacker<'a> {
        WordPacker { out, acc: 0, acc_bits: 0, pos: 0 }
    }

    /// Append one `bits`-bit code.
    #[inline(always)]
    pub(crate) fn push(&mut self, code: u32, bits: u32) {
        self.acc |= (code as u64) << self.acc_bits;
        self.acc_bits += bits;
        if self.acc_bits >= 64 {
            // flush one whole word; bits of `code` shifted past the top
            // are recovered below (bits ≤ 24 < 64, so they all came from
            // this code)
            self.out[self.pos..self.pos + 8].copy_from_slice(&self.acc.to_le_bytes());
            self.pos += 8;
            self.acc_bits -= 64;
            self.acc = if self.acc_bits == 0 {
                0
            } else {
                (code as u64) >> (bits - self.acc_bits)
            };
        }
    }

    /// Flush the sub-word tail: `out` has exactly `ceil(acc_bits/8)`
    /// bytes left.
    pub(crate) fn finish(mut self) {
        while self.acc_bits > 0 {
            self.out[self.pos] = self.acc as u8;
            self.pos += 1;
            self.acc >>= 8;
            self.acc_bits = self.acc_bits.saturating_sub(8);
        }
    }
}

/// Pack `codes` (each `< 2^bits`) at `bits` bits per code, LSB-first.
///
/// Dispatching entry point: runs the SIMD kernel when the process-wide
/// [`simd::active`] mode is a vector tier, the word-wise kernel
/// otherwise. All paths are byte-identical to [`pack_bits_scalar`].
pub fn pack_bits(codes: &[u32], bits: u8) -> Result<Vec<u8>> {
    if simd::active().is_simd() {
        simd::pack_bits_simd(codes, bits)
    } else {
        pack_bits_wordwise(codes, bits)
    }
}

/// Unpack `n` codes at `bits` bits per code from `buf`.
///
/// Dispatching entry point: SIMD when [`simd::active`] is a vector tier,
/// word-wise otherwise. All paths are code-identical to
/// [`unpack_bits_scalar`].
pub fn unpack_bits(buf: &[u8], n: usize, bits: u8) -> Result<Vec<u32>> {
    if simd::active().is_simd() {
        simd::unpack_bits_simd(buf, n, bits)
    } else {
        unpack_bits_wordwise(buf, n, bits)
    }
}

/// Word-wise `pack_bits` (the PR 4 kernel): one upfront validation scan
/// (so the inner loop carries no per-code branch), then whole `u64` words
/// are flushed to the output in 8-byte stores. Byte-identical to
/// [`pack_bits_scalar`]; the oracle the SIMD paths are tested against and
/// the universal runtime fallback.
pub fn pack_bits_wordwise(codes: &[u32], bits: u8) -> Result<Vec<u8>> {
    check_bits("pack_bits", bits)?;
    let limit = 1u64 << bits;
    // upfront scan: the emit loop below is branch-light because every
    // code is already known to fit
    if let Some(&bad) = codes.iter().find(|&&c| (c as u64) >= limit) {
        return Err(Error::InvalidArg(format!("code {bad} does not fit in {bits} bits")));
    }
    let mut out = vec![0u8; packed_len_bytes(codes.len(), bits)];
    let mut packer = WordPacker::new(&mut out);
    let bits = bits as u32;
    for &c in codes {
        packer.push(c, bits);
    }
    packer.finish();
    Ok(out)
}

/// Word-wise `unpack_bits` (the PR 4 kernel): the accumulator refills
/// with up to 7–8 bytes per `u64` load instead of one byte per iteration.
/// Code-identical to [`unpack_bits_scalar`]; the oracle the SIMD paths
/// are tested against and the universal runtime fallback.
pub fn unpack_bits_wordwise(buf: &[u8], n: usize, bits: u8) -> Result<Vec<u32>> {
    check_bits("unpack_bits", bits)?;
    let need = packed_len_bytes(n, bits);
    if buf.len() < need {
        return Err(Error::InvalidArg(format!(
            "unpack_bits: buffer has {} bytes, need {need}",
            buf.len()
        )));
    }
    let bits = bits as u32;
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..n {
        if acc_bits < bits {
            // refill every whole byte that fits in the accumulator with
            // one (at most 8-byte) load; bits ≤ 24 leaves ≥ 5 free bytes
            let free = ((64 - acc_bits) >> 3) as usize;
            let take = free.min(buf.len() - pos);
            let mut chunk = [0u8; 8];
            chunk[..take].copy_from_slice(&buf[pos..pos + take]);
            acc |= u64::from_le_bytes(chunk) << acc_bits;
            pos += take;
            acc_bits += (take as u32) << 3;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        acc_bits -= bits;
    }
    Ok(out)
}

/// Byte-at-a-time reference packer (the pre-word-wise implementation).
/// Kept as the property-test oracle and the `perf_quant` baseline.
pub fn pack_bits_scalar(codes: &[u32], bits: u8) -> Result<Vec<u8>> {
    check_bits("pack_bits", bits)?;
    let limit = 1u64 << bits;
    let mut out = vec![0u8; packed_len_bytes(codes.len(), bits)];
    let mut acc: u64 = 0; // bit accumulator, LSB-first
    let mut acc_bits: u32 = 0;
    let mut pos = 0usize;
    for &c in codes {
        if (c as u64) >= limit {
            return Err(Error::InvalidArg(format!("code {c} does not fit in {bits} bits")));
        }
        acc |= (c as u64) << acc_bits;
        acc_bits += bits as u32;
        while acc_bits >= 8 {
            out[pos] = (acc & 0xFF) as u8;
            pos += 1;
            acc >>= 8;
            acc_bits -= 8;
        }
    }
    if acc_bits > 0 {
        out[pos] = (acc & 0xFF) as u8;
    }
    Ok(out)
}

/// Byte-at-a-time reference unpacker (the pre-word-wise implementation).
/// Kept as the property-test oracle and the `perf_quant` baseline.
pub fn unpack_bits_scalar(buf: &[u8], n: usize, bits: u8) -> Result<Vec<u32>> {
    check_bits("unpack_bits", bits)?;
    let need = packed_len_bytes(n, bits);
    if buf.len() < need {
        return Err(Error::InvalidArg(format!(
            "unpack_bits: buffer has {} bytes, need {need}",
            buf.len()
        )));
    }
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    let mut pos = 0usize;
    for _ in 0..n {
        while acc_bits < bits as u32 {
            acc |= (buf[pos] as u64) << acc_bits;
            pos += 1;
            acc_bits += 8;
        }
        out.push((acc & mask) as u32);
        acc >>= bits;
        acc_bits -= bits as u32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::check;

    #[test]
    fn roundtrip_all_widths() {
        for bits in 1u8..=24 {
            let limit = 1u64 << bits;
            let codes: Vec<u32> =
                (0..200u64).map(|i| ((i * 2_654_435_761) % limit) as u32).collect();
            let packed = pack_bits(&codes, bits).unwrap();
            assert_eq!(packed.len(), packed_len_bytes(codes.len(), bits));
            let back = unpack_bits(&packed, codes.len(), bits).unwrap();
            assert_eq!(back, codes, "bits={bits}");
        }
    }

    #[test]
    fn exact_sizes() {
        assert_eq!(packed_len_bytes(8, 1), 1);
        assert_eq!(packed_len_bytes(9, 1), 2);
        assert_eq!(packed_len_bytes(3, 5), 2); // 15 bits → 2 bytes
        assert_eq!(packed_len_bytes(0, 7), 0);
    }

    #[test]
    fn rejects_oversized_codes() {
        assert!(pack_bits(&[8], 3).is_err());
        assert!(pack_bits(&[7], 3).is_ok());
        assert!(pack_bits_scalar(&[8], 3).is_err());
        assert!(pack_bits_scalar(&[7], 3).is_ok());
    }

    #[test]
    fn rejects_short_buffer() {
        let packed = pack_bits(&[1, 2, 3], 8).unwrap();
        assert!(unpack_bits(&packed[..2], 3, 8).is_err());
        assert!(unpack_bits_scalar(&packed[..2], 3, 8).is_err());
    }

    #[test]
    fn rejects_bad_widths() {
        assert!(pack_bits(&[0], 0).is_err());
        assert!(pack_bits(&[0], 25).is_err());
        assert!(unpack_bits(&[0], 1, 0).is_err());
        assert!(pack_bits_scalar(&[0], 0).is_err());
        assert!(unpack_bits_scalar(&[0], 1, 25).is_err());
    }

    #[test]
    fn empty_roundtrip() {
        let packed = pack_bits(&[], 5).unwrap();
        assert!(packed.is_empty());
        assert_eq!(unpack_bits(&packed, 0, 5).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn prop_pack_unpack_identity() {
        check("pack∘unpack = id", 80, |rng| {
            let bits = rng.range_usize(1, 25) as u8;
            let n = rng.range_usize(0, 500);
            let limit = 1u64 << bits;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(limit) as u32).collect();
            let packed = pack_bits(&codes, bits).unwrap();
            let back = unpack_bits(&packed, n, bits).unwrap();
            assert_eq!(back, codes);
        });
    }

    #[test]
    fn prop_wordwise_matches_scalar_reference() {
        // The word-wise kernels must be drop-in: byte-identical packed
        // output and code-identical unpacking for every width.
        check("word-wise ≡ scalar", 120, |rng| {
            let bits = rng.range_usize(1, 25) as u8;
            let n = rng.range_usize(0, 600);
            let limit = 1u64 << bits;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(limit) as u32).collect();
            let word = pack_bits_wordwise(&codes, bits).unwrap();
            let scalar = pack_bits_scalar(&codes, bits).unwrap();
            assert_eq!(word, scalar, "bits={bits} n={n}");
            assert_eq!(
                unpack_bits_wordwise(&word, n, bits).unwrap(),
                unpack_bits_scalar(&word, n, bits).unwrap(),
                "bits={bits} n={n}"
            );
        });
    }

    #[test]
    fn wordwise_matches_scalar_at_dense_sizes() {
        // Sweep every width × lengths around the u64 flush boundaries so
        // the word/tail seams are covered deterministically, not just by
        // the random property test.
        for bits in 1u8..=24 {
            let limit = 1u64 << bits;
            for n in [0usize, 1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 21, 22, 63, 64, 65, 127] {
                let codes: Vec<u32> =
                    (0..n as u64).map(|i| ((i * 2_654_435_761) % limit) as u32).collect();
                let word = pack_bits_wordwise(&codes, bits).unwrap();
                let scalar = pack_bits_scalar(&codes, bits).unwrap();
                assert_eq!(word, scalar, "bits={bits} n={n}");
                assert_eq!(
                    unpack_bits_wordwise(&word, n, bits).unwrap(),
                    codes,
                    "bits={bits} n={n}"
                );
            }
        }
    }

    #[test]
    fn unpack_tolerates_oversized_buffer() {
        // Decoders may hand in a frame with trailing bytes; both
        // implementations must read only what `n` codes need.
        let codes = vec![3u32, 1, 2, 3, 0, 1];
        let mut packed = pack_bits(&codes, 2).unwrap();
        packed.extend_from_slice(&[0xFF; 9]);
        assert_eq!(unpack_bits(&packed, codes.len(), 2).unwrap(), codes);
        assert_eq!(unpack_bits_scalar(&packed, codes.len(), 2).unwrap(), codes);
    }

    #[test]
    fn prop_payload_matches_eq14_accounting() {
        // The packed byte length is exactly ceil(n·b/8): the wire carries
        // what Eq. 14 charges for (up to sub-byte padding).
        check("packed length", 40, |rng| {
            let bits = rng.range_usize(1, 17) as u8;
            let n = rng.range_usize(0, 300);
            let codes: Vec<u32> = (0..n).map(|_| rng.below(1u64 << bits) as u32).collect();
            let packed = pack_bits(&codes, bits).unwrap();
            assert_eq!(packed.len() as u64, (n as u64 * bits as u64).div_ceil(8));
        });
    }
}
