//! Crate-wide error type.

/// Errors produced by qpart-core.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// JSON syntax or structure error, with byte offset where available.
    #[error("json error at offset {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// A JSON document was valid but missing a required field / wrong type.
    #[error("schema error at {path}: {msg}")]
    Schema { path: String, msg: String },

    /// Tensor-file (.qt) format violation.
    #[error("tensor format error: {0}")]
    TensorFormat(String),

    /// Shape mismatch in tensor or model operations.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid argument to a public API.
    #[error("invalid argument: {0}")]
    InvalidArg(String),

    /// Optimization problem is infeasible for the given constraints
    /// (e.g. accuracy budget unreachable even at the maximum bit-width).
    #[error("infeasible: {0}")]
    Infeasible(String),

    /// Referenced model / layer / pattern does not exist.
    #[error("not found: {0}")]
    NotFound(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Convenience alias used across qpart crates.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for schema errors.
    pub fn schema(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Schema { path: path.into(), msg: msg.into() }
    }
}
