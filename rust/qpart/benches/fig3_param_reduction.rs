//! **Fig. 3** — Layer-wise Parameter Size Reduction.
//!
//! Paper: quantizing mlp6 layer-wise at the 1 % accuracy level shrinks
//! every layer's parameters by 62–84 % (avg 77 %) with degradation < 1 %.
//! This bench regenerates the per-layer bars: f32 size, quantized size,
//! reduction ratio.

mod common;

use common::*;
use qpart_bench::{fmt_bits, Table};

fn main() {
    let setup = mlp6_setup();
    banner("Fig. 3 — layer-wise parameter size reduction (mlp6, a = 1%)", setup.calibrated);
    let arch = &setup.arch;
    let l = arch.num_layers();
    let pat = setup
        .patterns
        .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: l })
        .expect("full-partition pattern");

    let mut table = Table::new(
        "per-layer parameter payload",
        &["layer", "params", "bits", "f32 size", "quantized", "reduction"],
    );
    let mut total_f32 = 0u64;
    let mut total_q = 0u64;
    let mut reductions = Vec::new();
    for i in 1..=l {
        let z = arch.weight_params(i);
        let bits = pat.weight_bits[i - 1] as u64;
        let f32_bits = 32 * z;
        let q_bits = bits * z;
        let red = 1.0 - q_bits as f64 / f32_bits as f64;
        reductions.push(red);
        total_f32 += f32_bits;
        total_q += q_bits;
        table.row(vec![
            arch.layers[i - 1].name.clone(),
            z.to_string(),
            bits.to_string(),
            fmt_bits(f32_bits),
            fmt_bits(q_bits),
            format!("{:.1}%", red * 100.0),
        ]);
    }
    table.row(vec![
        "TOTAL".into(),
        arch.total_params().to_string(),
        "-".into(),
        fmt_bits(total_f32),
        fmt_bits(total_q),
        format!("{:.1}%", (1.0 - total_q as f64 / total_f32 as f64) * 100.0),
    ]);
    table.print();
    let avg = reductions.iter().sum::<f64>() / reductions.len() as f64;
    println!(
        "\npaper: per-layer reductions 62–84 %, average 77 %  |  measured avg: {:.1} % \
         (min {:.1} %, max {:.1} %), predicted degradation {:.3} % (budget 1 %)",
        avg * 100.0,
        reductions.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
        reductions.iter().cloned().fold(0.0, f64::max) * 100.0,
        pat.predicted_degradation * 100.0,
    );
}
