//! Quantization: the uniform asymmetric quantizer (paper Eq. 9–10),
//! arbitrary-bit-width bit-packing for the simulated wire (the payload the
//! channel model charges for, Eq. 14), and quantization patterns `(b, p)`
//! (the unit Algorithm 1 produces and Algorithm 2 selects).
//!
//! Hot-path entry points: the word-wise [`pack_bits`] / [`unpack_bits`]
//! and the fused [`quantize_packed`] (no intermediate code vector). The
//! byte-at-a-time `*_scalar` variants are the property-test oracles and
//! the `perf_quant` baselines.

mod bitpack;
mod pattern;
mod quantizer;

pub use bitpack::{
    pack_bits, pack_bits_scalar, packed_len_bytes, unpack_bits, unpack_bits_scalar,
};
pub use pattern::{PatternKey, PatternSet, QuantPattern};
pub use quantizer::{
    dequantize, quantize, quantize_packed, quantize_packed_with, quantize_with, PackedQuantized,
    QuantParams, Quantized,
};
