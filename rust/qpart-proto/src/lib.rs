//! # qpart-proto
//!
//! Wire protocol between edge devices and the QPART coordinator:
//! newline-delimited JSON over TCP (JSON-lines). Every message is one line;
//! binary payloads (bit-packed quantized segments) are base64-encoded.
//!
//! The request carries exactly the tuple of paper Algorithm 2's Require
//! line: model id, accuracy budget `a`, channel capacity `r`, transmit
//! power `π`, and the device compute profile `(γ_local, f_local, κ)`.

pub mod base64;
pub mod frame;
pub mod messages;

pub use frame::{read_frame, write_frame, FrameError, MAX_FRAME_BYTES};
pub use messages::{
    ErrorReply, InferReply, InferRequest, LayerBlob, PatternInfo, Request, Response, SegmentBlob,
};
