//! Front-end integration tests: connection churn, slow-loris timeouts,
//! the `max_conns` accept gate, the metrics scrape listener, and
//! reactor-vs-threaded reply identity — no PJRT required (synthetic
//! bundle, phase-1 traffic plus raw-socket abuse).
//!
//! The default front-end is the poll-based reactor (`Frontend::Reactor`),
//! so every other TCP-level test in this crate soaks it too; this file
//! covers the behaviors that are *about* the front-end itself.

use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, BlockingConn};
use qpart_coordinator::{serve, Frontend, ServerConfig};
use qpart_proto::frame::read_frame;
use qpart_proto::messages::{HelloRequest, Request, Response};
use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Poll `f` until it returns true or `deadline` elapses (the reactor
/// notices closes/timeouts on its next tick, not synchronously).
fn wait_until<F: Fn() -> bool>(deadline: Duration, f: F) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    f()
}

#[test]
fn accepted_connections_scale_past_the_worker_cap() {
    let dir = synthetic_bundle("fe-scale");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // hold many more live connections than workers, all served
    let clients = 48usize;
    let barrier = std::sync::Arc::new(std::sync::Barrier::new(clients));
    let mut joins = Vec::new();
    for _ in 0..clients {
        let addr = addr.clone();
        let barrier = std::sync::Arc::clone(&barrier);
        joins.push(std::thread::spawn(move || {
            let mut conn = BlockingConn::connect(&addr).unwrap();
            assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
            // everyone connected at once — the peak is clients-wide
            barrier.wait();
            match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
                Response::Segment(r) => assert!(r.session > 0),
                other => panic!("unexpected {other:?}"),
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let snap = handle.snapshot();
    assert!(
        snap.conns_accepted_total >= clients as u64,
        "accepted {} < {clients}",
        snap.conns_accepted_total
    );
    assert!(
        snap.conns_open_peak >= clients as u64,
        "peak {} — connections did not overlap",
        snap.conns_open_peak
    );
    assert!(
        snap.conns_open_peak > 2,
        "accepted-connection count must not be capped near the worker count"
    );
    assert_eq!(snap.errors_total, 0);
    // every client dropped: the front-end reaps them all
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "conns_open stuck at {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn many_short_lived_clients_churn_cleanly() {
    let dir = synthetic_bundle("fe-churn");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();
    let rounds = 60usize;
    for i in 0..rounds {
        let mut conn = BlockingConn::connect(&addr).unwrap();
        match conn.call(&Request::Ping).unwrap() {
            Response::Pong => {}
            other => panic!("round {i}: unexpected {other:?}"),
        }
        // dropped here: connect/serve/close every round
    }
    let snap = handle.snapshot();
    assert!(snap.conns_accepted_total >= rounds as u64);
    assert_eq!(snap.requests_total, rounds as u64);
    assert_eq!(snap.errors_total, 0);
    assert_eq!(snap.conns_rejected_total, 0);
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open == 0),
        "short-lived connections leaked: conns_open = {}",
        handle.snapshot().conns_open
    );
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slow_loris_and_half_open_clients_are_idle_timed_out() {
    let dir = synthetic_bundle("fe-loris");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        conn_idle: Duration::from_millis(200),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // a slow loris: half a frame, then silence — a connection thread
    // would be pinned forever, the reactor must time it out
    let mut loris = TcpStream::connect(&addr).unwrap();
    loris.write_all(b"{\"type\":\"pi").unwrap();
    // a half-open peer: connects and never sends a byte
    let half_open = TcpStream::connect(&addr).unwrap();

    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_timed_out >= 2),
        "idle sweep never fired: conns_timed_out = {}",
        handle.snapshot().conns_timed_out
    );
    // the server really closed the sockets: reads drain to EOF/reset
    loris.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut buf = [0u8; 64];
    match loris.read(&mut buf) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("loris got {n} unexpected bytes: {:?}", &buf[..n]),
    }
    drop(half_open);

    // a live client that keeps talking is NOT timed out
    let mut conn = BlockingConn::connect(&addr).unwrap();
    for _ in 0..5 {
        assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
        std::thread::sleep(Duration::from_millis(60));
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn max_conns_gate_refuses_excess_connections() {
    let dir = synthetic_bundle("fe-gate");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        max_conns: 2,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let addr = handle.addr.to_string();

    // two registered connections fill the gate (the ping round trips
    // guarantee the front-end has accepted both)
    let mut c1 = BlockingConn::connect(&addr).unwrap();
    let mut c2 = BlockingConn::connect(&addr).unwrap();
    assert!(matches!(c1.call(&Request::Ping).unwrap(), Response::Pong));
    assert!(matches!(c2.call(&Request::Ping).unwrap(), Response::Pong));

    // the third is refused loudly: a max_conns error line, then EOF
    let third = TcpStream::connect(&addr).unwrap();
    third.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = BufReader::new(third);
    let line = read_frame(&mut reader).expect("refusal line before close");
    match Response::from_line(&line).unwrap() {
        Response::Error(e) => assert_eq!(e.code, "max_conns", "{}", e.message),
        other => panic!("unexpected {other:?}"),
    }
    let snap = handle.snapshot();
    assert!(snap.conns_rejected_total >= 1);
    assert_eq!(snap.conns_open, 2, "rejected connection consumed no slot");

    // capacity freed by a close is reusable
    drop(c2);
    assert!(
        wait_until(Duration::from_secs(5), || handle.snapshot().conns_open < 2),
        "closed connection never released its slot"
    );
    let mut c3 = BlockingConn::connect(&addr).unwrap();
    assert!(matches!(c3.call(&Request::Ping).unwrap(), Response::Pong));
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn metrics_listener_serves_a_prometheus_scrape() {
    let dir = synthetic_bundle("fe-scrape");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        metrics_listen: Some("127.0.0.1:0".into()),
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let metrics_addr = handle.metrics_addr.expect("metrics listener bound");

    // some traffic so the counters are non-trivial
    let mut conn = BlockingConn::connect(&handle.addr.to_string()).unwrap();
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))).unwrap() {
        Response::Segment(_) => {}
        other => panic!("unexpected {other:?}"),
    }

    let mut scrape = TcpStream::connect(metrics_addr).unwrap();
    scrape.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    scrape.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut body = String::new();
    let _ = scrape.read_to_string(&mut body); // server closes when flushed
    assert!(body.starts_with("HTTP/1.0 200 OK\r\n"), "{body}");
    assert!(body.contains("Content-Type: text/plain"), "{body}");
    for needle in [
        "qpart_requests_total ",
        "qpart_conns_open ",
        "qpart_conns_accepted_total ",
        "qpart_open_sessions 1",
    ] {
        assert!(body.contains(needle), "missing {needle:?} in scrape:\n{body}");
    }
    assert!(!body.contains("NaN"), "{body}");

    // the protocol socket still works after scrapes (separate listener)
    assert!(matches!(conn.call(&Request::Ping).unwrap(), Response::Pong));
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pipelined_frames_on_one_connection_are_answered_in_order() {
    let dir = synthetic_bundle("fe-pipeline");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        artifacts_dir: dir.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap();
    let mut stream = TcpStream::connect(handle.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // two requests in one write: the front-end must answer both, in order
    stream.write_all(b"{\"type\":\"ping\"}\n{\"type\":\"list_models\"}\n").unwrap();
    let mut reader = BufReader::new(stream);
    let first = Response::from_line(&read_frame(&mut reader).unwrap()).unwrap();
    assert!(matches!(first, Response::Pong), "{first:?}");
    match Response::from_line(&read_frame(&mut reader).unwrap()).unwrap() {
        Response::Models(ms) => assert_eq!(ms[0].name, "tinymlp"),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn reactor_and_threaded_frontends_serve_identical_replies() {
    let dir = synthetic_bundle("fe-identity");
    let mk = |frontend| {
        serve(ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            frontend,
            artifacts_dir: dir.to_str().unwrap().to_string(),
            ..ServerConfig::default()
        })
        .unwrap()
    };
    let reactor = mk(Frontend::Reactor);
    let threaded = mk(Frontend::Threaded);

    let req = paper_request("tinymlp", 0.02);
    for negotiate in [false, true] {
        let mut a = BlockingConn::connect(&reactor.addr.to_string()).unwrap();
        let mut b = BlockingConn::connect(&threaded.addr.to_string()).unwrap();
        if negotiate {
            let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
            for conn in [&mut a, &mut b] {
                match conn.call(&hello).unwrap() {
                    Response::Hello(h) => assert!(h.binary_frames),
                    other => panic!("unexpected {other:?}"),
                }
            }
        }
        let ra = match a.call(&Request::Infer(req.clone())).unwrap() {
            Response::Segment(r) => r,
            other => panic!("unexpected {other:?}"),
        };
        let rb = match b.call(&Request::Infer(req.clone())).unwrap() {
            Response::Segment(r) => r,
            other => panic!("unexpected {other:?}"),
        };
        // session ids are per-server; payload and decision must match
        assert_eq!(ra.segment, rb.segment, "negotiate={negotiate}");
        assert_eq!(ra.pattern, rb.pattern, "negotiate={negotiate}");
    }
    reactor.shutdown();
    threaded.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
