//! PJRT engine: compile HLO-text artifacts, execute with f32 tensors.
//!
//! Follows the reference wiring (`/opt/xla-example/load_hlo`): HLO *text* is
//! the interchange format (jax ≥ 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids), every
//! executable returns a 1-tuple (`return_tuple=True` at lowering), and the
//! client is the single-device CPU PJRT plugin.

use crate::error::{Error, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// A host-side f32 tensor (dims + row-major data) — the runtime's lingua
/// franca between `qpart_core::tensor::Tensor`, literals, and wire buffers.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "dims {:?} imply {n} elements, got {}",
                dims,
                data.len()
            )));
        }
        Ok(HostTensor { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> HostTensor {
        let n = dims.iter().product();
        HostTensor { dims, data: vec![0.0; n] }
    }

    pub fn scalar2(v: f32) -> HostTensor {
        HostTensor { dims: vec![1, 1], data: vec![v] }
    }

    /// Leading-dim length (batch size).
    pub fn batch(&self) -> usize {
        self.dims.first().copied().unwrap_or(0)
    }

    /// Elements per batch row.
    pub fn row_elems(&self) -> usize {
        self.dims[1..].iter().product()
    }

    /// Rows `lo..hi` (shares the non-batch dims).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> HostTensor {
        let re = self.row_elems();
        let mut dims = self.dims.clone();
        dims[0] = hi - lo;
        HostTensor { dims, data: self.data[lo * re..hi * re].to_vec() }
    }

    /// Stack tensors along the batch dim (all must share the non-batch
    /// dims). The building block of batched phase-2 execution: coalesced
    /// activation rows stack into one executable input.
    pub fn stack(rows: &[HostTensor]) -> Result<HostTensor> {
        let first = rows
            .first()
            .ok_or_else(|| Error::Shape("cannot stack zero tensors".into()))?;
        if first.dims.is_empty() {
            return Err(Error::Shape("cannot stack rank-0 tensors".into()));
        }
        let total: usize = rows.iter().map(HostTensor::batch).sum();
        let mut dims = first.dims.clone();
        dims[0] = total;
        let mut data = Vec::with_capacity(total * first.row_elems());
        for r in rows {
            if r.dims[1..] != first.dims[1..] {
                return Err(Error::Shape(format!(
                    "stack: row dims {:?} vs {:?}",
                    r.dims, first.dims
                )));
            }
            data.extend_from_slice(&r.data);
        }
        HostTensor::new(dims, data)
    }

    /// Rows `lo..hi`, zero-padded up to `rows` (for fixed-batch executables).
    pub fn slice_rows_padded(&self, lo: usize, hi: usize, rows: usize) -> HostTensor {
        let re = self.row_elems();
        let mut dims = self.dims.clone();
        dims[0] = rows;
        let mut data = vec![0.0f32; rows * re];
        data[..(hi - lo) * re].copy_from_slice(&self.data[lo * re..hi * re]);
        HostTensor { dims, data }
    }

    /// Convert to an XLA literal (copies once; cache the result when the
    /// tensor is reused across calls — see `PreparedSegment`).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.dims,
            bytes,
        )?)
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        HostTensor::new(dims, data)
    }
}

impl From<qpart_core::tensor::Tensor> for HostTensor {
    fn from(t: qpart_core::tensor::Tensor) -> Self {
        HostTensor { dims: t.dims().to_vec(), data: t.into_data() }
    }
}

/// A compiled executable (1-tuple output convention).
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    /// Identifier for diagnostics (artifact name or path).
    pub name: String,
}

// SAFETY: an `Exec` is immutable after compilation and PJRT CPU
// executables are internally synchronized for concurrent `Execute` calls;
// the pool-wide compile cache shares them read-only across workers. The
// offline `xla` stub is a plain struct. Builds against real bindings
// whose handles are not thread-safe must keep `workers = 1` or disable
// the shared cache (see the README's real-xla notes).
unsafe impl Send for Exec {}
unsafe impl Sync for Exec {}

impl Exec {
    /// Execute with host tensors; returns the single output tensor.
    pub fn run(&self, inputs: &[&HostTensor]) -> Result<HostTensor> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literals(&refs)
    }

    /// Execute with pre-built literals (hot path: cached weight/code
    /// literals skip the per-call host->literal copy).
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<HostTensor> {
        let result = self.exe.execute::<&xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        HostTensor::from_literal(&out)
    }
}

/// PJRT CPU client + executable cache.
///
/// Not `Send`/`Sync` (wraps raw PJRT pointers); the coordinator owns one
/// engine on a dedicated inference thread.
pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Exec>>>,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu()?, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile an HLO text file (no caching).
    pub fn compile_file(&self, path: &Path, name: &str) -> Result<Exec> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Shape(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Exec { exe, name: name.to_string() })
    }

    /// Compile with caching keyed by `name`.
    pub fn load(&self, path: &Path, name: &str) -> Result<Rc<Exec>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(e));
        }
        let exec = Rc::new(self.compile_file(path, name)?);
        self.cache.borrow_mut().insert(name.to_string(), Rc::clone(&exec));
        Ok(exec)
    }

    /// Number of cached executables (diagnostics).
    pub fn cached(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop all cached executables.
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_shape_checks() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        let t = HostTensor::zeros(vec![4, 2]);
        assert_eq!(t.batch(), 4);
        assert_eq!(t.row_elems(), 2);
    }

    #[test]
    fn slice_rows_basic_and_padded() {
        let t = HostTensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let s = t.slice_rows(1, 3);
        assert_eq!(s.dims, vec![2, 2]);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
        let p = t.slice_rows_padded(2, 3, 4);
        assert_eq!(p.dims, vec![4, 2]);
        assert_eq!(p.data, vec![5., 6., 0., 0., 0., 0., 0., 0.]);
    }

    #[test]
    fn stack_concatenates_rows_and_checks_shapes() {
        let a = HostTensor::new(vec![1, 2], vec![1., 2.]).unwrap();
        let b = HostTensor::new(vec![2, 2], vec![3., 4., 5., 6.]).unwrap();
        let s = HostTensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.dims, vec![3, 2]);
        assert_eq!(s.data, vec![1., 2., 3., 4., 5., 6.]);
        // stack → slice round-trips each row
        assert_eq!(s.slice_rows(0, 1), a);
        assert_eq!(s.slice_rows(1, 3), b);
        // shape mismatches and empty stacks are rejected
        let c = HostTensor::new(vec![1, 3], vec![0.; 3]).unwrap();
        assert!(HostTensor::stack(&[a, c]).is_err());
        assert!(HostTensor::stack(&[]).is_err());
    }

    // PJRT-backed tests live in rust/qpart/tests/ (they need artifacts).
}
