//! The artifact bundle: manifest parsing + lazy artifact loading.
//!
//! Layout (produced by `python/compile/aot.py`, see DESIGN.md §7):
//!
//! ```text
//! artifacts/
//!   manifest.json
//!   calibration/<model>.json
//!   weights/<model>/l{i}_{w,b}.qt
//!   ae/<model>/p{b}_{we,be,wd,bd}.qt
//!   hlo/<arch>/{q,f32}_l{i}_b{B}.hlo.txt, full_b32.hlo.txt, ae_*_p{b}_b{B}.hlo.txt
//!   data/<dataset>_test_{x,y}.qt
//! ```

use crate::error::{Error, Result};
use qpart_core::accuracy::CalibrationTable;
use qpart_core::json::{parse, Value};
use qpart_core::model::ModelSpec;
use qpart_core::tensor::{load_i32, Tensor};
use std::path::{Path, PathBuf};

/// One model instance (arch + trained weights + calibration + dataset).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub arch: String,
    pub dataset: String,
    pub weights_dir: String,
    pub calibration: String,
    /// Full-precision test accuracy measured at build time.
    pub test_accuracy: f64,
    /// Autoencoder-baseline boundaries, if trained for this model.
    pub ae_boundaries: Vec<AeBoundary>,
    pub ae_dir: Option<String>,
}

/// One trained autoencoder (baseline) at a partition boundary.
#[derive(Debug, Clone, Copy)]
pub struct AeBoundary {
    pub boundary: usize,
    pub bottleneck: usize,
}

/// One lowered executable in the bundle.
#[derive(Debug, Clone)]
pub struct ExecEntry {
    pub name: String,
    pub hlo: String,
    pub arch: String,
    /// `qlayer`, `f32layer`, `full`, `ae_enc`, `ae_dec`.
    pub kind: String,
    /// 1-based layer for `qlayer`/`f32layer`.
    pub layer: Option<usize>,
    /// Boundary for `ae_enc`/`ae_dec`.
    pub boundary: Option<usize>,
    pub batch: usize,
    pub has_skip: bool,
}

/// One held-out evaluation dataset.
#[derive(Debug, Clone)]
pub struct DatasetEntry {
    pub name: String,
    pub x: String,
    pub y: String,
    pub n: usize,
    pub classes: usize,
}

/// Trained weights of one model (w/b per layer, natural shapes).
#[derive(Debug, Clone)]
pub struct ModelWeights {
    /// `(w, b)` per layer; conv `w` is `[C_in, k, k, C_out]`.
    pub layers: Vec<(Tensor, Tensor)>,
}

impl ModelWeights {
    /// Flattened (matmul-layout) weight view for layer `l` (1-based):
    /// linear `[D, G]` kept as-is, conv reshaped `[C_in·k·k, C_out]`
    /// (same memory order, so this is just a dims change).
    pub fn flat_w(&self, l: usize) -> Result<Tensor> {
        let (w, _) = &self.layers[l - 1];
        let dims = w.dims();
        match dims.len() {
            2 => Ok(w.clone()),
            4 => {
                let rows = dims[0] * dims[1] * dims[2];
                Ok(w.clone().reshape(vec![rows, dims[3]]).map_err(Error::Core)?)
            }
            other => Err(Error::Shape(format!("layer {l}: unexpected weight rank {other}"))),
        }
    }

    pub fn bias(&self, l: usize) -> &Tensor {
        &self.layers[l - 1].1
    }
}

/// The whole artifact bundle.
#[derive(Debug)]
pub struct Bundle {
    pub root: PathBuf,
    pub archs: Vec<ModelSpec>,
    pub models: Vec<ModelEntry>,
    pub executables: Vec<ExecEntry>,
    pub datasets: Vec<DatasetEntry>,
    /// Accuracy-degradation levels the calibration tables cover.
    pub levels: Vec<f64>,
}

impl Bundle {
    /// Load and validate `root/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Bundle> {
        let root = root.as_ref().to_path_buf();
        let text = std::fs::read_to_string(root.join("manifest.json")).map_err(|e| {
            Error::NotInBundle(format!("{}: {e} (run `make artifacts`)", root.display()))
        })?;
        let v = parse(&text).map_err(Error::Core)?;

        let mut archs = Vec::new();
        for a in v.req_arr("archs").map_err(Error::Core)? {
            archs.push(ModelSpec::from_json(a).map_err(Error::Core)?);
        }

        let mut models = Vec::new();
        for m in v.req_arr("models").map_err(Error::Core)? {
            let (ae_boundaries, ae_dir) = match m.get("ae") {
                Some(ae) if !ae.is_null() => {
                    let mut bs = Vec::new();
                    for b in ae.req_arr("boundaries").map_err(Error::Core)? {
                        bs.push(AeBoundary {
                            boundary: b.req_usize("boundary").map_err(Error::Core)?,
                            bottleneck: b.req_usize("bottleneck").map_err(Error::Core)?,
                        });
                    }
                    (bs, Some(ae.req_str("dir").map_err(Error::Core)?.to_string()))
                }
                _ => (Vec::new(), None),
            };
            models.push(ModelEntry {
                name: m.req_str("name").map_err(Error::Core)?.to_string(),
                arch: m.req_str("arch").map_err(Error::Core)?.to_string(),
                dataset: m.req_str("dataset").map_err(Error::Core)?.to_string(),
                weights_dir: m.req_str("weights_dir").map_err(Error::Core)?.to_string(),
                calibration: m.req_str("calibration").map_err(Error::Core)?.to_string(),
                test_accuracy: m.opt_f64("test_accuracy", f64::NAN),
                ae_boundaries,
                ae_dir,
            });
        }

        let mut executables = Vec::new();
        for e in v.req_arr("executables").map_err(Error::Core)? {
            executables.push(ExecEntry {
                name: e.req_str("name").map_err(Error::Core)?.to_string(),
                hlo: e.req_str("hlo").map_err(Error::Core)?.to_string(),
                arch: e.req_str("arch").map_err(Error::Core)?.to_string(),
                kind: e.req_str("kind").map_err(Error::Core)?.to_string(),
                layer: e.get("layer").and_then(Value::as_i64).map(|x| x as usize),
                boundary: e.get("boundary").and_then(Value::as_i64).map(|x| x as usize),
                batch: e.req_usize("batch").map_err(Error::Core)?,
                has_skip: e.opt_bool("has_skip", false),
            });
        }

        let mut datasets = Vec::new();
        for d in v.req_arr("datasets").map_err(Error::Core)? {
            datasets.push(DatasetEntry {
                name: d.req_str("name").map_err(Error::Core)?.to_string(),
                x: d.req_str("x").map_err(Error::Core)?.to_string(),
                y: d.req_str("y").map_err(Error::Core)?.to_string(),
                n: d.req_usize("n").map_err(Error::Core)?,
                classes: d.req_usize("classes").map_err(Error::Core)?,
            });
        }

        let levels = v.req_f64_arr("levels").map_err(Error::Core)?;
        let bundle = Bundle { root, archs, models, executables, datasets, levels };
        bundle.validate()?;
        Ok(bundle)
    }

    /// Cross-checks: every model's arch exists; every model's calibration
    /// file and weight files exist on disk; each arch has its executables.
    pub fn validate(&self) -> Result<()> {
        for m in &self.models {
            let arch = self.arch(&m.arch)?;
            for l in 1..=arch.num_layers() {
                let p = self.root.join(&m.weights_dir).join(format!("l{l}_w.qt"));
                if !p.exists() {
                    return Err(Error::NotInBundle(format!("{}", p.display())));
                }
            }
            if !self.root.join(&m.calibration).exists() {
                return Err(Error::NotInBundle(m.calibration.clone()));
            }
            if self.datasets.iter().all(|d| d.name != m.dataset) {
                return Err(Error::NotInBundle(format!("dataset {}", m.dataset)));
            }
        }
        for e in &self.executables {
            if !self.root.join(&e.hlo).exists() {
                return Err(Error::NotInBundle(e.hlo.clone()));
            }
        }
        Ok(())
    }

    pub fn arch(&self, name: &str) -> Result<&ModelSpec> {
        self.archs
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| Error::NotInBundle(format!("arch {name}")))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| Error::NotInBundle(format!("model {name}")))
    }

    pub fn dataset_entry(&self, name: &str) -> Result<&DatasetEntry> {
        self.datasets
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| Error::NotInBundle(format!("dataset {name}")))
    }

    /// Find a layer/full/AE executable.
    pub fn find_exec(
        &self,
        arch: &str,
        kind: &str,
        layer_or_boundary: Option<usize>,
        batch: usize,
    ) -> Result<&ExecEntry> {
        self.executables
            .iter()
            .find(|e| {
                e.arch == arch
                    && e.kind == kind
                    && e.batch == batch
                    && match kind {
                        "qlayer" | "f32layer" => e.layer == layer_or_boundary,
                        "ae_enc" | "ae_dec" => e.boundary == layer_or_boundary,
                        _ => true,
                    }
            })
            .ok_or_else(|| {
                Error::MissingExec(format!("{arch}/{kind}/{layer_or_boundary:?}/b{batch}"))
            })
    }

    /// Load a model's calibration table.
    pub fn calibration(&self, model: &str) -> Result<CalibrationTable> {
        let m = self.model(model)?;
        let text = std::fs::read_to_string(self.root.join(&m.calibration))?;
        let v = parse(&text).map_err(Error::Core)?;
        let mut table = CalibrationTable::from_json(&v).map_err(Error::Core)?;
        // calibration.json is keyed by arch name; re-key to the instance
        table.model = self.arch(&m.arch)?.name.clone();
        Ok(table)
    }

    /// Load a model's trained weights.
    pub fn weights(&self, model: &str) -> Result<ModelWeights> {
        let m = self.model(model)?;
        let arch = self.arch(&m.arch)?;
        let dir = self.root.join(&m.weights_dir);
        let mut layers = Vec::with_capacity(arch.num_layers());
        for l in 1..=arch.num_layers() {
            let w = Tensor::load(dir.join(format!("l{l}_w.qt"))).map_err(Error::Core)?;
            let b = Tensor::load(dir.join(format!("l{l}_b.qt"))).map_err(Error::Core)?;
            layers.push((w, b));
        }
        Ok(ModelWeights { layers })
    }

    /// Load autoencoder params at `boundary`: (we, be, wd, bd).
    pub fn ae_params(&self, model: &str, boundary: usize) -> Result<[Tensor; 4]> {
        let m = self.model(model)?;
        let dir = m
            .ae_dir
            .as_ref()
            .ok_or_else(|| Error::NotInBundle(format!("model {model} has no AE baseline")))?;
        let dir = self.root.join(dir);
        let load = |k: &str| Tensor::load(dir.join(format!("p{boundary}_{k}.qt")));
        Ok([
            load("we").map_err(Error::Core)?,
            load("be").map_err(Error::Core)?,
            load("wd").map_err(Error::Core)?,
            load("bd").map_err(Error::Core)?,
        ])
    }

    /// Load a held-out dataset: (x, labels).
    pub fn dataset(&self, name: &str) -> Result<(Tensor, Vec<i32>)> {
        let d = self.dataset_entry(name)?;
        let x = Tensor::load(self.root.join(&d.x)).map_err(Error::Core)?;
        let (dims, y) = load_i32(self.root.join(&d.y)).map_err(Error::Core)?;
        if dims.iter().product::<usize>() != x.dims()[0] {
            return Err(Error::Shape(format!(
                "dataset {name}: {} labels for {} samples",
                dims.iter().product::<usize>(),
                x.dims()[0]
            )));
        }
        Ok((x, y))
    }
}

#[cfg(test)]
mod tests {
    // Bundle tests that need real artifacts live in rust/qpart/tests/.
    use super::*;

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Bundle::load("/nonexistent/path").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn flat_w_reshapes_conv() {
        let w = Tensor::zeros(vec![3, 3, 3, 8]);
        let b = Tensor::zeros(vec![8]);
        let mw = ModelWeights { layers: vec![(w, b)] };
        let flat = mw.flat_w(1).unwrap();
        assert_eq!(flat.dims(), &[27, 8]);
        let w2 = Tensor::zeros(vec![16, 4]);
        let mw2 = ModelWeights { layers: vec![(w2, Tensor::zeros(vec![4]))] };
        assert_eq!(mw2.flat_w(1).unwrap().dims(), &[16, 4]);
    }
}
