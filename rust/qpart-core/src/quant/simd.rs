//! Runtime-dispatched SIMD kernels for the encode hot path.
//!
//! PR 4 took `pack_bits`/`unpack_bits`/`quantize_packed` word-wise; this
//! module adds the next rung: `core::arch` vector kernels (AVX2 and SSE2 on
//! x86/x86_64, NEON on aarch64) selected **once per process** by
//! [`active`] and picked up transparently by the public entry points in
//! [`crate::quant`]. The word-wise kernels stay exactly where PR 4 left
//! them — as the property-test oracle every SIMD path must match
//! **byte-for-byte** for all widths 1..=24, and as the runtime fallback on
//! hardware without vector units.
//!
//! ## Dispatch
//!
//! [`active`] caches its answer in a `OnceLock`:
//!
//! * `QPART_SIMD=off|scalar|wordwise|0|false` forces the word-wise
//!   fallback (the forced-scalar CI job runs the whole coordinator suite
//!   this way);
//! * `QPART_SIMD=avx2|sse2|neon` requests a specific tier, honored only
//!   when the CPU supports it (requesting an unsupported tier falls back
//!   to detection — a mode that cannot execute is never returned);
//! * unset/anything else: runtime detection
//!   (`is_x86_feature_detected!("avx2")` → AVX2, else SSE2 on x86;
//!   NEON is baseline on aarch64).
//!
//! ## Byte-identity
//!
//! The scalar Eq. 10 kernel is `((x - min) * inv + 0.5) as u32` followed by
//! `.min(max_code)`; the saturating float→int cast maps NaN→0, negatives→0.
//! The vector kernels replicate that exactly:
//!
//! * the float expression uses separate sub/mul/add (never FMA), so each
//!   lane computes bit-identical IEEE intermediates;
//! * `max_ps(t, 0)` returns its **second** operand when `t` is NaN, so
//!   NaN→0 like the saturating cast, and negatives clamp to 0;
//! * the top clamp moves into the float domain — `min_ps(t, max_code as
//!   f32)` — which is exact because `max_code ≤ 2^24 − 1` is representable
//!   in f32, leaving `cvttps` (truncate toward zero) on an in-range value,
//!   the same truncation the scalar cast performs. (On aarch64, `FCVTZU`
//!   is itself a saturating NaN→0 truncation — the instruction Rust's
//!   `as u32` lowers to — so NEON needs no float-domain clamp at 0.)
//!
//! The bit-packing accumulator is inherently serial, so all quantize
//! kernels stream their vector-computed codes through the *same*
//! [`WordPacker`] the word-wise path uses: the emitted bytes cannot
//! diverge. `pack_bits`/`unpack_bits` gain full-vector narrow/widen loops
//! at the byte-aligned widths (8 and 16 bits) plus a vectorized
//! validation scan at every width; other widths keep the word-wise emit
//! loop after the vector scan.

use std::sync::OnceLock;

use crate::error::{Error, Result};
use crate::quant::bitpack::{
    check_bits, pack_bits_wordwise, packed_len_bytes, unpack_bits_wordwise, WordPacker,
};
use crate::quant::quantizer::{scan_range, PackedQuantized, QuantParams};

/// Which kernel tier the process dispatches to. Decided once by
/// [`active`]; every tier other than [`SimdMode::Wordwise`] is guaranteed
/// executable on the running CPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdMode {
    /// The PR 4 word-wise scalar kernels — oracle and universal fallback.
    Wordwise,
    /// 4-lane SSE2 quantize kernel (x86/x86_64 without AVX2).
    Sse2,
    /// 8-lane AVX2 quantize kernel + byte-aligned pack/unpack kernels.
    Avx2,
    /// 4-lane NEON quantize kernel (aarch64 baseline).
    Neon,
}

impl SimdMode {
    /// Stable lowercase label (used by `perf_quant` rows and bench-serve).
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Wordwise => "wordwise",
            SimdMode::Sse2 => "sse2",
            SimdMode::Avx2 => "avx2",
            SimdMode::Neon => "neon",
        }
    }

    /// True for every tier that runs vector instructions.
    pub fn is_simd(self) -> bool {
        self != SimdMode::Wordwise
    }
}

/// Best tier the running CPU supports, ignoring the env override.
pub fn detected() -> SimdMode {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdMode::Avx2;
        }
        if is_x86_feature_detected!("sse2") {
            return SimdMode::Sse2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdMode::Neon;
    }
    #[allow(unreachable_code)]
    SimdMode::Wordwise
}

/// Resolve an override string against what the CPU supports. A requested
/// tier the hardware lacks falls back to detection (never to a mode that
/// would fault).
fn parse(raw: Option<&str>, detected: SimdMode) -> SimdMode {
    let Some(raw) = raw else { return detected };
    match raw.trim().to_ascii_lowercase().as_str() {
        "off" | "scalar" | "wordwise" | "0" | "false" => SimdMode::Wordwise,
        "sse2" if matches!(detected, SimdMode::Sse2 | SimdMode::Avx2) => SimdMode::Sse2,
        "avx2" if detected == SimdMode::Avx2 => SimdMode::Avx2,
        "neon" if detected == SimdMode::Neon => SimdMode::Neon,
        _ => detected,
    }
}

/// The tier the public `quant` entry points dispatch to, resolved once per
/// process from the `QPART_SIMD` env var (see module docs) and CPU
/// detection.
pub fn active() -> SimdMode {
    static MODE: OnceLock<SimdMode> = OnceLock::new();
    *MODE.get_or_init(|| parse(std::env::var("QPART_SIMD").ok().as_deref(), detected()))
}

/// SIMD `pack_bits`: vectorized validation scan at every width, vector
/// narrowing at the byte-aligned widths (8/16), word-wise emit elsewhere.
/// Byte-identical to [`pack_bits_wordwise`] / `pack_bits_scalar`; always
/// runs the best *detected* tier regardless of `QPART_SIMD` (it is the
/// explicit-SIMD surface the property tests and `perf_quant` call).
pub fn pack_bits_simd(codes: &[u32], bits: u8) -> Result<Vec<u8>> {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    if detected() == SimdMode::Avx2 {
        check_bits("pack_bits", bits)?;
        let limit = 1u64 << bits;
        // SAFETY: AVX2 presence verified by `detected()` above.
        if let Some(bad) = unsafe { x86::find_oversized_avx2(codes, limit) } {
            return Err(Error::InvalidArg(format!("code {bad} does not fit in {bits} bits")));
        }
        let mut out = vec![0u8; packed_len_bytes(codes.len(), bits)];
        match bits {
            // SAFETY: AVX2 verified; codes validated < 2^bits above.
            8 => unsafe { x86::pack8_avx2(codes, &mut out) },
            16 => unsafe { x86::pack16_avx2(codes, &mut out) },
            _ => {
                let mut packer = WordPacker::new(&mut out);
                for &c in codes {
                    packer.push(c, bits as u32);
                }
                packer.finish();
            }
        }
        return Ok(out);
    }
    pack_bits_wordwise(codes, bits)
}

/// SIMD `unpack_bits`: vector widening at the byte-aligned widths (8/16),
/// word-wise refill elsewhere. Code-identical to [`unpack_bits_wordwise`];
/// always runs the best *detected* tier regardless of `QPART_SIMD`.
pub fn unpack_bits_simd(buf: &[u8], n: usize, bits: u8) -> Result<Vec<u32>> {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    if detected() == SimdMode::Avx2 && (bits == 8 || bits == 16) {
        check_bits("unpack_bits", bits)?;
        let need = packed_len_bytes(n, bits);
        if buf.len() < need {
            return Err(Error::InvalidArg(format!(
                "unpack_bits: buffer has {} bytes, need {need}",
                buf.len()
            )));
        }
        let mut out = Vec::with_capacity(n);
        match bits {
            // SAFETY: AVX2 verified; buffer length validated above.
            8 => unsafe { x86::unpack8_avx2(buf, n, &mut out) },
            _ => unsafe { x86::unpack16_avx2(buf, n, &mut out) },
        }
        return Ok(out);
    }
    unpack_bits_wordwise(buf, n, bits)
}

/// SIMD fused quantize→pack with explicit parameters: the vector analogue
/// of `quantize_packed_with_wordwise`, byte-identical to it (the lanes
/// feed the same [`WordPacker`]). Always runs the best *detected* tier.
pub fn quantize_packed_with_simd(data: &[f32], params: QuantParams) -> PackedQuantized {
    let step = params.step();
    let inv = 1.0 / step;
    let min = params.min;
    let max_code = params.levels() - 1;
    let bits = params.bits as u32;
    let mut packed = vec![0u8; packed_len_bytes(data.len(), params.bits)];
    {
        let mut packer = WordPacker::new(&mut packed);
        quantize_into(data, min, inv, max_code, bits, &mut packer, detected());
        packer.finish();
    }
    PackedQuantized { params, len: data.len(), packed }
}

/// SIMD fused quantize→pack with data-derived range (the vector analogue
/// of `quantize_packed`). Always runs the best *detected* tier.
pub fn quantize_packed_simd(data: &[f32], bits: u8) -> Result<PackedQuantized> {
    let (mn, mx) = scan_range(data)?;
    let params = QuantParams::from_range(bits, mn, mx)?;
    Ok(quantize_packed_with_simd(data, params))
}

/// Quantize `data` into `packer` using `mode`'s widest supported kernel.
/// `mode` must come from [`detected`]/[`active`] so the tier is executable.
fn quantize_into(
    data: &[f32],
    min: f32,
    inv: f32,
    max_code: u32,
    bits: u32,
    packer: &mut WordPacker,
    mode: SimdMode,
) {
    match mode {
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: tier verified executable by detection (fn contract).
        SimdMode::Avx2 => unsafe { x86::quantize_pack_avx2(data, min, inv, max_code, bits, packer) },
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        // SAFETY: as above.
        SimdMode::Sse2 => unsafe { x86::quantize_pack_sse2(data, min, inv, max_code, bits, packer) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdMode::Neon => unsafe { neon::quantize_pack_neon(data, min, inv, max_code, bits, packer) },
        _ => {
            for &x in data {
                let q = (((x - min) * inv + 0.5) as u32).min(max_code);
                packer.push(q, bits);
            }
        }
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
mod x86 {
    #[cfg(target_arch = "x86")]
    use core::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use core::arch::x86_64::*;

    use crate::quant::bitpack::WordPacker;

    /// 8 Eq. 10 codes per iteration. sub/mul/add (no FMA) matches the
    /// scalar intermediates bit-for-bit; `max_ps(t, 0)` yields 0 for NaN
    /// lanes (maxps returns its second operand on NaN) and clamps
    /// negatives; `min_ps` against `max_code as f32` is exact because
    /// `max_code < 2^24`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn quantize_pack_avx2(
        data: &[f32],
        min: f32,
        inv: f32,
        max_code: u32,
        bits: u32,
        packer: &mut WordPacker,
    ) {
        let minv = _mm256_set1_ps(min);
        let invv = _mm256_set1_ps(inv);
        let half = _mm256_set1_ps(0.5);
        let zero = _mm256_setzero_ps();
        let top = _mm256_set1_ps(max_code as f32);
        let mut codes = [0u32; 8];
        let mut chunks = data.chunks_exact(8);
        for c in chunks.by_ref() {
            let x = _mm256_loadu_ps(c.as_ptr());
            let t = _mm256_add_ps(_mm256_mul_ps(_mm256_sub_ps(x, minv), invv), half);
            let t = _mm256_min_ps(_mm256_max_ps(t, zero), top);
            let q = _mm256_cvttps_epi32(t);
            _mm256_storeu_si256(codes.as_mut_ptr() as *mut __m256i, q);
            for &code in &codes {
                packer.push(code, bits);
            }
        }
        for &x in chunks.remainder() {
            let q = (((x - min) * inv + 0.5) as u32).min(max_code);
            packer.push(q, bits);
        }
    }

    /// 4-lane SSE2 variant of [`quantize_pack_avx2`] — same byte-identity
    /// argument, half the width, for pre-AVX2 x86.
    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn quantize_pack_sse2(
        data: &[f32],
        min: f32,
        inv: f32,
        max_code: u32,
        bits: u32,
        packer: &mut WordPacker,
    ) {
        let minv = _mm_set1_ps(min);
        let invv = _mm_set1_ps(inv);
        let half = _mm_set1_ps(0.5);
        let zero = _mm_setzero_ps();
        let top = _mm_set1_ps(max_code as f32);
        let mut codes = [0u32; 4];
        let mut chunks = data.chunks_exact(4);
        for c in chunks.by_ref() {
            let x = _mm_loadu_ps(c.as_ptr());
            let t = _mm_add_ps(_mm_mul_ps(_mm_sub_ps(x, minv), invv), half);
            let t = _mm_min_ps(_mm_max_ps(t, zero), top);
            let q = _mm_cvttps_epi32(t);
            _mm_storeu_si128(codes.as_mut_ptr() as *mut __m128i, q);
            for &code in &codes {
                packer.push(code, bits);
            }
        }
        for &x in chunks.remainder() {
            let q = (((x - min) * inv + 0.5) as u32).min(max_code);
            packer.push(q, bits);
        }
    }

    /// Vectorized `pack_bits` validation: 8 codes per compare. On a hit,
    /// rescan the offending block scalar so the reported code is the
    /// *first* oversized one, exactly like the word-wise/scalar paths.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn find_oversized_avx2(codes: &[u32], limit: u64) -> Option<u32> {
        let lm1 = (limit - 1) as u32; // limit ≤ 2^24, fits u32
        let top = _mm256_set1_epi32(lm1 as i32);
        let mut chunks = codes.chunks_exact(8);
        for c in chunks.by_ref() {
            let v = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
            // v ≤ lm1 (unsigned) ⇔ max_epu32(v, lm1) == lm1
            let ok = _mm256_cmpeq_epi32(_mm256_max_epu32(v, top), top);
            if _mm256_movemask_epi8(ok) != -1 {
                return c.iter().find(|&&x| (x as u64) >= limit).copied();
            }
        }
        chunks.remainder().iter().find(|&&x| (x as u64) >= limit).copied()
    }

    /// bits=8 pack: narrow 32 validated u32 codes → 32 bytes per
    /// iteration (two packus stages + a lane-fixing permute).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pack8_avx2(codes: &[u32], out: &mut [u8]) {
        let mut pos = 0usize;
        let mut chunks = codes.chunks_exact(32);
        for c in chunks.by_ref() {
            let a = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
            let b = _mm256_loadu_si256(c.as_ptr().add(8) as *const __m256i);
            let cc = _mm256_loadu_si256(c.as_ptr().add(16) as *const __m256i);
            let d = _mm256_loadu_si256(c.as_ptr().add(24) as *const __m256i);
            // per-lane u32→u16, then u16→u8 (no saturation: codes < 256)
            let ab = _mm256_packus_epi32(a, b);
            let cd = _mm256_packus_epi32(cc, d);
            let abcd = _mm256_packus_epi16(ab, cd);
            // dwords now [a0-3 b0-3 c0-3 d0-3 | a4-7 b4-7 c4-7 d4-7]
            let idx = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
            let fixed = _mm256_permutevar8x32_epi32(abcd, idx);
            _mm256_storeu_si256(out.as_mut_ptr().add(pos) as *mut __m256i, fixed);
            pos += 32;
        }
        for (&code, o) in chunks.remainder().iter().zip(out[pos..].iter_mut()) {
            *o = code as u8;
        }
    }

    /// bits=16 pack: narrow 16 validated u32 codes → 32 LE bytes per
    /// iteration.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn pack16_avx2(codes: &[u32], out: &mut [u8]) {
        let mut pos = 0usize;
        let mut chunks = codes.chunks_exact(16);
        for c in chunks.by_ref() {
            let a = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
            let b = _mm256_loadu_si256(c.as_ptr().add(8) as *const __m256i);
            // per-lane u32→u16 (codes < 2^16, no saturation), then fix the
            // qword order [a0-3, b0-3, a4-7, b4-7] → [a0-3, a4-7, b0-3, b4-7]
            let ab = _mm256_packus_epi32(a, b);
            let fixed = _mm256_permute4x64_epi64::<0b1101_1000>(ab);
            _mm256_storeu_si256(out.as_mut_ptr().add(pos) as *mut __m256i, fixed);
            pos += 32;
        }
        for (&code, o) in chunks.remainder().iter().zip(out[pos..].chunks_exact_mut(2)) {
            o.copy_from_slice(&(code as u16).to_le_bytes());
        }
    }

    /// bits=8 unpack: widen 8 bytes → 8 u32 per iteration.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn unpack8_avx2(buf: &[u8], n: usize, out: &mut Vec<u32>) {
        let mut tmp = [0u32; 8];
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm_loadl_epi64(buf.as_ptr().add(i) as *const __m128i);
            let w = _mm256_cvtepu8_epi32(v);
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, w);
            out.extend_from_slice(&tmp);
            i += 8;
        }
        for &b in &buf[i..n] {
            out.push(b as u32);
        }
    }

    /// bits=16 unpack: widen 8 LE u16 → 8 u32 per iteration.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn unpack16_avx2(buf: &[u8], n: usize, out: &mut Vec<u32>) {
        let mut tmp = [0u32; 8];
        let mut i = 0usize;
        while i + 8 <= n {
            let v = _mm_loadu_si128(buf.as_ptr().add(i * 2) as *const __m128i);
            let w = _mm256_cvtepu16_epi32(v);
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, w);
            out.extend_from_slice(&tmp);
            i += 8;
        }
        for c in buf[i * 2..n * 2].chunks_exact(2) {
            out.push(u16::from_le_bytes([c[0], c[1]]) as u32);
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use core::arch::aarch64::*;

    use crate::quant::bitpack::WordPacker;

    /// 4 Eq. 10 codes per iteration. `vcvtq_u32_f32` lowers to FCVTZU —
    /// the saturating truncate-toward-zero with NaN→0 that Rust's
    /// `as u32` cast uses on aarch64 — so no float-domain clamp at 0 is
    /// needed; the top clamp stays in the integer domain like the scalar.
    pub(super) unsafe fn quantize_pack_neon(
        data: &[f32],
        min: f32,
        inv: f32,
        max_code: u32,
        bits: u32,
        packer: &mut WordPacker,
    ) {
        let minv = vdupq_n_f32(min);
        let invv = vdupq_n_f32(inv);
        let half = vdupq_n_f32(0.5);
        let top = vdupq_n_u32(max_code);
        let mut codes = [0u32; 4];
        let mut chunks = data.chunks_exact(4);
        for c in chunks.by_ref() {
            let x = vld1q_f32(c.as_ptr());
            let t = vaddq_f32(vmulq_f32(vsubq_f32(x, minv), invv), half);
            let q = vminq_u32(vcvtq_u32_f32(t), top);
            vst1q_u32(codes.as_mut_ptr(), q);
            for &code in &codes {
                packer.push(code, bits);
            }
        }
        for &x in chunks.remainder() {
            let q = (((x - min) * inv + 0.5) as u32).min(max_code);
            packer.push(q, bits);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitpack::{pack_bits_scalar, unpack_bits_scalar};
    use crate::quant::quantizer::quantize_packed_with_wordwise;
    use crate::testing::{check, vec_f32};

    #[test]
    fn parse_honors_overrides_and_hardware() {
        let det = SimdMode::Avx2;
        for off in ["off", "scalar", "wordwise", "0", "false", " OFF "] {
            assert_eq!(parse(Some(off), det), SimdMode::Wordwise, "{off}");
        }
        assert_eq!(parse(Some("avx2"), det), SimdMode::Avx2);
        assert_eq!(parse(Some("sse2"), det), SimdMode::Sse2);
        // a tier the CPU lacks falls back to detection, never faults
        assert_eq!(parse(Some("avx2"), SimdMode::Sse2), SimdMode::Sse2);
        assert_eq!(parse(Some("neon"), SimdMode::Sse2), SimdMode::Sse2);
        assert_eq!(parse(Some("garbage"), det), det);
        assert_eq!(parse(None, det), det);
    }

    #[test]
    fn active_is_executable() {
        // whatever the env says, active() must be runnable here: exercise
        // the dispatched public entry points end to end
        let m = active();
        assert!(!m.name().is_empty());
        let codes: Vec<u32> = (0..777u32).map(|i| i % 251).collect();
        let packed = crate::quant::pack_bits(&codes, 8).unwrap();
        assert_eq!(packed, pack_bits_scalar(&codes, 8).unwrap());
        assert_eq!(crate::quant::unpack_bits(&packed, codes.len(), 8).unwrap(), codes);
    }

    #[test]
    fn prop_simd_pack_unpack_matches_oracles_all_widths() {
        // SIMD ≡ word-wise ≡ scalar, widths 1..=24, odd/unaligned lengths
        check("simd pack/unpack ≡ oracles", 160, |rng| {
            let bits = rng.range_usize(1, 25) as u8;
            let n = rng.range_usize(0, 700);
            let limit = 1u64 << bits;
            let codes: Vec<u32> = (0..n).map(|_| rng.below(limit) as u32).collect();
            let simd = pack_bits_simd(&codes, bits).unwrap();
            assert_eq!(simd, pack_bits_scalar(&codes, bits).unwrap(), "bits={bits} n={n}");
            assert_eq!(simd, pack_bits_wordwise(&codes, bits).unwrap(), "bits={bits} n={n}");
            let back = unpack_bits_simd(&simd, n, bits).unwrap();
            assert_eq!(back, codes, "bits={bits} n={n}");
            assert_eq!(back, unpack_bits_scalar(&simd, n, bits).unwrap());
        });
    }

    #[test]
    fn simd_pack_unpack_dense_sweep_with_unaligned_slices() {
        // deterministic seams: every width × lengths around the vector
        // block sizes (8/16/32) and the u64 flush boundary, plus inputs
        // deliberately offset one element/byte so loadu paths see
        // unaligned addresses
        for bits in 1u8..=24 {
            let limit = 1u64 << bits;
            let base: Vec<u32> =
                (0..101u64).map(|i| ((i * 2_654_435_761) % limit) as u32).collect();
            for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100] {
                let codes = &base[1..1 + n]; // misaligned start
                let simd = pack_bits_simd(codes, bits).unwrap();
                assert_eq!(simd, pack_bits_scalar(codes, bits).unwrap(), "bits={bits} n={n}");
                // unpack from a buffer whose start is odd too
                let mut shifted = vec![0xA5u8];
                shifted.extend_from_slice(&simd);
                assert_eq!(
                    unpack_bits_simd(&shifted[1..], n, bits).unwrap(),
                    codes,
                    "bits={bits} n={n}"
                );
            }
        }
    }

    #[test]
    fn simd_pack_validates_like_the_oracles() {
        // first oversized code reported, wherever it sits relative to the
        // vector blocks
        for pos in [0usize, 3, 7, 8, 9, 30, 31, 32, 40] {
            let mut codes = vec![1u32; 41];
            codes[pos] = 256;
            let simd = pack_bits_simd(&codes, 8).unwrap_err().to_string();
            let scalar = pack_bits_scalar(&codes, 8).unwrap_err().to_string();
            assert_eq!(simd, scalar, "pos={pos}");
        }
        assert!(pack_bits_simd(&[0], 0).is_err());
        assert!(pack_bits_simd(&[0], 25).is_err());
        assert!(unpack_bits_simd(&[0u8; 2], 3, 8).is_err());
    }

    #[test]
    fn prop_simd_quantize_packed_matches_wordwise() {
        check("simd quantize_packed ≡ wordwise", 120, |rng| {
            let len = rng.range_usize(0, 500);
            let lo = rng.range_f64(-50.0, 0.0) as f32;
            let hi = lo + rng.range_f64(0.001, 100.0) as f32;
            let data = vec_f32(rng, len, lo, hi);
            let bits = rng.range_usize(1, 25) as u8;
            let simd = quantize_packed_simd(&data, bits).unwrap();
            let word = crate::quant::quantizer::quantize_packed_wordwise(&data, bits).unwrap();
            assert_eq!(simd, word, "bits={bits} len={len}");
        });
    }

    #[test]
    fn simd_quantize_saturation_matches_scalar_exactly() {
        // explicit params admit values outside [min, max]: NaN, ±inf, and
        // huge magnitudes must hit the same saturating-cast clamps as the
        // scalar kernel, lane-for-lane, at every width
        let data = [
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            -1e30,
            1e30,
            -0.0,
            0.0,
            2.5e9, // > i32::MAX but < u32::MAX as f32
            0.4999,
            0.5001,
            -3.7,
            1.0,
            7.3,
            42.0,
            -42.0,
            1e-20,
            123.456,
        ];
        for bits in 1u8..=24 {
            let params = QuantParams::from_range(bits, 0.0, 8.0).unwrap();
            let simd = quantize_packed_with_simd(&data, params);
            let word = quantize_packed_with_wordwise(&data, params);
            assert_eq!(simd, word, "bits={bits}");
        }
    }
}
