"""End-to-end AOT build test (fast mode, mlp6 only) + manifest schema."""

import json
import os

import numpy as np
import pytest

from compile import aot, qt


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, fast=True, only={"mlp6"}, log=lambda *_: None)
    return out, manifest


def test_manifest_written(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["version"] == 1
    assert on_disk["models"][0]["name"] == "mlp6"
    assert manifest["models"][0]["test_accuracy"] > 0.1


def test_arch_schema_matches_rust(built):
    _, manifest = built
    arch = manifest["archs"][0]
    assert arch["name"] == "mlp6"
    assert arch["num_classes"] == 10
    assert len(arch["layers"]) == 6
    assert arch["partition_points"] == list(range(7))
    for layer in arch["layers"]:
        assert layer["kind"] == "linear"
        assert {"name", "relu", "d_in", "d_out"} <= set(layer)


def test_all_referenced_files_exist(built):
    out, manifest = built
    for e in manifest["executables"]:
        assert os.path.exists(os.path.join(out, e["hlo"])), e["hlo"]
    for m in manifest["models"]:
        assert os.path.exists(os.path.join(out, m["calibration"]))
        for i in range(1, 7):
            assert os.path.exists(os.path.join(out, m["weights_dir"], f"l{i}_w.qt"))
    for d in manifest["datasets"]:
        assert os.path.exists(os.path.join(out, d["x"]))
        assert os.path.exists(os.path.join(out, d["y"]))


def test_executable_inventory(built):
    _, manifest = built
    kinds = {}
    for e in manifest["executables"]:
        kinds.setdefault(e["kind"], 0)
        kinds[e["kind"]] += 1
    # 6 layers × 2 batches for each layer kind; 1 full; 5 AE boundaries × 2 batches
    assert kinds["qlayer"] == 12
    assert kinds["f32layer"] == 12
    assert kinds["full"] == 1
    assert kinds["ae_enc"] == 10
    assert kinds["ae_dec"] == 10


def test_weights_roundtrip_consistent(built):
    out, manifest = built
    m = manifest["models"][0]
    w1 = qt.load(os.path.join(out, m["weights_dir"], "l1_w.qt"))
    assert w1.shape == (784, 512)
    assert np.isfinite(w1).all()
    y = qt.load(os.path.join(out, manifest["datasets"][0]["y"]))
    assert y.dtype == np.int32


def test_calibration_schema(built):
    out, manifest = built
    with open(os.path.join(out, manifest["models"][0]["calibration"])) as f:
        cal = json.load(f)
    assert cal["levels"] == list(aot.C.DEFAULT_LEVELS)
    assert len(cal["weight"]) == 6
    assert len(cal["activation"]) == 7
    assert cal["adversarial_energy"] > 0


def test_hlo_files_look_like_hlo(built):
    out, manifest = built
    path = os.path.join(out, manifest["executables"][0]["hlo"])
    text = open(path).read()
    assert "HloModule" in text
    assert "ENTRY" in text
