//! Crate-wide error type (hand-rolled `Display`/`Error` impls — this build
//! is fully offline, so `thiserror` is not available).

use std::fmt;

/// Errors produced by qpart-core.
#[derive(Debug)]
pub enum Error {
    /// JSON syntax or structure error, with byte offset where available.
    Json { offset: usize, msg: String },

    /// A JSON document was valid but missing a required field / wrong type.
    Schema { path: String, msg: String },

    /// Tensor-file (.qt) format violation.
    TensorFormat(String),

    /// Shape mismatch in tensor or model operations.
    Shape(String),

    /// Invalid argument to a public API.
    InvalidArg(String),

    /// Optimization problem is infeasible for the given constraints
    /// (e.g. accuracy budget unreachable even at the maximum bit-width).
    Infeasible(String),

    /// Referenced model / layer / pattern does not exist.
    NotFound(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

/// Convenience alias used across qpart crates.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Helper for schema errors.
    pub fn schema(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Error::Schema { path: path.into(), msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Json { offset, msg } => write!(f, "json error at offset {offset}: {msg}"),
            Error::Schema { path, msg } => write!(f, "schema error at {path}: {msg}"),
            Error::TensorFormat(m) => write!(f, "tensor format error: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Infeasible(m) => write!(f, "infeasible: {m}"),
            Error::NotFound(m) => write!(f, "not found: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
