//! `qpart` — launcher for the QPART serving stack.
//!
//! ```text
//! qpart serve       [--config cfg.json] [--set k=v ...] [--listen addr] [--artifacts dir]
//!                   [--workers N] [--queue N] [--sessions N] [--session-ttl SECS]
//!                   [--batch-window MS] [--batch-max N] [--cache-bytes N]
//!                   [--binary-frames true|false] [--warm off|paper|log]
//!                   [--store-dir dir] [--host-fallback]
//!                   [--frontend reactor|threaded] [--max-conns N]
//!                   [--conn-idle-secs S] [--fair-rate R] [--metrics-listen addr]
//!                   [--trace-sample P] [--trace-slow-ms MS] [--trace-keep N]
//!                   [--trace-store N] [--record-trace file]
//! qpart request     --model mlp6 [--accuracy 0.01] [--n 16] [--addr host:port]
//!                   [--capacity-bps 2e8] [--clock-hz 2e8] [--artifacts dir] [--binary]
//! qpart bench-serve [--clients 8] [--requests 32] [--workers 4] [--keys 3]
//!                   [--batch-window 2] [--cache-bytes N] [--binary-frames true|false]
//!                   [--phase2 B] [--warm-cache B] [--store-dir dir]
//!                   [--sweep workers=1,2,4,8] [--csv]
//!                   [--frontend reactor|threaded] [--min-peak-conns N]
//!                   [--fair-rate R] [--artifacts dir]
//!                   [--scenario flashcrowd|file] [--time-scale S]
//!                   [--chaos drop-mid-phase2,garbage-frames,slow-loris,half-open]
//!                   [--chaos-rate P] [--trace-out file] [--scrape-check]
//! qpart sim         [--model mlp6] [--rate 20] [--devices 16] [--duration 10] [--seed 1]
//! qpart offline     [--model mlp6] [--artifacts dir]
//! qpart models      [--artifacts dir]
//! ```
//!
//! `serve` starts the coordinator; `request` plays an edge device over the
//! two-phase protocol (real PJRT execution on both sides); `bench-serve`
//! load-tests the serving dataplane AND the batched phase-2 execution
//! plane (in-process server, multi-client two-phase driver, no PJRT
//! needed — synthetic bundle + host reference kernels unless
//! `--artifacts` is given), with `--sweep workers=...` producing scaling
//! curves and `--csv` the same CSV rows the qpart-bench harness emits;
//! with `--scenario` it instead replays a declarative multi-phase fleet
//! scenario (flash crowds, diurnal cycles, upload storms) through the
//! live server, optionally alongside `--chaos` misbehaving peers, and
//! reports per-class latency plus Jain's fairness index;
//! `sim` runs the discrete-event fleet simulation; `offline` prints the
//! Algorithm-1 pattern table; `models` lists the bundle.

mod args;

use args::Args;
use qpart::coordinator::client::{paper_request, random_input};
use qpart::coordinator::testing::{synthetic_upload, BlockingConn};
use qpart::prelude::*;
use qpart::proto::messages::{ActivationUpload, HelloRequest, InferReply, Request, Response};
use qpart::sim::{Scenario, Trace, TraceEvent};
use std::collections::{BTreeMap, HashMap};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(raw) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(raw: Vec<String>) -> Result<(), String> {
    let args = Args::parse(raw)?;
    match args.positional.first().map(String::as_str) {
        Some("serve") => cmd_serve(&args),
        Some("request") => cmd_request(&args),
        Some("bench-serve") => cmd_bench_serve(&args),
        Some("sim") => cmd_sim(&args),
        Some("offline") => cmd_offline(&args),
        Some("models") => cmd_models(&args),
        Some(other) => Err(format!("unknown command '{other}'\n{USAGE}")),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "usage: qpart <serve|request|bench-serve|sim|offline|models> [flags]\n\
  serve    --listen 127.0.0.1:7878 --artifacts artifacts [--config f] [--set k=v]\n\
           [--workers N]        executor-pool size: N inference threads, each owning\n\
                                its own PJRT executor (default: serving.workers = 4;\n\
                                mirrors the simulator's server_slots)\n\
           [--queue N]          admission control: bounded job-queue depth; requests\n\
                                beyond it are shed with an 'overloaded' error\n\
                                (default: serving.queue_capacity = 1024)\n\
           [--sessions N]       two-phase session-table capacity, sharded across\n\
                                workers; oldest evicted first (default: 4096)\n\
           [--session-ttl S]    expire sessions older than S seconds (0 = never;\n\
                                default: serving.session_ttl_secs = 600)\n\
           [--batch-window MS]  coalescing window: hold the first dequeued request\n\
                                up to MS milliseconds so concurrent same-pattern\n\
                                requests share one encode (default 0 = drain-only)\n\
           [--batch-max N]      max requests per drained batch (default 32)\n\
           [--cache-bytes N]    encoded-reply cache budget in bytes (LRU beyond it;\n\
                                default 64 MiB)\n\
           [--binary-frames B]  allow binary-frame negotiation, symmetric: segment\n\
                                replies down, activation uploads up (default true)\n\
           [--warm M]           cache pre-warming at startup: 'off' (default),\n\
                                'paper' (pre-encode likely reply keys +\n\
                                pre-build phase-2 plans under the paper-default\n\
                                profile), or 'log' (replay the --store-dir\n\
                                segment log: the previous process's recorded\n\
                                working set comes back byte-identical).\n\
                                --warm-cache B remains as a deprecated alias\n\
                                for off/paper\n\
           [--store-dir D]      durable warm state: stage cache inserts into an\n\
                                append-only CRC-guarded segment log under D\n\
                                (flushed + compacted by the housekeeping\n\
                                thread), replayed by --warm log (default off)\n\
           [--host-fallback B]  phase 2 on pure-Rust reference kernels, no PJRT\n\
                                (linear archs only; default false)\n\
           [--frontend F]       connection handling: 'reactor' (default; one\n\
                                poll-based event loop carries every accepted\n\
                                device) or 'threaded' (thread-per-connection\n\
                                baseline)\n\
           [--max-conns N]      accept gate: refuse protocol connections beyond\n\
                                N with a max_conns error (default 4096)\n\
           [--conn-idle-secs S] close connections idle (nothing in flight, no\n\
                                bytes moved) for S seconds — defuses slow-loris\n\
                                and half-open peers (0 = never; default 600,\n\
                                matching the session TTL)\n\
           [--fair-rate R]      per-connection fair queuing: admit at most R\n\
                                requests/s per connection (2 s burst); excess\n\
                                gets a 'throttled' error instead of queue space\n\
                                (0 = off; default serving.fair_rate = 0)\n\
           [--metrics-listen A] serve a plaintext Prometheus-style scrape of the\n\
                                stats document on a second listener (default off)\n\
           [--trace-sample P]   probability an accepted connection is traced\n\
                                (0 = off, default); traced requests record a\n\
                                span per pipeline stage, timelines served at\n\
                                /trace, /trace?id=N and /trace/slow on the\n\
                                metrics listener\n\
           [--trace-slow-ms M]  slow-request exemplars: traced timelines\n\
                                slower than M ms survive store eviction and\n\
                                are listed worst-first at /trace/slow\n\
                                (0 = off)\n\
           [--trace-keep N]     how many slow exemplars to keep (default 8)\n\
           [--trace-store N]    trace-store capacity in timelines, oldest\n\
                                evicted first (default 1024)\n\
           [--record-trace F]   capture live traffic into F in the scenario\n\
                                engine's 'trace v1' text format, replayable\n\
                                with bench-serve --scenario F\n\
           [--brownout-ms M]    overload brownout: sustained queue waits above\n\
                                M ms step a degradation ladder — requests whose\n\
                                accuracy budget still holds at a coarser\n\
                                quantization level are planned there (never\n\
                                past budget), marked 'degraded' in replies\n\
                                (0 = off, default)\n\
           [--job-timeout-ms M] soft watchdog: count batches executing longer\n\
                                than M ms in job_timeouts_total (0 = off)\n\
           [--drain-timeout-secs S] cap on the graceful drain after SIGTERM/\n\
                                SIGINT: stop accepting, finish in-flight work,\n\
                                then exit 0 (default 30)\n\
           [--fault-inject S]   chaos harness (requires QPART_FAULT_INJECT=1):\n\
                                worker-panic=P,exec-delay-ms=D,alloc-fail=P\n\
           [--synthetic]        serve the self-contained synthetic test bundle\n\
                                (tinymlp, host kernels) from a temp dir — no\n\
                                artifacts bundle needed\n\
  request  --model mlp6 --accuracy 0.01 --n 16 --addr 127.0.0.1:7878 [--binary]\n\
  bench-serve  load-test the front-end + dataplane + batched phase-2 execution\n\
           plane (synthetic bundle + host kernels unless --artifacts):\n\
           [--clients N] [--requests N-per-client] [--workers N] [--keys K]\n\
           [--batch-window MS] [--cache-bytes N] [--binary-frames B]\n\
           [--phase2 B] [--warm-cache B] [--host-fallback B]\n\
           [--store-dir D]            durable-store restart measurement: run the\n\
                                      load once cold with the segment log at D,\n\
                                      drain, restart with --warm log, and report\n\
                                      restart-to-p50-warm time plus first-wave\n\
                                      hit counts and reply byte-identity\n\
           [--frontend F]             reactor (default) or threaded\n\
           [--min-peak-conns N]       fail unless peak open connections >= N\n\
                                      (the CI fleet-soak assertion)\n\
           [--expect-zero-copy]       fail unless cache-hit reply bodies went\n\
                                      out via the zero-copy writev path\n\
                                      (reactor only; the 'zero-copy MB' column)\n\
           [--fair-rate R]            per-connection token-bucket admission rate\n\
                                      (0 = off); refusals are counted in the\n\
                                      'throttled' column\n\
           [--sweep workers=1,2,4,8]  run once per value, print a scaling table\n\
           [--csv]                    emit the table as CSV rows (qpart-bench format)\n\
           [--scenario NAME|FILE]     replay a declarative multi-phase scenario\n\
                                      (builtin: flashcrowd, diurnal, storm; a\n\
                                      scenario file; or a 'trace v1' capture\n\
                                      from serve --record-trace) instead of the\n\
                                      uniform load; reports per-class p50/p99\n\
                                      + Jain fairness\n\
           [--time-scale S]           multiply scenario arrival times by S\n\
           [--chaos a,b,..]           inject misbehaving peers alongside the\n\
                                      scenario: drop-mid-phase2, garbage-frames,\n\
                                      slow-loris, half-open\n\
           [--chaos-rate P]           per-upload probability of drop-mid-phase2\n\
                                      (default 0.25)\n\
           [--trace-out F]            trace every request and export the span\n\
                                      timelines as Chrome trace-event JSON\n\
                                      (chrome://tracing / Perfetto) to F\n\
           [--brownout-ms M]          arm the server's overload brownout for\n\
                                      the run (see serve --brownout-ms)\n\
           [--fault-inject S]         arm server-side fault injection for the\n\
                                      run (requires QPART_FAULT_INJECT=1);\n\
                                      the report asserts panics were recovered\n\
                                      (worker restarts > 0, zero misroutes)\n\
           [--scrape-check]           start a metrics listener and assert that\n\
                                      /metrics histogram _bucket series parse\n\
                                      and /trace/slow returns valid JSON\n\
           reports peak open connections + accept-to-first-byte latency (front-end\n\
           scaling), req/s, p50/p99 latency, shed rate, throttled count + Jain\n\
           fairness index, encodes vs requests,\n\
           cache + decision-cache hit rates, per-stage means (plan / encode+pack\n\
           / phase-2 exec), phase-2 batch occupancy + ladder-padded rows, uplink\n\
           bytes saved, binary-vs-JSON byte-identity checks in both directions,\n\
           and reactor-vs-threaded reply byte-identity\n\
  sim      --model mlp6 --rate 20 --devices 16 --duration 10\n\
  offline  --model mlp6\n\
  models";

fn load_config(args: &Args) -> Result<Config, String> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(path).map_err(|e| e.to_string())?,
        None => Config::defaults(),
    };
    for kv in args.get_all("set") {
        cfg.set_override(kv).map_err(|e| e.to_string())?;
    }
    Ok(cfg)
}

fn bool_flag(args: &Args, key: &str, default: bool) -> Result<bool, String> {
    match args.get(key) {
        None => Ok(default),
        Some(s) => s.parse::<bool>().map_err(|_| format!("--{key}: expected true|false, got '{s}'")),
    }
}

/// Resolve the warm mode: `--warm off|paper|log` wins; the deprecated
/// `--warm-cache B` boolean maps true → paper (warning once); otherwise
/// the config's `serving.warm` (which applies the same aliasing to the
/// `serving.warm_cache` key).
fn warm_flag(args: &Args, cfg_warm: &str) -> Result<WarmMode, String> {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    if let Some(s) = args.get("warm") {
        return WarmMode::parse(s);
    }
    if args.get("warm-cache").is_some() {
        let on = bool_flag(args, "warm-cache", false)?;
        if !WARNED.swap(true, Ordering::Relaxed) {
            eprintln!(
                "warning: --warm-cache is deprecated; use --warm {}",
                if on { "paper" } else { "off" }
            );
        }
        return Ok(if on { WarmMode::Paper } else { WarmMode::Off });
    }
    WarmMode::parse(cfg_warm)
}

/// Parse `--frontend reactor|threaded`.
fn frontend_flag(args: &Args, default: Frontend) -> Result<Frontend, String> {
    match args.get("frontend") {
        None => Ok(default),
        Some("reactor") => Ok(Frontend::Reactor),
        Some("threaded") => Ok(Frontend::Threaded),
        Some(other) => Err(format!("--frontend: expected reactor|threaded, got '{other}'")),
    }
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = load_config(args)?;
    let serving = cfg.serving().map_err(|e| e.to_string())?;
    // --synthetic: serve the self-contained test bundle (tinymlp on the
    // host reference kernels) from a fresh temp dir — no artifacts
    // needed. CI's SIGTERM drain check leans on this to stand up a real
    // `serve` process on a bare runner.
    let synth_dir = if bool_flag(args, "synthetic", false)? {
        Some(qpart::coordinator::testing::synthetic_bundle("serve"))
    } else {
        None
    };
    let batch_window_ms = args.get_f64("batch-window", serving.batch_window_us as f64 / 1000.0)?;
    let metrics_listen = args
        .get_or("metrics-listen", &serving.metrics_listen)
        .to_string();
    let server_cfg = qpart::coordinator::ServerConfig {
        listen: args.get_or("listen", &serving.listen).to_string(),
        workers: args.get_usize("workers", serving.workers)?,
        queue_capacity: args.get_usize("queue", serving.queue_capacity)?,
        session_capacity: args.get_usize("sessions", 4096)?,
        session_ttl: Duration::from_secs(
            args.get_usize("session-ttl", serving.session_ttl_secs as usize)? as u64,
        ),
        batch_window: Duration::from_micros((batch_window_ms * 1000.0).max(0.0) as u64),
        batch_max: args.get_usize("batch-max", 32)?,
        cache_bytes: args.get_usize("cache-bytes", serving.cache_bytes)?,
        binary_frames: bool_flag(args, "binary-frames", serving.binary_frames)?,
        frontend: frontend_flag(args, Frontend::Reactor)?,
        max_conns: args.get_usize("max-conns", serving.max_conns)?,
        conn_idle: Duration::from_secs(
            args.get_usize("conn-idle-secs", serving.conn_idle_secs as usize)? as u64,
        ),
        fair_rate: args.get_f64("fair-rate", serving.fair_rate)?,
        metrics_listen: if metrics_listen.is_empty() { None } else { Some(metrics_listen) },
        trace_sample: args.get_f64("trace-sample", 0.0)?,
        trace_slow_us: (args.get_f64("trace-slow-ms", 0.0)?.max(0.0) * 1000.0) as u64,
        trace_slow_keep: args.get_usize("trace-keep", 8)?,
        trace_store: args.get_usize("trace-store", 1024)?,
        record_trace: args.get("record-trace").map(str::to_string),
        warm: warm_flag(args, &serving.warm)?,
        store_dir: {
            let dir = args.get_or("store-dir", &serving.store_dir).to_string();
            if dir.is_empty() { None } else { Some(dir) }
        },
        host_fallback: bool_flag(args, "host-fallback", synth_dir.is_some())?,
        brownout_wait_us: (args.get_f64("brownout-ms", 0.0)?.max(0.0) * 1000.0) as u64,
        job_timeout: Duration::from_millis(args.get_usize("job-timeout-ms", 0)? as u64),
        fault_inject: fault_inject_flag(args)?,
        artifacts_dir: match &synth_dir {
            Some(d) => d.to_str().unwrap().to_string(),
            None => args.get_or("artifacts", &serving.artifacts_dir).to_string(),
        },
    };
    println!(
        "loading bundle from '{}' ({} workers, queue {}, batch window {:?}, cache {} MiB, binary frames {}, warm {}, store {}, frontend {:?}, max conns {}, conn idle {:?}, fair rate {}) ...",
        server_cfg.artifacts_dir,
        server_cfg.workers,
        server_cfg.queue_capacity,
        server_cfg.batch_window,
        server_cfg.cache_bytes >> 20,
        server_cfg.binary_frames,
        server_cfg.warm.as_str(),
        server_cfg.store_dir.as_deref().unwrap_or("off"),
        server_cfg.frontend,
        server_cfg.max_conns,
        server_cfg.conn_idle,
        server_cfg.fair_rate,
    );
    let record_path = server_cfg.record_trace.clone();
    let handle = serve(server_cfg)?;
    println!("qpart coordinator listening on {}", handle.addr);
    if let Some(m) = handle.metrics_addr {
        println!("metrics scrape endpoint on http://{m}/metrics");
        println!("trace timelines on http://{m}/trace (index), /trace?id=N, /trace/slow");
    }
    if let Some(path) = record_path {
        println!("recording live traffic to '{path}' (trace v1, flushed periodically)");
    }
    let drain_timeout =
        Duration::from_secs(args.get_usize("drain-timeout-secs", 30)? as u64);
    // SIGTERM/SIGINT flip a flag; the loop below notices within 250 ms
    // and drains gracefully: stop accepting, finish in-flight work,
    // flush replies, exit 0
    qpart::coordinator::net::install_shutdown_handler();
    println!("(ctrl-c / SIGTERM to drain and stop)");
    while !qpart::coordinator::net::shutdown_requested() {
        std::thread::sleep(Duration::from_millis(250));
    }
    println!(
        "shutdown requested: draining (refusing new connections, finishing in-flight work, {}s cap) ...",
        drain_timeout.as_secs()
    );
    let clean = handle.drain(drain_timeout);
    println!("drained {}", if clean { "cleanly" } else { "with the timeout forcing the exit" });
    if let Some(d) = synth_dir {
        let _ = std::fs::remove_dir_all(&d);
    }
    Ok(())
}

/// Parse `--fault-inject worker-panic=P,exec-delay-ms=D,alloc-fail=P`.
/// The spec is compiled in but double-gated: the flag is refused unless
/// the environment also opts in with `QPART_FAULT_INJECT=1`, so a copied
/// production command line cannot arm the chaos path by accident.
fn fault_inject_flag(args: &Args) -> Result<Option<qpart::coordinator::FaultSpec>, String> {
    let Some(spec) = args.get("fault-inject") else {
        return Ok(None);
    };
    if std::env::var("QPART_FAULT_INJECT").as_deref() != Ok("1") {
        return Err(
            "--fault-inject requires QPART_FAULT_INJECT=1 in the environment (chaos harness only)"
                .into(),
        );
    }
    qpart::coordinator::FaultSpec::parse(spec).map(Some)
}

fn cmd_request(args: &Args) -> Result<(), String> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let model = args.get_or("model", "mlp6").to_string();
    let n = args.get_usize("n", 8)?;
    let accuracy = args.get_f64("accuracy", 0.01)?;
    let bundle = Arc::new(Bundle::load(artifacts).map_err(|e| e.to_string())?);
    let mut client =
        DeviceClient::connect(addr, Arc::clone(&bundle)).map_err(|e| e.to_string())?;
    if bool_flag(args, "binary", false)? {
        let granted = client.negotiate_binary().map_err(|e| e.to_string())?;
        println!("binary frames: {}", if granted { "granted" } else { "refused (JSON fallback)" });
    }

    let entry = bundle.model(&model).map_err(|e| e.to_string())?;
    let (x, y) = bundle.dataset(&entry.dataset).map_err(|e| e.to_string())?;
    let x = HostTensor::from(x);
    let arch = bundle.arch(&entry.arch).map_err(|e| e.to_string())?;

    let mut req = paper_request(&model, accuracy);
    req.channel_capacity_bps = args.get_f64("capacity-bps", req.channel_capacity_bps)?;
    req.clock_hz = args.get_f64("clock-hz", req.clock_hz)?;

    // --simulate: one-shot mode (server plays the device too)
    let simulate = args.has("simulate");
    let mut correct = 0usize;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let idx = i % x.batch();
        let input = x.slice_rows_padded(idx, idx + 1, 1);
        let (pred, partition) = if simulate {
            match client.simulate(req.clone(), &input).map_err(|e| e.to_string())? {
                qpart::proto::messages::Response::Result(r) => {
                    let p = r
                        .costs
                        .as_ref()
                        .and_then(|c| c.get("partition"))
                        .and_then(|v| v.as_i64())
                        .unwrap_or(-1);
                    (r.prediction, p as usize)
                }
                other => return Err(format!("unexpected response {other:?}")),
            }
        } else {
            let (pred, _logits, partition) =
                client.infer(req.clone(), input).map_err(|e| e.to_string())?;
            (pred, partition)
        };
        if pred == y[idx] {
            correct += 1;
        }
        println!("request {i}: partition={partition} pred={pred} label={}", y[idx]);
    }
    let dt = t0.elapsed();
    println!(
        "\n{n} requests in {:.2}s ({:.1} req/s), accuracy {}/{} = {:.1}%",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64(),
        correct,
        n,
        100.0 * correct as f64 / n as f64
    );
    // sanity: the arch accepts a random input of its declared shape
    let probe = random_input(arch, 7);
    debug_assert_eq!(probe.row_elems() as u64, arch.activation_elems(0));
    Ok(())
}

// ---------------------------------------------------------------------------
// bench-serve: the serving-dataplane load harness
// ---------------------------------------------------------------------------

fn quantile_us(sorted: &[u64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)] as f64
}

/// One bench-serve run's result row (feeds the sweep table / CSV).
struct BenchSummary {
    workers: usize,
    attempts: usize,
    shed: u64,
    /// High-water mark of concurrently open connections — the front-end
    /// scaling figure (decoupled from `workers` by the reactor).
    peak_conns: u64,
    /// Mean connect→first-reply-byte time (accept + dispatch round trip
    /// with no inference work), in milliseconds.
    first_byte_ms: f64,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    /// Requests refused by per-connection fair queuing (`--fair-rate`).
    throttled: u64,
    /// Jain's fairness index over per-client completed-request counts
    /// (1.0 = perfectly even service across the fleet).
    jain: f64,
    encodes: u64,
    coalesced: u64,
    hit_rate_pct: f64,
    phase2_execs: u64,
    phase2_rows: u64,
    /// Zero rows the batch ladder padded onto phase-2 executions this
    /// pass (0 ⇔ every chunk hit a ladder rung exactly).
    phase2_padded: u64,
    /// Per-stage mean cost this pass: Algorithm-2 planning, segment
    /// encode (quantize+pack+serialize), phase-2 execution.
    plan_us: f64,
    encode_us: f64,
    exec_us: f64,
    uplink_saved_bytes: u64,
    /// MB written to sockets straight from shared reply bodies this pass
    /// (`outbox_zero_copy_bytes_total` delta) — reactor front-end only,
    /// 0 on the threaded fallback.
    zero_copy_mb: f64,
}

impl BenchSummary {
    fn table_headers() -> [&'static str; 20] {
        [
            "workers",
            "peak conns",
            "1st byte ms",
            "req/s",
            "p50 ms",
            "p99 ms",
            "shed %",
            "throttled",
            "jain",
            "encodes",
            "coalesced",
            "hit %",
            "plan µs",
            "enc µs",
            "exec µs",
            "p2 execs",
            "p2 rows",
            "p2 padded",
            "uplink saved B",
            "zero-copy MB",
        ]
    }

    fn table_row(&self) -> Vec<String> {
        vec![
            self.workers.to_string(),
            self.peak_conns.to_string(),
            format!("{:.2}", self.first_byte_ms),
            format!("{:.0}", self.req_per_s),
            format!("{:.2}", self.p50_ms),
            format!("{:.2}", self.p99_ms),
            format!("{:.1}", 100.0 * self.shed as f64 / self.attempts.max(1) as f64),
            self.throttled.to_string(),
            format!("{:.3}", self.jain),
            self.encodes.to_string(),
            self.coalesced.to_string(),
            format!("{:.1}", self.hit_rate_pct),
            format!("{:.0}", self.plan_us),
            format!("{:.0}", self.encode_us),
            format!("{:.0}", self.exec_us),
            self.phase2_execs.to_string(),
            self.phase2_rows.to_string(),
            self.phase2_padded.to_string(),
            self.uplink_saved_bytes.to_string(),
            format!("{:.1}", self.zero_copy_mb),
        ]
    }
}

/// Per-pass mean of a latency histogram given its cumulative
/// `(count, mean)` before and after the pass (a NaN mean encodes an
/// empty histogram — treated as zero sum).
fn delta_mean_us(prev_count: u64, prev_mean: f64, count: u64, mean: f64) -> f64 {
    let sum = |c: u64, m: f64| if c == 0 { 0.0 } else { m * c as f64 };
    let dc = count.saturating_sub(prev_count);
    if dc == 0 {
        0.0
    } else {
        (sum(count, mean) - sum(prev_count, prev_mean)) / dc as f64
    }
}

/// Bytes of a JSON-framed activation request (line + newline).
fn upload_json_bytes(a: &ActivationUpload) -> usize {
    Request::Activation(a.clone()).to_line().len() + 1
}

/// Bytes of the same upload as a binary request frame (envelope + header
/// + raw blob).
fn upload_binary_bytes(a: &ActivationUpload) -> usize {
    let (header, blob) = a.to_binary();
    1 + 4 + 4 + header.len() + blob.len()
}

fn cmd_bench_serve(args: &Args) -> Result<(), String> {
    // bundle: real artifacts if given, else a synthetic temp bundle —
    // resolved out here so the temp dir is removed on EVERY exit path
    let (artifacts_dir, synth_dir) = match args.get("artifacts") {
        Some(d) => (d.to_string(), None),
        None => {
            let d = qpart::coordinator::testing::synthetic_bundle("bench-serve");
            (d.to_string_lossy().into_owned(), Some(d))
        }
    };
    let synthetic = synth_dir.is_some();
    let model = args.get_or("model", if synthetic { "tinymlp" } else { "mlp6" }).to_string();
    let result = bench_serve_runs(args, &artifacts_dir, &model, synthetic);
    if let Some(d) = synth_dir {
        let _ = std::fs::remove_dir_all(d);
    }
    result
}

/// Parse `--sweep workers=1,2,4,8` into the workers values to run.
fn parse_sweep(spec: &str) -> Result<Vec<usize>, String> {
    let (key, vals) = spec
        .split_once('=')
        .ok_or_else(|| format!("--sweep: expected key=v1,v2,..., got '{spec}'"))?;
    if key != "workers" {
        return Err(format!("--sweep: only 'workers' is sweepable, got '{key}'"));
    }
    vals.split(',')
        .map(|v| {
            v.trim()
                .parse::<usize>()
                .map_err(|_| format!("--sweep: bad workers value '{v}'"))
        })
        .collect()
}

/// Single-run or sweep mode, plus the sweep table / CSV output.
fn bench_serve_runs(
    args: &Args,
    artifacts_dir: &str,
    model: &str,
    synthetic: bool,
) -> Result<(), String> {
    if args.get("scenario").is_some() {
        if args.get("sweep").is_some() {
            return Err("--sweep is not supported with --scenario".into());
        }
        return run_bench_scenario(args, artifacts_dir, model, synthetic);
    }
    // phase-2 load and host-kernel execution default on for the synthetic
    // bundle (no PJRT anywhere); with real artifacts both are opt-in
    let phase2 = bool_flag(args, "phase2", synthetic)?;
    let host_fallback = bool_flag(args, "host-fallback", synthetic)?;
    let sweep = match args.get("sweep") {
        Some(spec) => Some(parse_sweep(spec)?),
        None => None,
    };
    if bool_flag(args, "csv", false)? {
        // same switch qpart-bench's Table honors, so sweep CSV output
        // matches the figure benches'
        std::env::set_var("QPART_BENCH_CSV", "1");
    }
    let workers_list = match &sweep {
        Some(v) => v.clone(),
        None => vec![args.get_usize("workers", 4)?],
    };
    let frontend = frontend_flag(args, Frontend::Reactor)?;
    let mut table = qpart_bench::Table::new(
        format!("bench-serve {} (model {model})", if sweep.is_some() { "sweep" } else { "run" }),
        &BenchSummary::table_headers(),
    );
    for workers in workers_list {
        let summary = run_bench_serve(
            args,
            artifacts_dir,
            model,
            workers,
            phase2,
            host_fallback,
            frontend,
        )?;
        table.row(summary.table_row());
    }
    table.print();
    Ok(())
}

#[allow(clippy::too_many_lines, clippy::too_many_arguments)]
fn run_bench_serve(
    args: &Args,
    artifacts_dir: &str,
    model: &str,
    workers: usize,
    phase2: bool,
    host_fallback: bool,
    frontend: Frontend,
) -> Result<BenchSummary, String> {
    let clients = args.get_usize("clients", 8)?.max(1);
    let per_client = args.get_usize("requests", 32)?.max(1);
    let keys = args.get_usize("keys", 3)?.max(1);
    let window_ms = args.get_f64("batch-window", 2.0)?;
    let cache_bytes = args.get_usize("cache-bytes", 64 << 20)?;
    let binary = bool_flag(args, "binary-frames", true)?;
    let warm = bool_flag(args, "warm-cache", false)?;
    let store_dir = args.get("store-dir").map(str::to_string);
    let trace_out = args.get("trace-out").map(str::to_string);
    let scrape_check = bool_flag(args, "scrape-check", false)?;
    let brownout_us = (args.get_f64("brownout-ms", 0.0)?.max(0.0) * 1000.0) as u64;
    let faults = fault_inject_flag(args)?;
    // with injected worker panics or allocation failures, `internal`
    // error replies are the expected recovery signature, not a failure
    let panics_armed = faults.map_or(false, |f| f.worker_panic > 0.0);
    let chaos_errors_ok = faults.map_or(false, |f| f.worker_panic > 0.0 || f.alloc_fail > 0.0);

    // the device-side arch spec (for boundary dims of phase-2 uploads)
    let bundle = Bundle::load(artifacts_dir).map_err(|e| e.to_string())?;
    let entry = bundle.model(model).map_err(|e| e.to_string())?;
    let arch = bundle.arch(&entry.arch).map_err(|e| e.to_string())?.clone();
    drop(bundle);

    let handle = serve(qpart::coordinator::ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        queue_capacity: args.get_usize("queue", 1024)?,
        batch_window: Duration::from_micros((window_ms * 1000.0).max(0.0) as u64),
        cache_bytes,
        binary_frames: binary,
        frontend,
        max_conns: args.get_usize("max-conns", 4096)?,
        fair_rate: args.get_f64("fair-rate", 0.0)?,
        // --trace-out wants every request traced, into a store deep
        // enough that nothing is evicted before the export
        trace_sample: if trace_out.is_some() { 1.0 } else { 0.0 },
        trace_store: if trace_out.is_some() { 65536 } else { 1024 },
        metrics_listen: if scrape_check { Some("127.0.0.1:0".into()) } else { None },
        warm: if warm { WarmMode::Paper } else { WarmMode::Off },
        store_dir: store_dir.clone(),
        host_fallback,
        brownout_wait_us: brownout_us,
        fault_inject: faults,
        artifacts_dir: artifacts_dir.to_string(),
        ..Default::default()
    })?;
    let addr = handle.addr.to_string();
    println!(
        "bench-serve: model={model} workers={workers} clients={clients} \
         requests/client={per_client} keys={keys} batch-window={window_ms}ms \
         phase2={phase2} binary={binary} frontend={frontend:?}"
    );
    if let Some(f) = &faults {
        println!(
            "fault-inject armed: worker-panic={} exec-delay-ms={} alloc-fail={}",
            f.worker_panic, f.exec_delay_ms, f.alloc_fail
        );
    }

    let mut prev = handle.snapshot();
    let mut summary = None;
    let mut uplink_saved_total = 0u64;
    for pass in 1..=2 {
        let barrier = Arc::new(Barrier::new(clients));
        let t0 = Instant::now();
        let mut joins = Vec::with_capacity(clients);
        for c in 0..clients {
            let addr = addr.clone();
            let model = model.to_string();
            let arch = arch.clone();
            let barrier = Arc::clone(&barrier);
            joins.push(std::thread::spawn(
                move || -> Result<(Vec<u64>, u64, u64, u64, u64, u64), String> {
                    // accept-to-first-byte: connect + one ping round trip
                    // (front-end accept + dispatch, no inference work) —
                    // the latency figure that shows whether the reactor
                    // keeps up as accepted connections pile past the
                    // worker count
                    let t_accept = Instant::now();
                    let mut conn = BlockingConn::connect(&addr)?;
                    match conn.call(&Request::Ping)? {
                        Response::Pong => {}
                        other => return Err(format!("ping: unexpected {other:?}")),
                    }
                    let first_byte_us = t_accept.elapsed().as_micros() as u64;
                    // odd clients negotiate the binary uplink (when the
                    // server allows), evens stay JSON — both paths load
                    let mut bin_session = false;
                    if binary && c % 2 == 1 {
                        let hello = HelloRequest { binary_frames: true, ..HelloRequest::default() };
                        match conn.call(&Request::Hello(hello))? {
                            Response::Hello(h) => bin_session = h.binary_frames,
                            other => return Err(format!("hello: unexpected {other:?}")),
                        }
                    }
                    barrier.wait();
                    let mut lat = Vec::with_capacity(per_client);
                    let mut shed = 0u64;
                    let mut throttled = 0u64;
                    let mut errors = 0u64;
                    let mut saved = 0u64;
                    for i in 0..per_client {
                        let mut req = paper_request(&model, 0.02);
                        // K overlapping channel classes → K coalescing keys
                        // shared across all clients
                        req.channel_capacity_bps = 50e6 * (1 + (c + i) % keys) as f64;
                        let t = Instant::now();
                        let reply = match conn.call(&Request::Infer(req))? {
                            Response::Segment(r) => r,
                            Response::Error(e) if e.code == "overloaded" => {
                                shed += 1;
                                continue;
                            }
                            Response::Error(e) if e.code == "throttled" => {
                                throttled += 1;
                                continue;
                            }
                            Response::Error(e) => {
                                errors += 1;
                                eprintln!("client {c}: {}: {}", e.code, e.message);
                                continue;
                            }
                            other => return Err(format!("unexpected response {other:?}")),
                        };
                        if phase2 {
                            let upload =
                                synthetic_upload(&reply, &arch, (c * 10_000 + i) as u64);
                            if bin_session {
                                saved += (upload_json_bytes(&upload)
                                    .saturating_sub(upload_binary_bytes(&upload)))
                                    as u64;
                            }
                            let resp = if bin_session {
                                conn.call_binary_upload(&upload)?
                            } else {
                                conn.call(&Request::Activation(upload))?
                            };
                            match resp {
                                Response::Result(_) => {}
                                // failed uploads record no latency sample
                                // (matching the infer shed/error paths)
                                Response::Error(e) if e.code == "overloaded" => {
                                    shed += 1;
                                    continue;
                                }
                                Response::Error(e) if e.code == "throttled" => {
                                    throttled += 1;
                                    continue;
                                }
                                Response::Error(e) => {
                                    errors += 1;
                                    eprintln!("client {c} upload: {}: {}", e.code, e.message);
                                    continue;
                                }
                                other => {
                                    return Err(format!("unexpected response {other:?}"))
                                }
                            }
                        }
                        lat.push(t.elapsed().as_micros() as u64);
                    }
                    Ok((lat, shed, throttled, errors, saved, first_byte_us))
                },
            ));
        }
        let mut lats: Vec<u64> = Vec::new();
        let mut first_bytes: Vec<u64> = Vec::new();
        let mut ok_per_client: Vec<u64> = Vec::new();
        let mut shed = 0u64;
        let mut throttled = 0u64;
        let mut errors = 0u64;
        let mut pass_saved = 0u64;
        for j in joins {
            let (l, s, t, e, saved, fb) =
                j.join().map_err(|_| "bench client panicked".to_string())??;
            ok_per_client.push(l.len() as u64);
            lats.extend(l);
            shed += s;
            throttled += t;
            errors += e;
            pass_saved += saved;
            first_bytes.push(fb);
        }
        uplink_saved_total += pass_saved;
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_unstable();
        let attempts = clients * per_client;
        let snap = handle.snapshot();
        let d_hits = snap.cache_hits - prev.cache_hits;
        let d_misses = snap.cache_misses - prev.cache_misses;
        let d_encodes = snap.encodes_total - prev.encodes_total;
        let d_coalesced = snap.coalesced_total - prev.coalesced_total;
        let d_execs = snap.phase2_execs_total - prev.phase2_execs_total;
        let d_rows = snap.phase2_rows_total - prev.phase2_rows_total;
        let d_padded = snap.phase2_padded_rows_total - prev.phase2_padded_rows_total;
        let d_zero_copy =
            snap.outbox_zero_copy_bytes_total - prev.outbox_zero_copy_bytes_total;
        let lookups = d_hits + d_misses;
        let hit_rate = if lookups > 0 { 100.0 * d_hits as f64 / lookups as f64 } else { 0.0 };
        // per-pass stage means from the cumulative histogram sums
        let d_wait_mean = delta_mean_us(
            prev.queue_wait_count,
            prev.queue_wait_mean_us,
            snap.queue_wait_count,
            snap.queue_wait_mean_us,
        );
        let d_plan_mean = delta_mean_us(
            prev.decide_count,
            prev.decide_mean_us,
            snap.decide_count,
            snap.decide_mean_us,
        );
        let d_encode_mean = delta_mean_us(
            prev.quantize_count,
            prev.quantize_mean_us,
            snap.quantize_count,
            snap.quantize_mean_us,
        );
        let d_exec_mean = delta_mean_us(
            prev.execute_count,
            prev.execute_mean_us,
            snap.execute_count,
            snap.execute_mean_us,
        );
        first_bytes.sort_unstable();
        let fb_mean_ms = if first_bytes.is_empty() {
            f64::NAN
        } else {
            first_bytes.iter().sum::<u64>() as f64 / first_bytes.len() as f64 / 1000.0
        };
        println!(
            "pass {pass}: {} ok / {attempts} ({shed} shed = {:.1}%, {throttled} throttled, \
             {errors} errors), {:.0} req/s, p50 {:.2} ms, p99 {:.2} ms, jain {:.3}",
            lats.len(),
            100.0 * shed as f64 / attempts as f64,
            lats.len() as f64 / wall,
            quantile_us(&lats, 0.50) / 1000.0,
            quantile_us(&lats, 0.99) / 1000.0,
            jain_index(&ok_per_client),
        );
        println!(
            "        front-end: conns open peak {}, accept→first-byte mean {fb_mean_ms:.2} ms \
             (p99 {:.2} ms) over {} connects, zero-copy egress {:.1} MB",
            snap.conns_open_peak,
            quantile_us(&first_bytes, 0.99) / 1000.0,
            first_bytes.len(),
            d_zero_copy as f64 / 1e6,
        );
        println!(
            "        encodes {d_encodes} / {attempts} infer requests, \
             coalesced {d_coalesced}, cache hits {d_hits}/{lookups} ({hit_rate:.1}%), \
             queue wait mean {d_wait_mean:.0} µs"
        );
        println!(
            "        stages: plan {d_plan_mean:.0} µs, encode+pack {d_encode_mean:.0} µs, \
             phase2 exec {d_exec_mean:.0} µs (per-stage means this pass)"
        );
        if phase2 {
            let occupancy =
                if d_execs > 0 { d_rows as f64 / d_execs as f64 } else { f64::NAN };
            let waste = if d_rows + d_padded > 0 {
                100.0 * d_padded as f64 / (d_rows + d_padded) as f64
            } else {
                0.0
            };
            println!(
                "        phase2: {d_rows} uploads in {d_execs} server-segment runs \
                 (occupancy {occupancy:.2}, ladder padded {d_padded} rows = {waste:.1}% waste)"
            );
        }
        if errors > 0 && !chaos_errors_ok {
            return Err(format!("{errors} requests failed"));
        }
        summary = Some(BenchSummary {
            workers,
            attempts,
            shed,
            peak_conns: snap.conns_open_peak,
            first_byte_ms: fb_mean_ms,
            req_per_s: lats.len() as f64 / wall,
            p50_ms: quantile_us(&lats, 0.50) / 1000.0,
            p99_ms: quantile_us(&lats, 0.99) / 1000.0,
            throttled,
            jain: jain_index(&ok_per_client),
            encodes: d_encodes,
            coalesced: d_coalesced,
            hit_rate_pct: hit_rate,
            phase2_execs: d_execs,
            phase2_rows: d_rows,
            phase2_padded: d_padded,
            plan_us: d_plan_mean,
            encode_us: d_encode_mean,
            exec_us: d_exec_mean,
            // per-pass, like every other field in the row (the cumulative
            // total is printed in the totals line instead)
            uplink_saved_bytes: pass_saved,
            zero_copy_mb: d_zero_copy as f64 / 1e6,
        });
        prev = snap;
    }

    // with brownout armed the storm must have pushed the ladder up AND the
    // controller must step back to 0 once the load drains — wait for that
    // here, before the byte-identity checks below (a reply degraded by a
    // still-hot ladder would differ from the calm control server by design)
    if brownout_us > 0 {
        let snap = handle.snapshot();
        if snap.brownout_enters_total == 0 {
            return Err(
                "brownout: armed but never entered under load (raise load or lower --brownout-ms)"
                    .into(),
            );
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut level = snap.brownout_level;
        while level != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
            level = handle.snapshot().brownout_level;
        }
        if level != 0 {
            return Err(format!(
                "brownout: level still {level} after load drained — controller never exited"
            ));
        }
        let calm = handle.snapshot();
        println!(
            "brownout: entered {}x, exited {}x, {} replies degraded within budget, \
             level back to 0",
            calm.brownout_enters_total, calm.brownout_exits_total, calm.degraded_total,
        );
    }

    // byte-identity check: a binary-frame session against a JSON control,
    // in BOTH directions (segment downlink, activation uplink)
    let retries = if chaos_errors_ok { 40 } else { 0 };
    if binary {
        let mut json_conn = BlockingConn::connect(&addr)?;
        let mut bin_conn = BlockingConn::connect(&addr)?;
        let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
        match bin_conn.call(&hello)? {
            Response::Hello(h) if h.binary_frames => {}
            other => return Err(format!("binary negotiation failed: {other:?}")),
        }
        let req = paper_request(model, 0.02);
        let a = checked_infer(&mut json_conn, &Request::Infer(req.clone()), retries)?;
        let b = checked_infer(&mut bin_conn, &Request::Infer(req), retries)?;
        if a.segment != b.segment || a.pattern != b.pattern {
            return Err("binary-frame segment differs from JSON control".into());
        }
        println!("binary-frame check: segment payloads byte-identical across framings: OK");

        // uplink: the same upload must decode identically from both
        // framings, and (with phase 2 on) produce the same prediction
        let upload = synthetic_upload(&b, &arch, 424_242);
        let (header, blob) = upload.to_binary();
        let via_bin =
            ActivationUpload::from_binary(&header, &blob).map_err(|e| e.to_string())?;
        let via_json = match Request::from_line(&Request::Activation(upload.clone()).to_line())
            .map_err(|e| e.to_string())?
        {
            Request::Activation(u) => u,
            other => return Err(format!("unexpected request {other:?}")),
        };
        if via_bin != upload || via_json != upload || via_bin.packed != via_json.packed {
            return Err("binary activation frame differs from JSON path".into());
        }
        println!(
            "binary-frame check: activation payloads byte-identical across framings: OK \
             ({} B binary vs {} B JSON per upload)",
            upload_binary_bytes(&upload),
            upload_json_bytes(&upload),
        );
        if phase2 {
            let ra = match bin_conn.call_binary_upload(&upload)? {
                Response::Result(r) => r,
                other => return Err(format!("unexpected response {other:?}")),
            };
            // same seed → same payload; the session comes from `a` itself
            let json_upload = synthetic_upload(&a, &arch, 424_242);
            let rb = match json_conn.call(&Request::Activation(json_upload))? {
                Response::Result(r) => r,
                other => return Err(format!("unexpected response {other:?}")),
            };
            if ra.prediction != rb.prediction || ra.logits != rb.logits {
                return Err("phase-2 results differ across framings".into());
            }
            println!("binary-frame check: phase-2 results identical across framings: OK");
        }
    }

    // the evented front-end must be a pure transport change: replies off
    // the reactor are byte-identical to the thread-per-connection
    // baseline, in both framings
    if frontend == Frontend::Reactor {
        let control = serve(qpart::coordinator::ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers: 1,
            frontend: Frontend::Threaded,
            binary_frames: binary,
            host_fallback,
            artifacts_dir: artifacts_dir.to_string(),
            ..Default::default()
        })?;
        let control_addr = control.addr.to_string();
        let req = paper_request(model, 0.02);
        let mut live = BlockingConn::connect(&addr)?;
        let mut base = BlockingConn::connect(&control_addr)?;
        let a = checked_infer(&mut live, &Request::Infer(req.clone()), retries)?;
        let b = checked_infer(&mut base, &Request::Infer(req.clone()), retries)?;
        if a.segment != b.segment || a.pattern != b.pattern {
            return Err("reactor reply differs from thread-per-connection baseline (JSON)".into());
        }
        if binary {
            let hello = Request::Hello(HelloRequest { binary_frames: true, ..HelloRequest::default() });
            for conn in [&mut live, &mut base] {
                match conn.call(&hello)? {
                    Response::Hello(h) if h.binary_frames => {}
                    other => return Err(format!("baseline negotiation failed: {other:?}")),
                }
            }
            let a = checked_infer(&mut live, &Request::Infer(req.clone()), retries)?;
            let b = checked_infer(&mut base, &Request::Infer(req), retries)?;
            if a.segment != b.segment || a.pattern != b.pattern {
                return Err(
                    "reactor reply differs from thread-per-connection baseline (binary)".into(),
                );
            }
        }
        control.shutdown();
        println!(
            "frontend check: reactor replies byte-identical to thread-per-connection \
             baseline (both framings): OK"
        );
    }

    let final_snap = handle.snapshot();
    // fault-injection soak gates: injected panics must show up as worker
    // respawns (the supervisor noticed and replaced every dead thread),
    // and the server must still be serving — which the byte-identity
    // checks above already proved by round-tripping fresh requests
    if panics_armed {
        if final_snap.worker_restarts_total == 0 {
            return Err(
                "fault-inject: worker-panic armed but worker_restarts_total is 0 — \
                 no panic fired or the supervisor never respawned"
                    .into(),
            );
        }
        println!(
            "fault-inject: {} worker restarts after injected panics, {} sessions live, \
             server still serving",
            final_snap.worker_restarts_total,
            handle.sessions.len(),
        );
    }
    // fleet-soak gate: accepted connections must scale past the worker
    // count (CI asserts clients ≫ workers landed concurrently)
    let min_peak = args.get_usize("min-peak-conns", 0)?;
    if min_peak > 0 && final_snap.conns_open_peak < min_peak as u64 {
        return Err(format!(
            "front-end scaling: peak open connections {} < required {} (workers {})",
            final_snap.conns_open_peak, min_peak, workers
        ));
    }
    // zero-copy gate (reactor front-end): segment replies — cache hits
    // included — must have gone out as shared bodies, not per-connection
    // copies. The byte-identity checks above prove the shared path emits
    // the same wire bytes.
    if bool_flag(args, "expect-zero-copy", false)? {
        if frontend != Frontend::Reactor {
            return Err("--expect-zero-copy requires the reactor front-end".into());
        }
        if final_snap.outbox_zero_copy_bytes_total == 0 {
            return Err(
                "zero-copy egress: outbox_zero_copy_bytes_total is 0 — segment bodies \
                 were copied into connection buffers"
                    .into(),
            );
        }
    }
    println!(
        "front-end: conns accepted {}, open peak {}, rejected {}, timed out {}, \
         outbox bytes peak {}, zero-copy egress bytes {}",
        final_snap.conns_accepted_total,
        final_snap.conns_open_peak,
        final_snap.conns_rejected_total,
        final_snap.conns_timed_out,
        final_snap.outbox_bytes_peak,
        final_snap.outbox_zero_copy_bytes_total,
    );
    println!(
        "totals: requests {}, encodes {}, coalesced {}, cache hits {}, cache misses {}, \
         decision hits {}, decision misses {}, phase2 execs {}, phase2 rows {}, \
         phase2 padded rows {}, warmed {}, uplink bytes saved {}",
        final_snap.requests_total,
        final_snap.encodes_total,
        final_snap.coalesced_total,
        final_snap.cache_hits,
        final_snap.cache_misses,
        final_snap.decision_hits,
        final_snap.decision_misses,
        final_snap.phase2_execs_total,
        final_snap.phase2_rows_total,
        final_snap.phase2_padded_rows_total,
        final_snap.warmed_total,
        uplink_saved_total,
    );
    if scrape_check {
        let maddr = handle.metrics_addr.ok_or("scrape-check: no metrics listener")?;
        let scrape = http_get(&maddr.to_string(), "/metrics")?;
        let buckets: Vec<&str> = scrape.lines().filter(|l| l.contains("_bucket{le=")).collect();
        if buckets.is_empty() {
            return Err("scrape-check: no histogram _bucket series in /metrics".into());
        }
        for line in &buckets {
            let val = line.rsplit(' ').next().unwrap_or("");
            val.parse::<u64>()
                .map_err(|_| format!("scrape-check: unparsable bucket count in '{line}'"))?;
        }
        let slow = http_get(&maddr.to_string(), "/trace/slow")?;
        let v = qpart::core::json::parse(&slow)
            .map_err(|e| format!("scrape-check: /trace/slow is not JSON: {e}"))?;
        v.req_arr("slow").map_err(|e| format!("scrape-check: /trace/slow shape: {e}"))?;
        println!(
            "scrape-check: {} _bucket series parse as cumulative counts, /trace/slow JSON OK",
            buckets.len()
        );
    }
    if let Some(path) = &trace_out {
        let json = handle.trace.chrome_trace_json();
        std::fs::write(path, &json).map_err(|e| format!("--trace-out {path}: {e}"))?;
        println!(
            "trace-out: wrote Chrome trace-event JSON ({} timelines, {} B) to {path}",
            handle.trace.stored(),
            json.len()
        );
    }
    // durable-store restart measurement: capture a cold control reply per
    // coalescing class, drain the loaded server (flushing the segment log
    // on the way down), bring a fresh process-equivalent up with
    // `--warm log`, and report restart-to-p50-warm — the time from
    // starting the new server until half the first wave has been served
    // off the replayed caches, byte-identical and without a single new
    // encode.
    if let Some(dir) = &store_dir {
        let mut control = Vec::with_capacity(keys);
        {
            let mut conn = BlockingConn::connect(&addr)?;
            for k in 0..keys {
                let mut req = paper_request(model, 0.02);
                req.channel_capacity_bps = 50e6 * (1 + k) as f64;
                control.push(checked_infer(&mut conn, &Request::Infer(req), retries)?);
            }
        }
        if !handle.drain(Duration::from_secs(10)) {
            return Err("store restart: drain timed out before the warm restart".into());
        }
        let t_up = Instant::now();
        let warm_handle = serve(qpart::coordinator::ServerConfig {
            listen: "127.0.0.1:0".into(),
            workers,
            queue_capacity: args.get_usize("queue", 1024)?,
            batch_window: Duration::from_micros((window_ms * 1000.0).max(0.0) as u64),
            cache_bytes,
            binary_frames: binary,
            frontend,
            max_conns: args.get_usize("max-conns", 4096)?,
            warm: WarmMode::Log,
            store_dir: Some(dir.clone()),
            host_fallback,
            artifacts_dir: artifacts_dir.to_string(),
            ..Default::default()
        })?;
        // worker 0 replays before reporting ready, so serve() returned
        // with the caches already populated — the counter is final here
        let warmed = warm_handle.snapshot().warmed_total;
        if warmed == 0 {
            warm_handle.shutdown();
            return Err(
                "store restart: warmed_total still 0 after `--warm log` replay (empty log?)"
                    .into(),
            );
        }
        let mut conn = BlockingConn::connect(&warm_handle.addr.to_string())?;
        let mut done_us = Vec::with_capacity(keys);
        for (k, cold) in control.iter().enumerate() {
            let mut req = paper_request(model, 0.02);
            req.channel_capacity_bps = 50e6 * (1 + k) as f64;
            let reply = checked_infer(&mut conn, &Request::Infer(req), retries)?;
            done_us.push(t_up.elapsed().as_micros() as u64);
            if reply.segment != cold.segment || reply.pattern != cold.pattern {
                warm_handle.shutdown();
                return Err(format!(
                    "store restart: warmed reply for class {k} differs from the cold control"
                ));
            }
        }
        let snap = warm_handle.snapshot();
        warm_handle.shutdown();
        if snap.encodes_total != 0 {
            return Err(format!(
                "store restart: first wave triggered {} fresh encodes — the replay did \
                 not warm the reply cache",
                snap.encodes_total
            ));
        }
        if snap.cache_hits == 0 || snap.decision_hits == 0 {
            return Err(format!(
                "store restart: first-wave hit counters are zero (reply {}, decision {})",
                snap.cache_hits, snap.decision_hits
            ));
        }
        // sequential wave ⇒ completion times are monotone; the p50 element
        // is when half the wave was warm-served
        let p50_warm_ms = done_us[(done_us.len() - 1) / 2] as f64 / 1000.0;
        println!(
            "store restart: {warmed} entries replayed from {dir}, restart→p50-warm \
             {p50_warm_ms:.1} ms, first wave {} reply hits / {} decision hits over {keys} \
             classes, 0 fresh encodes, replies byte-identical to cold control: OK",
            snap.cache_hits, snap.decision_hits,
        );
    } else {
        handle.shutdown();
    }
    Ok(summary.expect("two passes always ran"))
}

/// One infer round trip for the post-run identity checks. With fault
/// injection armed any single call may legitimately come back as an
/// `internal` error (the worker panicked and was respawned underneath
/// it), so allow retries — each eventual success doubles as proof the
/// server still serves after recovering from injected panics.
fn checked_infer(
    conn: &mut BlockingConn,
    req: &Request,
    retries: usize,
) -> Result<InferReply, String> {
    for _ in 0..=retries {
        match conn.call(req)? {
            Response::Segment(r) => return Ok(r),
            Response::Error(e) if e.code == "internal" && retries > 0 => continue,
            other => return Err(format!("unexpected response {other:?}")),
        }
    }
    Err("infer still failing after fault-injection retries".into())
}

/// One-shot HTTP/1.0 GET against the metrics listener; returns the body.
fn http_get(addr: &str, path: &str) -> Result<String, String> {
    let mut s = TcpStream::connect(addr).map_err(|e| format!("GET {path}: {e}"))?;
    s.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes())
        .map_err(|e| format!("GET {path}: {e}"))?;
    let mut buf = String::new();
    s.read_to_string(&mut buf).map_err(|e| format!("GET {path}: {e}"))?;
    match buf.split_once("\r\n\r\n") {
        Some((_, body)) => Ok(body.to_string()),
        None => Err(format!("GET {path}: malformed HTTP response")),
    }
}

// ---------------------------------------------------------------------------
// bench-serve --scenario: trace-driven fleet replay + chaos clients
// ---------------------------------------------------------------------------

/// Jain's fairness index over per-entity counts: `(Σx)² / (n·Σx²)` ∈ (0, 1],
/// 1.0 = perfectly even. NaN for an empty slice, 1.0 for all-zero.
fn jain_index(xs: &[u64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let sum: f64 = xs.iter().map(|&x| x as f64).sum();
    let sq: f64 = xs.iter().map(|&x| x as f64 * x as f64).sum();
    if sq == 0.0 {
        return 1.0;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// Chaos-injection modes parsed from `--chaos a,b,c`.
#[derive(Clone, Copy, Default)]
struct ChaosFlags {
    drop_mid_phase2: bool,
    garbage_frames: bool,
    slow_loris: bool,
    half_open: bool,
}

impl ChaosFlags {
    fn any_lingering(&self) -> bool {
        self.slow_loris || self.half_open
    }

    fn describe(&self) -> String {
        let mut on = Vec::new();
        if self.drop_mid_phase2 {
            on.push("drop-mid-phase2");
        }
        if self.garbage_frames {
            on.push("garbage-frames");
        }
        if self.slow_loris {
            on.push("slow-loris");
        }
        if self.half_open {
            on.push("half-open");
        }
        if on.is_empty() { "none".to_string() } else { on.join(",") }
    }
}

fn parse_chaos(spec: &str) -> Result<ChaosFlags, String> {
    let mut c = ChaosFlags::default();
    for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        match tok {
            "drop-mid-phase2" => c.drop_mid_phase2 = true,
            "garbage-frames" => c.garbage_frames = true,
            "slow-loris" => c.slow_loris = true,
            "half-open" => c.half_open = true,
            other => {
                return Err(format!(
                    "--chaos: unknown mode '{other}' (expected \
                     drop-mid-phase2, garbage-frames, slow-loris, half-open)"
                ))
            }
        }
    }
    Ok(c)
}

/// Spawn `n` lingering peers: each writes `probe` (a few bytes of a JSON
/// request for slow-loris, nothing for half-open) and then holds the
/// socket silently until the server's idle sweep closes it. Each handle
/// yields `true` when the server hung up within `patience`.
fn spawn_lingerers(addr: &str, n: usize, probe: &'static [u8], patience: Duration) -> Vec<JoinHandle<bool>> {
    (0..n)
        .map(|_| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut s = match TcpStream::connect(&addr) {
                    Ok(s) => s,
                    Err(_) => return false,
                };
                if !probe.is_empty() && s.write_all(probe).is_err() {
                    return false;
                }
                let _ = s.set_read_timeout(Some(patience));
                let mut buf = [0u8; 256];
                loop {
                    match s.read(&mut buf) {
                        Ok(0) => return true, // server closed: reaped
                        Ok(_) => continue,
                        Err(_) => return false, // patience exhausted first
                    }
                }
            })
        })
        .collect()
}

/// Build one damaged 0xB1 envelope for the garbage-frame fuzzer. Starts
/// from a well-formed frame (magic, u32 total, u32 header_len, JSON
/// header, blob) and corrupts it at an offset drawn across the
/// length-prefix / header / body boundary. Returns the bytes plus whether
/// the envelope is complete: a complete one must be answered with
/// `bad_frame` (the peer never sent a hello, so even an undamaged body
/// is refused at dispatch; length/header damage is refused earlier, at
/// the framing layer), while a truncated one is hung up mid-frame and
/// must be a quiet close, never a routed reply.
fn corrupt_binary_frame(rng: &mut qpart::core::rng::Rng) -> (Vec<u8>, bool) {
    let header = br#"{"type":"activation","session":1,"blob_len":64}"#;
    let blob = [0xABu8; 64];
    let total = (4 + header.len() + blob.len()) as u32;
    let mut frame = vec![0xB1u8];
    frame.extend_from_slice(&total.to_le_bytes());
    frame.extend_from_slice(&(header.len() as u32).to_le_bytes());
    frame.extend_from_slice(header);
    frame.extend_from_slice(&blob);
    let header_at = 9; // magic + total + header_len
    let blob_at = header_at + header.len();
    match (rng.uniform() * 6.0) as usize {
        0 => {
            // length prefix: total blown far past the 16 MiB frame cap
            let huge = u32::MAX - (rng.uniform() * 1e6) as u32;
            frame[1..5].copy_from_slice(&huge.to_le_bytes());
            (frame, true)
        }
        1 => {
            // length prefix: total too small to hold the header_len field
            let tiny = (rng.uniform() * 4.0) as u32;
            frame[1..5].copy_from_slice(&tiny.to_le_bytes());
            (frame[..5].to_vec(), true)
        }
        2 => {
            // header_len pointing past the end of the payload
            let past = total - 4 + 1 + (rng.uniform() * 100.0) as u32;
            frame[5..9].copy_from_slice(&past.to_le_bytes());
            (frame, true)
        }
        3 => {
            // header bytes: 0xFF is never valid UTF-8, so the JSON header
            // cannot decode no matter where it lands
            let at = header_at + (rng.uniform() * header.len() as f64) as usize;
            frame[at] = 0xFF;
            (frame, true)
        }
        4 => {
            // body bytes: the envelope stays well-formed, so this must
            // reach dispatch and be refused there (no hello was sent)
            let at = blob_at + (rng.uniform() * blob.len() as f64) as usize;
            frame[at] ^= 0xFF;
            (frame, true)
        }
        _ => {
            // truncation at a random offset, anywhere from mid-prefix to
            // one byte short of complete, followed by a hang-up
            let keep = 1 + (rng.uniform() * (frame.len() - 1) as f64) as usize;
            frame.truncate(keep);
            (frame, false)
        }
    }
}

/// Spawn `n` garbage-frame peers fuzzing the 0xB1 framing layer with
/// [`corrupt_binary_frame`] envelopes. The server must answer every
/// complete envelope with `bad_frame` — without disturbing any other
/// connection — and treat a truncated-then-dropped one as a quiet close.
/// Each handle yields the number of `bad_frame` replies it observed.
fn spawn_garbage_framers(addr: &str, n: usize, rounds: usize) -> Vec<JoinHandle<u64>> {
    (0..n)
        .map(|i| {
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut rng = qpart::core::rng::Rng::from_label(0xB1, &format!("garbage/{i}"));
                let mut seen = 0u64;
                for _ in 0..rounds {
                    let mut s = match TcpStream::connect(&addr) {
                        Ok(s) => s,
                        Err(_) => break,
                    };
                    let _ = s.set_read_timeout(Some(Duration::from_secs(10)));
                    let (frame, complete) = corrupt_binary_frame(&mut rng);
                    if s.write_all(&frame).is_err() {
                        continue;
                    }
                    if !complete {
                        continue; // hang up mid-frame
                    }
                    // read until the reply lands (body-corrupt frames keep
                    // the connection open, so don't wait for a close)
                    let mut buf = Vec::new();
                    let mut tmp = [0u8; 512];
                    while let Ok(k) = s.read(&mut tmp) {
                        if k == 0 {
                            break;
                        }
                        buf.extend_from_slice(&tmp[..k]);
                        if buf.contains(&b'\n') {
                            break;
                        }
                    }
                    if String::from_utf8_lossy(&buf).contains("bad_frame") {
                        seen += 1;
                    }
                }
                seen
            })
        })
        .collect()
}

/// What one replayed device brought back from a scenario run.
struct DeviceOutcome {
    class: String,
    lat_us: Vec<u64>,
    events: u64,
    shed: u64,
    throttled: u64,
    errors: u64,
    drops: u64,
    /// Dial attempts made by the backoff reconnect loop (first try
    /// included), across every redial this device performed.
    reconnects: u64,
}

/// Per-class aggregate for the scenario report table.
#[derive(Default)]
struct ClassAgg {
    devices: u64,
    events: u64,
    shed: u64,
    throttled: u64,
    reconnects: u64,
    lat_us: Vec<u64>,
    ok_per_device: Vec<u64>,
}

impl ClassAgg {
    fn absorb(&mut self, o: &DeviceOutcome) {
        self.devices += 1;
        self.events += o.events;
        self.shed += o.shed;
        self.throttled += o.throttled;
        self.reconnects += o.reconnects;
        self.lat_us.extend_from_slice(&o.lat_us);
        self.ok_per_device.push(o.lat_us.len() as u64);
    }

    fn table_row(&self, name: &str) -> Vec<String> {
        let mut lat = self.lat_us.clone();
        lat.sort_unstable();
        vec![
            name.to_string(),
            self.devices.to_string(),
            self.events.to_string(),
            lat.len().to_string(),
            self.shed.to_string(),
            self.throttled.to_string(),
            self.reconnects.to_string(),
            format!("{:.2}", quantile_us(&lat, 0.50) / 1000.0),
            format!("{:.2}", quantile_us(&lat, 0.99) / 1000.0),
            format!("{:.3}", jain_index(&self.ok_per_device)),
        ]
    }
}

/// Replay a declarative scenario through a live server: one thread per
/// device honoring the trace's arrival times, with optional chaos peers
/// attacking the front end while the fleet runs. Asserts the reactor's
/// survival invariants at the end: zero protocol errors, every chaos
/// connection reaped, and `conns_open` back to 0.
#[allow(clippy::too_many_lines)]
fn run_bench_scenario(
    args: &Args,
    artifacts_dir: &str,
    model: &str,
    synthetic: bool,
) -> Result<(), String> {
    let spec = args.get("scenario").expect("dispatch checked --scenario");
    // --scenario takes a builtin name, a declarative scenario file, or a
    // `trace v1` capture (e.g. written by `serve --record-trace`):
    // captures replay verbatim, scenarios generate their trace first
    let mut capture = None;
    let mut scenario = if Scenario::builtin_names().contains(&spec) {
        Some(Scenario::builtin(spec).expect("builtin scenario exists"))
    } else {
        let text =
            std::fs::read_to_string(spec).map_err(|e| format!("--scenario {spec}: {e}"))?;
        if text.starts_with("trace v1") {
            capture = Some(Trace::parse(&text)?);
            None
        } else {
            Some(Scenario::parse(&text)?)
        }
    };
    if let Some(sc) = &mut scenario {
        if args.get("clients").is_some() {
            sc.devices = args.get_usize("clients", sc.devices)?.max(1);
        }
    }
    let chaos = parse_chaos(args.get_or("chaos", ""))?;
    let time_scale = args.get_f64("time-scale", 1.0)?;
    let chaos_rate = args.get_f64("chaos-rate", 0.25)?;
    let brownout_us = (args.get_f64("brownout-ms", 0.0)?.max(0.0) * 1000.0) as u64;
    let faults = fault_inject_flag(args)?;
    let panics_armed = faults.map_or(false, |f| f.worker_panic > 0.0);
    let chaos_errors_ok = faults.map_or(false, |f| f.worker_panic > 0.0 || f.alloc_fail > 0.0);
    let phase2 = bool_flag(args, "phase2", synthetic)?;
    let host_fallback = bool_flag(args, "host-fallback", synthetic)?;
    let binary = bool_flag(args, "binary-frames", true)?;
    let fair_rate = args.get_f64("fair-rate", 0.0)?;
    let frontend = frontend_flag(args, Frontend::Reactor)?;
    let workers = args.get_usize("workers", 4)?;
    if bool_flag(args, "csv", false)? {
        std::env::set_var("QPART_BENCH_CSV", "1");
    }
    // chaos peers only die through these timeouts, so they default short
    let conn_idle = Duration::from_secs(args.get_usize(
        "conn-idle-secs",
        if chaos.any_lingering() { 2 } else { 600 },
    )? as u64);
    let session_ttl = Duration::from_secs(args.get_usize(
        "session-ttl",
        if chaos.drop_mid_phase2 { 2 } else { 600 },
    )? as u64);

    let bundle = Bundle::load(artifacts_dir).map_err(|e| e.to_string())?;
    let entry = bundle.model(model).map_err(|e| e.to_string())?;
    let arch = bundle.arch(&entry.arch).map_err(|e| e.to_string())?.clone();
    drop(bundle);

    let (name, seed, devices, n_phases, horizon_s, trace) = match scenario {
        Some(sc) => {
            let trace = sc.generate(&DeviceClass::default_fleet());
            (sc.name.clone(), sc.seed, sc.devices, sc.phases.len(), sc.total_duration_s(), trace)
        }
        None => {
            let trace = capture.expect("no scenario means a parsed capture");
            let devices = trace.events.iter().map(|e| e.device + 1).max().unwrap_or(0);
            let horizon = trace.events.last().map_or(0.0, |e| e.arrival_s);
            (format!("capture:{spec}"), 1u64, devices, 0usize, horizon, trace)
        }
    };
    if trace.events.is_empty() {
        return Err(format!("scenario '{name}' generated no events"));
    }
    let mut per_device: Vec<Vec<TraceEvent>> = vec![Vec::new(); devices];
    for e in &trace.events {
        per_device[e.device].push(e.clone());
    }
    println!(
        "bench-serve scenario '{name}': {n_phases} phases, {devices} devices, {} events \
         over {horizon_s:.2}s (time-scale {time_scale}), chaos [{}], fair-rate {fair_rate}, \
         frontend {frontend:?}",
        trace.events.len(),
        chaos.describe(),
    );

    let handle = serve(qpart::coordinator::ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers,
        queue_capacity: args.get_usize("queue", 1024)?,
        session_ttl,
        batch_window: Duration::from_micros(
            (args.get_f64("batch-window", 2.0)? * 1000.0).max(0.0) as u64,
        ),
        binary_frames: binary,
        frontend,
        max_conns: args.get_usize("max-conns", 4096)?,
        conn_idle,
        fair_rate,
        host_fallback,
        brownout_wait_us: brownout_us,
        fault_inject: faults,
        artifacts_dir: artifacts_dir.to_string(),
        ..Default::default()
    })?;
    let addr = handle.addr.to_string();
    if let Some(f) = &faults {
        println!(
            "fault-inject armed: worker-panic={} exec-delay-ms={} alloc-fail={}",
            f.worker_panic, f.exec_delay_ms, f.alloc_fail
        );
    }

    // chaos side-fleets attack while the scenario replays
    let scaled_run = Duration::from_secs_f64((horizon_s * time_scale).max(0.0));
    let patience = conn_idle + scaled_run + Duration::from_secs(20);
    let n_loris = if chaos.slow_loris { 32 } else { 0 };
    let n_half = if chaos.half_open { 16 } else { 0 };
    let n_garbage = if chaos.garbage_frames { 8 } else { 0 };
    let loris = spawn_lingerers(&addr, n_loris, b"{\"type\":\"pi", patience);
    let half = spawn_lingerers(&addr, n_half, b"", patience);
    let garbage = spawn_garbage_framers(&addr, n_garbage, 4);

    // one replay thread per device with traffic, all released together
    let replay_devices: Vec<usize> =
        (0..devices).filter(|&d| !per_device[d].is_empty()).collect();
    // class name -> fair-queuing weight, declared to the server in each
    // device's hello (classes outside the default fleet weigh 1.0)
    let class_weights: Arc<HashMap<String, f64>> = Arc::new(
        DeviceClass::default_fleet()
            .into_iter()
            .map(|c| (c.name.to_string(), c.weight))
            .collect(),
    );
    let barrier = Arc::new(Barrier::new(replay_devices.len()));
    let mut joins = Vec::with_capacity(replay_devices.len());
    for dev in replay_devices {
        let events = std::mem::take(&mut per_device[dev]);
        let addr = addr.clone();
        let model = model.to_string();
        let arch = arch.clone();
        let barrier = Arc::clone(&barrier);
        let class_weights = Arc::clone(&class_weights);
        joins.push(std::thread::spawn(move || -> Result<DeviceOutcome, String> {
            let class_name = events[0].class.clone();
            let mut out = DeviceOutcome {
                class: class_name.clone(),
                lat_us: Vec::new(),
                events: 0,
                shed: 0,
                throttled: 0,
                errors: 0,
                drops: 0,
                reconnects: 0,
            };
            let weight = class_weights.get(&out.class).copied().unwrap_or(1.0);
            // every device declares its class in the hello so the server's
            // per-class shed/throttle/degrade counters attribute correctly
            let negotiate = |conn: &mut BlockingConn| -> Result<bool, String> {
                let wants_binary = binary && dev % 2 == 1;
                let hello = Request::Hello(HelloRequest {
                    binary_frames: wants_binary,
                    weight,
                    class: class_name.clone(),
                    ..HelloRequest::default()
                });
                match conn.call(&hello)? {
                    Response::Hello(h) => Ok(h.binary_frames),
                    other => Err(format!("device {dev} hello: unexpected {other:?}")),
                }
            };
            // a device silent past --conn-idle-secs is legitimately reaped
            // by the idle sweep; like a real device it just dials back in —
            // with capped exponential backoff (10ms·2ⁿ capped at 250ms,
            // jittered) rather than hammering an overloaded accept queue
            let mut reconnect_attempts = 0u64;
            let mut backoff_rng =
                qpart::core::rng::Rng::from_label(seed, &format!("backoff/{dev}"));
            let mut reconnect =
                |conn: &mut BlockingConn, bin: &mut bool| -> Result<(), String> {
                    let mut last = String::new();
                    for attempt in 0u32..8 {
                        reconnect_attempts += 1;
                        let dial = BlockingConn::connect(&addr).and_then(|mut c| {
                            let b = negotiate(&mut c)?;
                            Ok((c, b))
                        });
                        match dial {
                            Ok((c, b)) => {
                                *conn = c;
                                *bin = b;
                                return Ok(());
                            }
                            Err(e) => last = e,
                        }
                        let cap_ms = 250u64.min(10u64 << attempt.min(6));
                        let jitter = backoff_rng.range_f64(0.5, 1.0);
                        std::thread::sleep(Duration::from_micros(
                            (cap_ms as f64 * 1000.0 * jitter) as u64,
                        ));
                    }
                    Err(format!("device {dev}: reconnect gave up after 8 attempts: {last}"))
                };
            let mut conn = BlockingConn::connect(&addr)?;
            let mut bin_session = negotiate(&mut conn)?;
            let mut chaos_rng =
                qpart::core::rng::Rng::from_label(seed, &format!("chaos/{dev}"));
            let mut seq = 0u64;
            barrier.wait();
            let t0 = Instant::now();
            for ev in &events {
                let target = Duration::from_secs_f64((ev.arrival_s * time_scale).max(0.0));
                let elapsed = t0.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
                out.events += 1;
                let mut req = paper_request(&model, ev.accuracy_budget);
                // channel fading: the phase's SNR scale shrinks capacity
                req.channel_capacity_bps *= ev.snr_scale;
                let t = Instant::now();
                let uploads = if phase2 { ev.phase2_uploads.max(1) } else { 0 };
                let infer_req = Request::Infer(req.clone());
                let mut reply = None;
                let mut completed = true;
                let resp = match conn.call(&infer_req) {
                    Ok(r) => r,
                    Err(_) => {
                        reconnect(&mut conn, &mut bin_session)?;
                        conn.call(&infer_req)?
                    }
                };
                match resp {
                    Response::Segment(r) => reply = Some(r),
                    Response::Error(e) if e.code == "overloaded" => {
                        out.shed += 1;
                        completed = false;
                    }
                    Response::Error(e) if e.code == "throttled" => {
                        out.throttled += 1;
                        completed = false;
                    }
                    Response::Error(e) => {
                        out.errors += 1;
                        eprintln!("device {dev}: {}: {}", e.code, e.message);
                        completed = false;
                    }
                    other => {
                        return Err(format!("device {dev}: unexpected response {other:?}"))
                    }
                }
                if completed {
                    'uploads: for u in 0..uploads {
                        // upload storms: every round consumes its session, so
                        // re-issue the (cache-hot) infer for each extra upload
                        if u > 0 {
                            match conn.call(&infer_req)? {
                                Response::Segment(r) => reply = Some(r),
                                Response::Error(e) if e.code == "overloaded" => {
                                    out.shed += 1;
                                    completed = false;
                                    break 'uploads;
                                }
                                Response::Error(e) if e.code == "throttled" => {
                                    out.throttled += 1;
                                    completed = false;
                                    break 'uploads;
                                }
                                Response::Error(e) => {
                                    out.errors += 1;
                                    eprintln!("device {dev}: {}: {}", e.code, e.message);
                                    completed = false;
                                    break 'uploads;
                                }
                                other => {
                                    return Err(format!(
                                        "device {dev}: unexpected response {other:?}"
                                    ))
                                }
                            }
                        }
                        if chaos.drop_mid_phase2 && chaos_rng.uniform() < chaos_rate {
                            // vanish mid-phase-2: the open session must be
                            // GC'd by the TTL sweep and any in-flight reply
                            // dropped by the generation check — never
                            // delivered to the replacement connection
                            reconnect(&mut conn, &mut bin_session)?;
                            out.drops += 1;
                            completed = false;
                            break 'uploads;
                        }
                        let r = reply.as_ref().expect("segment reply present");
                        let upload =
                            synthetic_upload(r, &arch, ((dev as u64) << 32) | seq);
                        seq += 1;
                        let resp = if bin_session {
                            conn.call_binary_upload(&upload)?
                        } else {
                            conn.call(&Request::Activation(upload))?
                        };
                        match resp {
                            Response::Result(_) => {}
                            Response::Error(e) if e.code == "overloaded" => {
                                out.shed += 1;
                                completed = false;
                                break 'uploads;
                            }
                            Response::Error(e) if e.code == "throttled" => {
                                out.throttled += 1;
                                completed = false;
                                break 'uploads;
                            }
                            Response::Error(e) => {
                                out.errors += 1;
                                eprintln!("device {dev} upload: {}: {}", e.code, e.message);
                                completed = false;
                                break 'uploads;
                            }
                            other => {
                                return Err(format!(
                                    "device {dev}: unexpected response {other:?}"
                                ))
                            }
                        }
                    }
                }
                if completed {
                    out.lat_us.push(t.elapsed().as_micros() as u64);
                }
            }
            out.reconnects = reconnect_attempts;
            Ok(out)
        }));
    }

    let mut outcomes = Vec::with_capacity(joins.len());
    for j in joins {
        outcomes.push(j.join().map_err(|_| "scenario device panicked".to_string())??);
    }
    let reaped_loris = loris.into_iter().filter(|h| h.join().unwrap_or(false)).count();
    let reaped_half = half.into_iter().filter(|h| h.join().unwrap_or(false)).count();
    let bad_frame_replies: u64 =
        garbage.into_iter().map(|h| h.join().unwrap_or(0)).sum();

    // per-class report + fleet-wide fairness
    let mut by_class: BTreeMap<String, ClassAgg> = BTreeMap::new();
    let mut fleet = ClassAgg::default();
    for o in &outcomes {
        by_class.entry(o.class.clone()).or_default().absorb(o);
        fleet.absorb(o);
    }
    let mut table = qpart_bench::Table::new(
        format!("bench-serve scenario {name} (model {model})"),
        &["class", "devices", "events", "ok", "shed", "throttled", "reconn", "p50 ms", "p99 ms", "jain"],
    );
    for (name, agg) in &by_class {
        table.row(agg.table_row(name));
    }
    table.row(fleet.table_row("all"));
    table.print();

    let errors: u64 = outcomes.iter().map(|o| o.errors).sum();
    let drops: u64 = outcomes.iter().map(|o| o.drops).sum();
    let final_snap = handle.snapshot();
    println!(
        "front-end: conns accepted {}, open peak {}, rejected {}, timed out {}, \
         throttled {}, sessions live {}",
        final_snap.conns_accepted_total,
        final_snap.conns_open_peak,
        final_snap.conns_rejected_total,
        final_snap.conns_timed_out,
        final_snap.sched_throttled_total,
        handle.sessions.len(),
    );
    if chaos.drop_mid_phase2 {
        println!("chaos: dropped {drops} connections mid-phase-2");
    }
    if chaos.any_lingering() {
        println!(
            "chaos: slow-loris reaped {reaped_loris}/{n_loris}, \
             half-open reaped {reaped_half}/{n_half}"
        );
    }
    if chaos.garbage_frames {
        println!("chaos: {bad_frame_replies} bad_frame replies to garbage frames");
    }

    // survival invariants — any failure fails the whole bench. With fault
    // injection armed, `internal` error replies are the expected recovery
    // signature of injected panics/alloc failures, not protocol errors.
    if errors > 0 && !chaos_errors_ok {
        return Err(format!("{errors} requests failed with protocol errors"));
    }
    if panics_armed {
        if final_snap.worker_restarts_total == 0 {
            return Err(
                "fault-inject: worker-panic armed but worker_restarts_total is 0 — \
                 no panic fired or the supervisor never respawned"
                    .into(),
            );
        }
        println!(
            "fault-inject: {} worker restarts after injected panics ({errors} requests \
             answered with error replies), fleet kept serving",
            final_snap.worker_restarts_total,
        );
    }
    if brownout_us > 0 {
        if final_snap.brownout_enters_total == 0 {
            return Err(
                "brownout: armed but never entered under load (raise load or lower --brownout-ms)"
                    .into(),
            );
        }
        // the controller must also step back down once the storm is over
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut level = final_snap.brownout_level;
        while level != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
            level = handle.snapshot().brownout_level;
        }
        if level != 0 {
            return Err(format!(
                "brownout: level still {level} after the scenario drained — never exited"
            ));
        }
        let calm = handle.snapshot();
        println!(
            "brownout: entered {}x, exited {}x, {} replies degraded within budget, \
             level back to 0",
            calm.brownout_enters_total, calm.brownout_exits_total, calm.degraded_total,
        );
    }
    if reaped_loris < n_loris || reaped_half < n_half {
        return Err(format!(
            "idle sweep leak: slow-loris reaped {reaped_loris}/{n_loris}, \
             half-open reaped {reaped_half}/{n_half}"
        ));
    }
    if chaos.any_lingering() && final_snap.conns_timed_out < (n_loris + n_half) as u64 {
        return Err(format!(
            "conns_timed_out {} < {} lingering chaos peers",
            final_snap.conns_timed_out,
            n_loris + n_half
        ));
    }
    // every garbage peer sends two oversized envelopes; each must be
    // answered with bad_frame, not a dropped reactor
    if chaos.garbage_frames && bad_frame_replies < n_garbage as u64 {
        return Err(format!(
            "garbage frames: only {bad_frame_replies} bad_frame replies \
             from {n_garbage} peers"
        ));
    }
    // zero-leak: every connection (devices + chaos) must be gone
    let deadline = Instant::now() + conn_idle + Duration::from_secs(20);
    let mut open = handle.snapshot().conns_open;
    while open != 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
        open = handle.snapshot().conns_open;
    }
    if open != 0 {
        return Err(format!("connection leak: {open} conns still open after scenario"));
    }
    // orphaned sessions from dropped connections must age out via the TTL
    if chaos.drop_mid_phase2 {
        let deadline = Instant::now() + session_ttl + Duration::from_secs(20);
        let mut live = handle.sessions.len();
        while live != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(50));
            live = handle.sessions.len();
        }
        if live != 0 {
            return Err(format!("session leak: {live} sessions still open after TTL"));
        }
    }
    let min_peak = args.get_usize("min-peak-conns", 0)?;
    if min_peak > 0 && final_snap.conns_open_peak < min_peak as u64 {
        return Err(format!(
            "front-end scaling: peak open connections {} < required {}",
            final_snap.conns_open_peak, min_peak
        ));
    }
    let errors_note =
        if chaos_errors_ok { " (expected under fault injection)" } else { "" };
    println!(
        "scenario '{name}' survived: {} ok / {} events, {errors} errors{errors_note}, \
         conns open 0",
        fleet.lat_us.len(),
        fleet.events,
    );
    handle.shutdown();
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<(), String> {
    let model_name = args.get_or("model", "mlp6");
    let arch = builtin(model_name).map_err(|e| e.to_string())?;
    let levels = [0.0025, 0.005, 0.01, 0.02, 0.05];
    // use the bundle calibration when available, else synthetic
    let artifacts = args.get_or("artifacts", "artifacts");
    let calib = Bundle::load(artifacts)
        .and_then(|b| b.calibration(model_name))
        .unwrap_or_else(|_| CalibrationTable::synthetic(&arch, &levels, 1));
    let patterns =
        offline_quantize(&arch, &calib, OfflineConfig::default()).map_err(|e| e.to_string())?;
    let cfg = FleetConfig {
        workload: WorkloadConfig {
            arrival_rate: args.get_f64("rate", 20.0)?,
            n_devices: args.get_usize("devices", 16)?,
            duration_s: args.get_f64("duration", 10.0)?,
            seed: args.get_usize("seed", 1)? as u64,
        },
        ..Default::default()
    };
    let report = run_fleet(&arch, &patterns, &DeviceClass::default_fleet(), &cfg)
        .map_err(|e| e.to_string())?;
    println!("{}", report.perf.to_json().to_string_pretty());
    println!(
        "rejected: {}, server cost: {:.4}, partitions: {:?}",
        report.rejected,
        report.server_cost,
        report.perf.partition_histogram(arch.num_layers())
    );
    Ok(())
}

fn cmd_offline(args: &Args) -> Result<(), String> {
    let model_name = args.get_or("model", "mlp6");
    let artifacts = args.get_or("artifacts", "artifacts");
    let (arch, calib) = match Bundle::load(artifacts) {
        Ok(b) => {
            let m = b.model(model_name).map_err(|e| e.to_string())?;
            let arch = b.arch(&m.arch).map_err(|e| e.to_string())?.clone();
            let calib = b.calibration(model_name).map_err(|e| e.to_string())?;
            (arch, calib)
        }
        Err(_) => {
            let arch = builtin(model_name).map_err(|e| e.to_string())?;
            let calib =
                CalibrationTable::synthetic(&arch, &[0.0025, 0.005, 0.01, 0.02, 0.05], 1);
            println!("(no artifacts bundle — using synthetic calibration)");
            (arch, calib)
        }
    };
    let set =
        offline_quantize(&arch, &calib, OfflineConfig::default()).map_err(|e| e.to_string())?;
    println!("offline pattern table for {model_name} (Algorithm 1):");
    for (k, row) in set.patterns.iter().enumerate() {
        println!("  accuracy level a={}", set.levels[k]);
        for pat in row {
            println!(
                "    p={:<2} bits={:?} b_x={} payload={} bits (f32: {}) predicted degradation {:.5}",
                pat.partition,
                pat.weight_bits,
                pat.activation_bits,
                pat.payload_bits(&arch),
                pat.payload_bits_f32(&arch),
                pat.predicted_degradation,
            );
        }
    }
    Ok(())
}

fn cmd_models(args: &Args) -> Result<(), String> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let bundle = Bundle::load(artifacts).map_err(|e| e.to_string())?;
    println!("{:<20} {:<12} {:<14} {:>8} {:>12} {:>9}", "model", "arch", "dataset", "layers", "params", "test acc");
    for m in &bundle.models {
        let arch = bundle.arch(&m.arch).map_err(|e| e.to_string())?;
        println!(
            "{:<20} {:<12} {:<14} {:>8} {:>12} {:>8.2}%",
            m.name,
            m.arch,
            m.dataset,
            arch.num_layers(),
            arch.total_params(),
            m.test_accuracy * 100.0
        );
    }
    Ok(())
}
