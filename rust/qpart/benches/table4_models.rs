//! **Table IV** — Accuracy on Baseline Models and Datasets.
//!
//! Paper: across SVHN / CIFAR10 / CIFAR100 / ResNet18 / ResNet34, QPART
//! compresses the communication payload to 11.88–18.12 % of the initial
//! parameter size with 0.08–0.66 % accuracy degradation.
//!
//! Here: the runnable instances (edgecnn×3, tinyresnet, + mlp6) are
//! evaluated with **real quantized inference** over their synthetic test
//! sets; ResNet18/34 are descriptor-only (payload columns, synthetic
//! calibration) since ImageNet is unavailable offline (DESIGN.md §3).

mod common;

use common::*;
use qpart::prelude::*;
use qpart_bench::Table;
use std::sync::Arc;

fn mb(bits: u64) -> f64 {
    bits as f64 / 8.0 / 1e6
}

fn main() {
    let bundle = load_bundle();
    banner("Table IV — payload compression + accuracy across models", bundle.is_some());

    let mut table = Table::new(
        "per-model compression and measured degradation (a = 1% level)",
        &[
            "model", "dataset", "initial (MB)", "optimized (MB)", "ratio",
            "initial acc", "QPART acc", "degradation",
        ],
    );

    if let Some(bundle) = &bundle {
        let mut ex = Executor::new(Arc::clone(bundle)).unwrap();
        for entry in bundle.models.clone() {
            let arch = bundle.arch(&entry.arch).unwrap().clone();
            let calib = bundle.calibration(&entry.name).unwrap();
            let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
            let l = *arch.partition_points.last().unwrap();
            let pat = patterns
                .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: l })
                .unwrap()
                .clone();
            let w_bits: u64 = (1..=l)
                .map(|i| (pat.weight_bits[i - 1] as u64) * arch.weight_params(i))
                .sum();
            let f32_bits = arch.segment_weight_bits_f32(l);

            let (x, y) = bundle.dataset(&entry.dataset).unwrap();
            let x = HostTensor::from(x);
            let n = std::env::var("QPART_TABLE4_N")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(256usize)
                .min(x.batch());
            let xs = x.slice_rows(0, n);
            let ys = &y[..n];
            let base = ex
                .eval_accuracy(&xs, ys, |e, c| Ok(e.run_full(&entry.name, c)?))
                .unwrap();
            let acc = ex
                .eval_accuracy(&xs, ys, |e, c| {
                    Ok(e.run_split(&entry.name, &pat, c)?.logits)
                })
                .unwrap();
            table.row(vec![
                entry.name.clone(),
                entry.dataset.clone(),
                format!("{:.2}", mb(f32_bits)),
                format!("{:.2}", mb(w_bits)),
                format!("{:.2}%", 100.0 * w_bits as f64 / f32_bits as f64),
                format!("{:.2}%", base * 100.0),
                format!("{:.2}%", acc * 100.0),
                format!("{:.2}%", (base - acc) * 100.0),
            ]);
        }
    } else {
        println!("(runnable-model rows skipped: run `make artifacts`)");
    }

    // descriptor-only ImageNet ResNets (payload columns)
    for depth in [18usize, 34] {
        let arch = qpart::core::model::resnet_descriptor(depth).unwrap();
        let calib = CalibrationTable::synthetic(&arch, &LEVELS, depth as u64);
        let patterns = offline_quantize(&arch, &calib, OfflineConfig::default()).unwrap();
        let l = arch.num_layers();
        let pat = patterns
            .get(qpart::core::quant::PatternKey { level_idx: LEVEL_1PCT, partition: l })
            .unwrap();
        let w_bits: u64 = (1..=l)
            .map(|i| (pat.weight_bits[i - 1] as u64) * arch.weight_params(i))
            .sum();
        let f32_bits = arch.segment_weight_bits_f32(l);
        table.row(vec![
            format!("resnet{depth} (descriptor)"),
            "imagenet (n/a)".into(),
            format!("{:.2}", mb(f32_bits)),
            format!("{:.2}", mb(w_bits)),
            format!("{:.2}%", 100.0 * w_bits as f64 / f32_bits as f64),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    table.print();
    println!(
        "\npaper row: compression ratio 11.88–18.12 %, degradation 0.08–0.66 % \
         (SVHN 13.45 / CIFAR10 11.88 / CIFAR100 13.53 / R18 17.60 / R34 18.12)."
    );
}
