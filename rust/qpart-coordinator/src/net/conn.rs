//! Per-connection state for the reactor: what used to live on a
//! connection thread's stack (read buffer, partial frame, negotiated
//! framing, the reply in flight) is an explicit [`Conn`] struct the
//! reactor owns in a slab.
//!
//! A `Conn` is a plain state machine over a nonblocking socket:
//!
//! * **inbound** — [`Conn::fill`] appends whatever the socket has into
//!   `rbuf`; [`Conn::next_frame`] splits complete frames off the front
//!   (`qpart_proto::frame::split_frame`, the incremental twin of the
//!   blocking reader, so framing is byte-identical to the threaded
//!   front-end).
//! * **outbound** — replies are serialized into the [`Outbox`] (a chunk
//!   queue with a byte count) and flushed as far as the socket allows;
//!   leftovers wait for `POLLOUT`. A chunk is either owned bytes (frame
//!   heads, small replies) or a shared `Arc<[u8]>` body (cache-hit
//!   segment payloads queued with zero copies); a multi-chunk flush
//!   gathers them into one `writev(2)` so the split costs no extra
//!   syscalls. The outbox **is** the backpressure signal: a connection
//!   with a deep outbox or an in-flight job is not polled for reads, so
//!   a fast producer/slow consumer peer stalls at the TCP layer instead
//!   of growing server memory.
//! * **lifecycle** — `last_activity` advances on every byte moved in
//!   either direction; the reactor idle-times-out connections with no
//!   activity and nothing in flight (slow-loris / half-open peers).
//!   `closing` marks "flush the outbox, then close" (fatal frame errors,
//!   metrics scrapes).

use super::sys::{writev_stream, IoVec};
use crate::metrics::ClassCounts;
use crate::obs::JobTrace;
use qpart_proto::frame::{split_frame, Frame, FrameError};
use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::os::unix::io::AsRawFd;
use std::sync::Arc;
use std::time::Instant;

/// Bytes read from a socket per `read(2)` call.
const READ_CHUNK: usize = 16 * 1024;

/// Per-`fill` ceiling: a firehose peer must not starve the other
/// connections of a level-triggered reactor tick (leftover bytes simply
/// re-report readable on the next poll).
const MAX_FILL_BYTES: usize = 256 * 1024;

/// Flavor of an accepted connection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConnKind {
    /// A QPART protocol peer: JSON lines + negotiated binary frames.
    Proto,
    /// A plaintext metrics scrape: the path-routed response is queued
    /// once the request line arrives, remaining inbound bytes are
    /// discarded, the connection closes once flushed.
    Metrics,
}

/// Iovec entries per `writev(2)` call: far below any IOV_MAX, and a
/// deeper outbox just writevs again on the same flush.
const WRITEV_BATCH: usize = 64;

/// One queued egress buffer.
#[derive(Debug)]
enum Chunk {
    /// Bytes this connection owns (frame heads, stamped headers, small
    /// replies).
    Owned(Vec<u8>),
    /// A reference-counted body shared with the encoded-reply cache and
    /// every other connection currently sending it — queued without
    /// copying, written to the socket straight from where it lives.
    Shared(Arc<[u8]>),
}

impl Chunk {
    fn as_slice(&self) -> &[u8] {
        match self {
            Chunk::Owned(v) => v,
            Chunk::Shared(a) => a,
        }
    }
}

/// Queued outbound bytes with a running total (the backpressure signal
/// and the `outbox_bytes` gauge source).
#[derive(Debug, Default)]
pub struct Outbox {
    chunks: VecDeque<Chunk>,
    /// Bytes of the front chunk already written.
    head: usize,
    bytes: usize,
    /// Bytes written to the socket straight out of [`Chunk::Shared`]
    /// bodies — egress that never passed through a per-connection copy.
    zero_copy_bytes: u64,
}

impl Outbox {
    pub fn push(&mut self, chunk: Vec<u8>) {
        if chunk.is_empty() {
            return;
        }
        self.bytes += chunk.len();
        self.chunks.push_back(Chunk::Owned(chunk));
    }

    /// Queue a shared body without copying it.
    pub fn push_shared(&mut self, chunk: Arc<[u8]>) {
        if chunk.is_empty() {
            return;
        }
        self.bytes += chunk.len();
        self.chunks.push_back(Chunk::Shared(chunk));
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    /// Drain the zero-copy byte count accumulated since the last call
    /// (the reactor credits it to `outbox_zero_copy_bytes_total`).
    pub fn take_zero_copy_bytes(&mut self) -> u64 {
        std::mem::take(&mut self.zero_copy_bytes)
    }

    /// Write as much as the socket accepts; returns bytes written this
    /// call. A lone chunk goes through a plain `write`; a split reply
    /// (owned head + shared body) gathers up to [`WRITEV_BATCH`] chunks
    /// into one `writev(2)`. `WouldBlock` stops quietly (wait for
    /// `POLLOUT`); real I/O errors propagate so the caller closes the
    /// connection.
    fn write_to(&mut self, w: &mut TcpStream) -> io::Result<usize> {
        let mut written = 0usize;
        while !self.chunks.is_empty() {
            let n = if self.chunks.len() == 1 {
                let front = self.chunks.front().expect("chunks is non-empty");
                match w.write(&front.as_slice()[self.head..]) {
                    Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                    Ok(n) => n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                }
            } else {
                let mut iovs = [IoVec::new(&[]); WRITEV_BATCH];
                let mut cnt = 0usize;
                for chunk in self.chunks.iter().take(WRITEV_BATCH) {
                    let slice = chunk.as_slice();
                    iovs[cnt] = IoVec::new(if cnt == 0 { &slice[self.head..] } else { slice });
                    cnt += 1;
                }
                match writev_stream(w.as_raw_fd(), &iovs[..cnt]) {
                    Ok(0) => return Err(io::ErrorKind::WriteZero.into()),
                    Ok(n) => n,
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) => return Err(e),
                }
            };
            self.advance(n);
            written += n;
        }
        Ok(written)
    }

    /// Account `n` bytes as written: pop spent chunks, credit the bytes
    /// that came out of shared bodies.
    fn advance(&mut self, mut n: usize) {
        self.bytes -= n;
        while n > 0 {
            let front = self.chunks.front().expect("advance past end of outbox");
            let len = front.as_slice().len();
            let step = n.min(len - self.head);
            if matches!(front, Chunk::Shared(_)) {
                self.zero_copy_bytes += step as u64;
            }
            self.head += step;
            n -= step;
            if self.head == len {
                self.chunks.pop_front();
                self.head = 0;
            }
        }
    }
}

/// One accepted connection owned by the reactor.
#[derive(Debug)]
pub struct Conn {
    pub stream: TcpStream,
    pub kind: ConnKind,
    /// Unparsed inbound bytes (partial frames live here, not on a
    /// thread stack).
    rbuf: Vec<u8>,
    pub outbox: Outbox,
    /// Negotiated binary framing (`hello`), symmetric as ever.
    pub binary: bool,
    /// Jobs submitted to the pool whose replies have not routed back.
    /// The protocol is request→reply per connection, so this is 0 or 1:
    /// pipelined bytes wait in `rbuf` (and then in the kernel buffer)
    /// until the pending reply is on the wire — exactly the pacing a
    /// connection thread imposed by blocking on the reply channel.
    pub in_flight: usize,
    pub last_activity: Instant,
    /// Flush the outbox, then close (fatal framing error, scrape done).
    pub closing: bool,
    /// The peer sent EOF. Requests already buffered in `rbuf` are still
    /// served (a BufReader-backed connection thread does the same — it
    /// drains its buffer before noticing the close); the connection
    /// closes once nothing is buffered, in flight, or unflushed.
    pub peer_eof: bool,
    /// Any inbound byte was ever seen. Metrics scrapes close only after
    /// the response is flushed AND this is set (or the peer is gone):
    /// closing while the scraper's request is still in flight would
    /// leave it unread in the receive queue, and the resulting RST can
    /// destroy the response on non-loopback paths.
    pub saw_input: bool,
    /// Trace identity for this connection's requests: minted at accept
    /// when the sampler fires, or granted (echo on the wire) when the
    /// peer's `hello` asks for tracing. `None` = untraced — every trace
    /// branch in the reactor is one `Option` check, so the disabled path
    /// does no extra work and writes byte-identical frames.
    pub trace: Option<JobTrace>,
    /// Sink-relative µs of the first inbound byte of the request being
    /// assembled; taken on the first `fill` after the previous frame
    /// completed, cleared when the frame dispatches (the read span's
    /// start). Only maintained while `trace` is set.
    pub read_mark: Option<u64>,
    /// Replies pushed into the outbox whose flush span is still open:
    /// `(trace, pushed_us)`. Drained into `flush` spans once the outbox
    /// empties (the span covers queue-in-outbox + socket write time).
    pub pending_flush: Vec<(JobTrace, u64)>,
    /// Metrics conns only: the path-routed response has been queued.
    /// The response is deferred until the HTTP request line arrives (or
    /// the peer closes), so `/trace` endpoints can be routed by path.
    pub responded: bool,
    /// Per-device-class counters resolved once from the `hello`'s
    /// `class` label (see [`crate::metrics::ClassRegistry`]); `None` for
    /// unlabeled peers. Jobs submitted by this connection carry a clone,
    /// so throttle/shed/degrade attribution is a field read per event.
    pub class: Option<Arc<ClassCounts>>,
}

impl Conn {
    pub fn new(stream: TcpStream, kind: ConnKind) -> Conn {
        Conn {
            stream,
            kind,
            rbuf: Vec::new(),
            outbox: Outbox::default(),
            binary: false,
            in_flight: 0,
            last_activity: Instant::now(),
            closing: false,
            peer_eof: false,
            saw_input: false,
            trace: None,
            read_mark: None,
            pending_flush: Vec::new(),
            responded: false,
            class: None,
        }
    }

    /// Pull everything currently readable (bounded per call) into
    /// `rbuf`. An orderly EOF sets `peer_eof` — bytes read before it
    /// stay buffered and will still be parsed. `Err` = broken peer.
    pub fn fill(&mut self) -> io::Result<()> {
        let mut chunk = [0u8; READ_CHUNK];
        let mut pulled = 0usize;
        while pulled < MAX_FILL_BYTES {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.peer_eof = true;
                    return Ok(());
                }
                Ok(n) => {
                    self.rbuf.extend_from_slice(&chunk[..n]);
                    self.last_activity = Instant::now();
                    self.saw_input = true;
                    pulled += n;
                    if n < chunk.len() {
                        break; // socket drained
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Split the next complete frame off `rbuf`, if one is buffered.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, FrameError> {
        match split_frame(&self.rbuf)? {
            Some((frame, consumed)) => {
                self.rbuf.drain(..consumed);
                Ok(Some(frame))
            }
            None => Ok(None),
        }
    }

    /// Whether `rbuf` holds bytes that might form further frames.
    pub fn has_buffered_input(&self) -> bool {
        !self.rbuf.is_empty()
    }

    /// Bytes of unparsed buffered input (caps request-line buffering on
    /// metrics conns).
    pub fn buffered_len(&self) -> usize {
        self.rbuf.len()
    }

    /// First complete buffered line, if one has arrived (metrics conns:
    /// the HTTP request line, parsed for path routing).
    pub fn head_line(&self) -> Option<String> {
        let end = self.rbuf.iter().position(|&b| b == b'\n')?;
        Some(String::from_utf8_lossy(&self.rbuf[..end]).into_owned())
    }

    /// Throw away buffered input (metrics scrapes: the request bytes are
    /// irrelevant and must not accumulate).
    pub fn discard_input(&mut self) {
        self.rbuf.clear();
    }

    /// Flush the outbox as far as the socket allows.
    pub fn flush(&mut self) -> io::Result<()> {
        let n = self.outbox.write_to(&mut self.stream)?;
        if n > 0 {
            self.last_activity = Instant::now();
        }
        Ok(())
    }

    pub fn wants_write(&self) -> bool {
        !self.outbox.is_empty()
    }

    /// Read interest: an idle protocol conn that is not drowning in
    /// unflushed replies. Metrics conns stay readable even while closing
    /// so a scraper's request bytes are drained before the close (unread
    /// bytes at close would RST the response off the wire).
    pub fn wants_read(&self, outbox_pause_bytes: usize) -> bool {
        if self.peer_eof {
            return false;
        }
        if self.kind == ConnKind::Metrics {
            return true;
        }
        if self.closing {
            return false;
        }
        self.in_flight == 0 && self.outbox.bytes() < outbox_pause_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outbox_tracks_bytes_across_chunks() {
        let mut o = Outbox::default();
        assert!(o.is_empty());
        o.push(vec![1, 2, 3]);
        o.push(Vec::new()); // ignored
        o.push(vec![4; 5]);
        let shared: Arc<[u8]> = vec![7u8; 4].into();
        o.push_shared(Arc::clone(&shared));
        o.push_shared(Vec::new().into()); // ignored
        assert_eq!(o.bytes(), 12);
        assert!(!o.is_empty());
        assert_eq!(o.take_zero_copy_bytes(), 0, "nothing written yet");
    }

    #[test]
    fn advance_credits_only_shared_bytes() {
        let mut o = Outbox::default();
        o.push(b"head".to_vec());
        o.push_shared(b"shared-body".to_vec().into());
        o.push(b"tail".to_vec());
        // partial write ending mid-shared-chunk
        o.advance(9); // 4 owned + 5 shared
        assert_eq!(o.bytes(), 10);
        assert_eq!(o.take_zero_copy_bytes(), 5);
        // the rest
        o.advance(10); // 6 shared + 4 owned
        assert!(o.is_empty());
        assert_eq!(o.bytes(), 0);
        assert_eq!(o.take_zero_copy_bytes(), 6);
        assert_eq!(o.take_zero_copy_bytes(), 0, "drained");
    }

    #[test]
    fn shared_chunks_flush_byte_identical_through_writev() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(server_side, ConnKind::Proto);
        let body: Arc<[u8]> = b"SHARED-BODY-BYTES".to_vec().into();
        conn.outbox.push(b"head:".to_vec());
        conn.outbox.push_shared(Arc::clone(&body));
        conn.outbox.push(b":tail\n".to_vec());
        conn.flush().unwrap();
        assert!(conn.outbox.is_empty());
        assert_eq!(conn.outbox.take_zero_copy_bytes(), body.len() as u64);
        let mut got = vec![0u8; 5 + body.len() + 6];
        let mut r = client;
        r.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"head:SHARED-BODY-BYTES:tail\n");
    }

    #[test]
    fn outbox_flushes_through_a_socket() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(server_side, ConnKind::Proto);
        conn.outbox.push(b"hello ".to_vec());
        conn.outbox.push(b"world\n".to_vec());
        conn.flush().unwrap();
        assert!(conn.outbox.is_empty());
        assert!(!conn.wants_write());
        let mut got = vec![0u8; 12];
        let mut r = client;
        r.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"hello world\n");
    }

    #[test]
    fn fill_and_split_reassemble_partial_frames() {
        use std::io::Write;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mut client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_nonblocking(true).unwrap();
        let mut conn = Conn::new(server_side, ConnKind::Proto);
        // half a frame: readable, but no frame yet
        client.write_all(b"{\"type\":\"pi").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.fill().unwrap();
        assert!(!conn.peer_eof);
        assert!(conn.next_frame().unwrap().is_none());
        assert!(conn.has_buffered_input());
        // the rest, plus a second pipelined frame
        client.write_all(b"ng\"}\n{\"type\":\"stats\"}\n").unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.fill().unwrap();
        assert_eq!(conn.next_frame().unwrap(), Some(Frame::Json("{\"type\":\"ping\"}".into())));
        assert_eq!(conn.next_frame().unwrap(), Some(Frame::Json("{\"type\":\"stats\"}".into())));
        assert_eq!(conn.next_frame().unwrap(), None);
        // orderly EOF is a flag, not a hard stop: buffered bytes survive
        drop(client);
        std::thread::sleep(std::time::Duration::from_millis(20));
        conn.fill().unwrap();
        assert!(conn.peer_eof);
        assert!(!conn.wants_read(1 << 20), "no reads after EOF");
    }

    #[test]
    fn read_interest_respects_inflight_and_backpressure() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let _client = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        let mut conn = Conn::new(server_side, ConnKind::Proto);
        assert!(conn.wants_read(1024));
        conn.in_flight = 1;
        assert!(!conn.wants_read(1024), "request in flight: pipelined bytes can wait");
        conn.in_flight = 0;
        conn.outbox.push(vec![0u8; 2048]);
        assert!(!conn.wants_read(1024), "deep outbox: stop reading, let TCP push back");
        conn.closing = true;
        assert!(!conn.wants_read(1 << 30));
    }
}
