//! Durable-store restart tests — no PJRT required (synthetic bundle +
//! host reference kernels).
//!
//! The kill-and-restart contract of the store tier, end to end over TCP:
//! a server run with `--store-dir` spills its committed cache entries to
//! the append-only segment log; a fresh server over the same directory
//! started with `--warm log` replays them and serves its first requests
//! straight off the replayed caches — hit counters nonzero before any
//! new encode, replies byte-identical to a cold-start control.

use qpart_coordinator::client::paper_request;
use qpart_coordinator::testing::{synthetic_bundle, BlockingConn};
use qpart_coordinator::{serve, ServerConfig, ServerHandle, WarmMode};
use qpart_proto::messages::{InferReply, Request, Response};
use std::path::{Path, PathBuf};
use std::time::Duration;

/// The channel classes driven before the kill and probed after the
/// restart (distinct capacities → distinct decision-cache buckets).
const CLASSES: [f64; 3] = [50e6, 100e6, 200e6];

fn store_server(artifacts: &Path, store_dir: &Path, warm: WarmMode) -> ServerHandle {
    serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        warm,
        store_dir: Some(store_dir.to_str().unwrap().to_string()),
        host_fallback: true,
        artifacts_dir: artifacts.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .unwrap()
}

fn infer(conn: &mut BlockingConn, capacity_bps: f64) -> InferReply {
    let mut req = paper_request("tinymlp", 0.02);
    req.channel_capacity_bps = capacity_bps;
    match conn.call(&Request::Infer(req)).unwrap() {
        Response::Segment(r) => r,
        other => panic!("unexpected {other:?}"),
    }
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qpart-sr-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The full cycle: load → drain (flushes the log) → restart with
/// `--warm log` → first wave is all cache hits, byte-identical replies.
#[test]
fn restart_with_warm_log_serves_replayed_entries_byte_identically() {
    let artifacts = synthetic_bundle("sr-cycle");
    let store_dir = scratch("cycle");

    // generation 1: drive every class twice (the second round proves the
    // keys are cacheable at all), remember the reply bytes
    let first = store_server(&artifacts, &store_dir, WarmMode::Off);
    let mut conn = BlockingConn::connect(&first.addr.to_string()).unwrap();
    let control: Vec<InferReply> = CLASSES.iter().map(|&c| infer(&mut conn, c)).collect();
    for (i, &c) in CLASSES.iter().enumerate() {
        let again = infer(&mut conn, c);
        assert_eq!(again.segment, control[i].segment, "class {i}: repeat differs in-process");
    }
    drop(conn);
    let gen1 = first.snapshot();
    assert!(gen1.encodes_total >= 1, "{gen1:?}");
    assert!(first.drain(Duration::from_secs(10)), "generation 1 must drain cleanly");

    // generation 2: `--warm log` replays before serve() returns
    let second = store_server(&artifacts, &store_dir, WarmMode::Log);
    let warm = second.snapshot();
    assert!(warm.warmed_total > 0, "replay warmed nothing: {warm:?}");
    assert_eq!(warm.encodes_total, 0, "replay must not encode");
    assert!(second.cache.len() >= 1, "encoded replies resident before traffic");

    // first post-restart wave: every class is a hit on both caches, with
    // zero fresh encodes, and the bytes match generation 1 exactly
    let mut conn = BlockingConn::connect(&second.addr.to_string()).unwrap();
    for (i, &c) in CLASSES.iter().enumerate() {
        let r = infer(&mut conn, c);
        assert_eq!(r.segment, control[i].segment, "class {i}: replayed bytes differ");
        assert_eq!(r.pattern, control[i].pattern, "class {i}: replayed decision differs");
    }
    drop(conn);
    let snap = second.snapshot();
    assert_eq!(snap.encodes_total, 0, "first wave re-encoded: {snap:?}");
    assert!(snap.cache_hits >= CLASSES.len() as u64, "{snap:?}");
    assert!(snap.decision_hits >= CLASSES.len() as u64, "{snap:?}");
    second.shutdown();

    // cold-start control from an empty store: same requests, same bytes —
    // the replayed replies are what a fresh process would have computed
    let cold_dir = scratch("cycle-cold");
    let cold = store_server(&artifacts, &cold_dir, WarmMode::Off);
    let mut conn = BlockingConn::connect(&cold.addr.to_string()).unwrap();
    for (i, &c) in CLASSES.iter().enumerate() {
        let r = infer(&mut conn, c);
        assert_eq!(r.segment, control[i].segment, "class {i}: cold-start bytes differ");
    }
    drop(conn);
    cold.shutdown();

    let _ = std::fs::remove_dir_all(&artifacts);
    let _ = std::fs::remove_dir_all(&store_dir);
    let _ = std::fs::remove_dir_all(&cold_dir);
}

/// A second kill-and-restart over the same directory keeps compounding:
/// generation 3 replays what generations 1–2 wrote, and the log survives
/// a restart that itself added nothing new.
#[test]
fn repeated_restarts_keep_replaying_the_same_log() {
    let artifacts = synthetic_bundle("sr-repeat");
    let store_dir = scratch("repeat");

    let first = store_server(&artifacts, &store_dir, WarmMode::Off);
    let mut conn = BlockingConn::connect(&first.addr.to_string()).unwrap();
    let control = infer(&mut conn, CLASSES[0]);
    drop(conn);
    assert!(first.drain(Duration::from_secs(10)));

    let mut expected_warm = None;
    for generation in 2..=3 {
        let server = store_server(&artifacts, &store_dir, WarmMode::Log);
        let warmed = server.snapshot().warmed_total;
        assert!(warmed > 0, "generation {generation} warmed nothing");
        // idle generations write nothing, so the replayed count is stable
        match expected_warm {
            None => expected_warm = Some(warmed),
            Some(w) => assert_eq!(warmed, w, "generation {generation} replay count drifted"),
        }
        let mut conn = BlockingConn::connect(&server.addr.to_string()).unwrap();
        let r = infer(&mut conn, CLASSES[0]);
        assert_eq!(r.segment, control.segment, "generation {generation} bytes differ");
        drop(conn);
        assert!(server.drain(Duration::from_secs(10)));
    }

    let _ = std::fs::remove_dir_all(&artifacts);
    let _ = std::fs::remove_dir_all(&store_dir);
}

/// `--warm log` without a `--store-dir` is a configuration error, caught
/// at startup rather than silently serving cold.
#[test]
fn warm_log_without_store_dir_fails_fast() {
    let artifacts = synthetic_bundle("sr-nolog");
    let err = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        warm: WarmMode::Log,
        host_fallback: true,
        artifacts_dir: artifacts.to_str().unwrap().to_string(),
        ..ServerConfig::default()
    })
    .err()
    .expect("warm log with no store must be rejected");
    assert!(err.contains("store_dir"), "{err}");
    let _ = std::fs::remove_dir_all(&artifacts);
}
