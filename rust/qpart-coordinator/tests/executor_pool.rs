//! Executor-pool integration tests — no PJRT required.
//!
//! These tests build a **synthetic artifact bundle** (a small MLP with
//! real weight/calibration/dataset files but zero HLO executables) in a
//! temp directory. The coordinator's phase-1 path — Algorithm 2 decision,
//! segment quantization, bit-packing, session open — is pure Rust, so a
//! real multi-worker server can be driven end-to-end over TCP in any
//! offline environment. Only phase-2 execution (PJRT) needs `make
//! artifacts`, and is covered by `rust/qpart/tests/integration.rs`.

use qpart_coordinator::client::paper_request;
use qpart_coordinator::{serve, ServerConfig};
use qpart_core::accuracy::CalibrationTable;
use qpart_core::json::Value;
use qpart_core::model::{LayerKind, LayerSpec, ModelSpec};
use qpart_core::tensor::{save_i32, Tensor};
use qpart_proto::frame::{read_frame, write_frame};
use qpart_proto::messages::{ActivationUpload, Request, Response};
use std::collections::HashSet;
use std::io::BufReader;
use std::net::TcpStream;
use std::path::PathBuf;

const LEVELS: [f64; 5] = [0.0025, 0.005, 0.01, 0.02, 0.05];

fn lin(name: &str, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
    LayerSpec { name: name.into(), kind: LayerKind::Linear { d_in, d_out }, relu }
}

fn tiny_arch() -> ModelSpec {
    ModelSpec::new(
        "tinymlp",
        vec![lin("fc1", 256, 512, true), lin("fc2", 512, 256, true), lin("fc3", 256, 10, false)],
        10,
    )
    .unwrap()
}

/// Write a loadable bundle: manifest + weights + calibration + dataset,
/// with an empty executables list (nothing here needs PJRT).
fn write_synthetic_bundle(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qpart-pool-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    for sub in ["weights/tinymlp", "calibration", "data"] {
        std::fs::create_dir_all(dir.join(sub)).unwrap();
    }
    let arch = tiny_arch();

    let mut rng = qpart_core::rng::Rng::new(7);
    for (i, layer) in arch.layers.iter().enumerate() {
        let (d_in, d_out) = match layer.kind {
            LayerKind::Linear { d_in, d_out } => (d_in, d_out),
            _ => unreachable!("tinymlp is linear-only"),
        };
        let w = Tensor::new(
            vec![d_in, d_out],
            (0..d_in * d_out).map(|_| rng.range_f64(-0.5, 0.5) as f32).collect(),
        )
        .unwrap();
        let b = Tensor::new(
            vec![d_out],
            (0..d_out).map(|_| rng.range_f64(-0.1, 0.1) as f32).collect(),
        )
        .unwrap();
        w.save(dir.join(format!("weights/tinymlp/l{}_w.qt", i + 1))).unwrap();
        b.save(dir.join(format!("weights/tinymlp/l{}_b.qt", i + 1))).unwrap();
    }

    let calib = CalibrationTable::synthetic(&arch, &LEVELS, 1);
    std::fs::write(dir.join("calibration/tinymlp.json"), calib.to_json().to_string_pretty())
        .unwrap();

    Tensor::zeros(vec![4, 256]).save(dir.join("data/synth_test_x.qt")).unwrap();
    save_i32(dir.join("data/synth_test_y.qt"), &[4], &[0, 1, 2, 3]).unwrap();

    let manifest = Value::obj([
        ("archs", Value::Arr(vec![arch.to_json()])),
        (
            "models",
            Value::Arr(vec![Value::obj([
                ("name", "tinymlp".into()),
                ("arch", "tinymlp".into()),
                ("dataset", "synth".into()),
                ("weights_dir", "weights/tinymlp".into()),
                ("calibration", "calibration/tinymlp.json".into()),
                ("test_accuracy", 0.9.into()),
            ])]),
        ),
        ("executables", Value::Arr(vec![])),
        (
            "datasets",
            Value::Arr(vec![Value::obj([
                ("name", "synth".into()),
                ("x", "data/synth_test_x.qt".into()),
                ("y", "data/synth_test_y.qt".into()),
                ("n", 4usize.into()),
                ("classes", 10usize.into()),
            ])]),
        ),
        ("levels", Value::num_arr(&LEVELS)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string_pretty()).unwrap();
    dir
}

/// Minimal blocking protocol connection (no PJRT-backed DeviceClient).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn connect(addr: &str) -> Conn {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Conn { writer: stream.try_clone().unwrap(), reader: BufReader::new(stream) }
    }

    fn call(&mut self, req: &Request) -> Response {
        write_frame(&mut self.writer, &req.to_line()).unwrap();
        Response::from_line(&read_frame(&mut self.reader).unwrap()).unwrap()
    }
}

#[test]
fn pool_spreads_concurrent_load_over_distinct_workers() {
    let dir = write_synthetic_bundle("load");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 4,
        queue_capacity: 128,
        session_capacity: 1024,
        artifacts_dir: dir.to_str().unwrap().to_string(),
    })
    .expect("pool server starts on the synthetic bundle");
    let addr = handle.addr.to_string();

    let clients = 8usize;
    let per_client = 8usize;
    let mut joins = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        joins.push(std::thread::spawn(move || {
            let mut conn = Conn::connect(&addr);
            let mut sessions = Vec::new();
            for i in 0..per_client {
                let mut req = paper_request("tinymlp", 0.02);
                // distinct live channels → the full Algorithm 2 +
                // quantize + pack path runs under varied decisions
                req.channel_capacity_bps = 1e6 * (1 + c * 7 + i) as f64;
                match conn.call(&Request::Infer(req)) {
                    Response::Segment(r) => {
                        assert_eq!(r.pattern.weight_bits.len(), r.pattern.partition);
                        sessions.push(r.session);
                    }
                    other => panic!("client {c} request {i}: unexpected {other:?}"),
                }
            }
            sessions
        }));
    }
    let mut all_sessions = HashSet::new();
    for j in joins {
        for s in j.join().unwrap() {
            assert!(all_sessions.insert(s), "duplicate session id {s}");
        }
    }
    let total = (clients * per_client) as u64;
    assert_eq!(all_sessions.len() as u64, total);

    // per-worker metrics aggregate into ONE logical snapshot...
    let snap = handle.snapshot();
    assert_eq!(snap.requests_total, total);
    assert_eq!(snap.errors_total, 0);
    assert_eq!(snap.sessions_opened, total);
    assert_eq!(snap.handle_count, total);

    // ...and the concurrent load really was serviced by >1 executor
    let per_worker = handle.worker_snapshots();
    assert_eq!(per_worker.len(), 4);
    let counts: Vec<u64> = per_worker.iter().map(|w| w.handle_count).collect();
    assert_eq!(counts.iter().sum::<u64>(), total, "per-worker counts must sum to the total");
    let active = counts.iter().filter(|&&c| c > 0).count();
    assert!(active >= 2, "all requests landed on one worker: {counts:?}");

    // the wire-level stats view is the aggregate, with per-worker detail
    let mut conn = Conn::connect(&addr);
    match conn.call(&Request::Stats) {
        Response::Stats(v) => {
            // the stats request itself is counted before it reports
            assert_eq!(v.req_f64("requests_total").unwrap() as u64, total + 1);
            assert_eq!(v.req_arr("workers").unwrap().len(), 4);
            assert_eq!(v.req_f64("open_sessions").unwrap() as u64, total);
            assert_eq!(v.req_f64("session_shards").unwrap() as u64, 4);
        }
        other => panic!("unexpected stats response {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sessions_opened_by_one_worker_are_visible_to_all() {
    let dir = write_synthetic_bundle("sessions");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 2,
        queue_capacity: 32,
        session_capacity: 64,
        artifacts_dir: dir.to_str().unwrap().to_string(),
    })
    .unwrap();
    let addr = handle.addr.to_string();

    let mut opener = Conn::connect(&addr);
    let mut uploader = Conn::connect(&addr);
    for i in 0..8 {
        let reply = match opener.call(&Request::Infer(paper_request("tinymlp", 0.05))) {
            Response::Segment(r) => r,
            other => panic!("request {i}: unexpected {other:?}"),
        };
        // Deliberately wrong dims: whichever worker handles phase 2, it
        // must FIND the session (bad_activation), never unknown_session —
        // that is the sharded-table-shared-across-workers contract.
        let upload = ActivationUpload {
            session: reply.session,
            bits: 8,
            qmin: 0.0,
            step: 0.01,
            dims: vec![9, 9],
            packed: vec![0u8; 81],
        };
        match uploader.call(&Request::Activation(upload)) {
            Response::Error(e) => {
                assert_eq!(e.code, "bad_activation", "request {i}: {}", e.message)
            }
            other => panic!("request {i}: unexpected {other:?}"),
        }
    }

    // a session id that never existed resolves the same way on any worker
    let upload = ActivationUpload {
        session: 9_999_999,
        bits: 8,
        qmin: 0.0,
        step: 0.01,
        dims: vec![1, 1],
        packed: vec![0u8; 1],
    };
    match uploader.call(&Request::Activation(upload)) {
        Response::Error(e) => assert_eq!(e.code, "unknown_session"),
        other => panic!("unexpected {other:?}"),
    }
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn single_worker_pool_still_serves() {
    // workers = 1 reproduces the classic dedicated-inference-thread
    // topology; the protocol surface must be identical.
    let dir = write_synthetic_bundle("single");
    let handle = serve(ServerConfig {
        listen: "127.0.0.1:0".into(),
        workers: 1,
        queue_capacity: 8,
        session_capacity: 16,
        artifacts_dir: dir.to_str().unwrap().to_string(),
    })
    .unwrap();
    let mut conn = Conn::connect(&handle.addr.to_string());
    assert!(matches!(conn.call(&Request::Ping), Response::Pong));
    match conn.call(&Request::ListModels) {
        Response::Models(ms) => {
            assert_eq!(ms.len(), 1);
            assert_eq!(ms[0].name, "tinymlp");
            assert_eq!(ms[0].layers, 3);
        }
        other => panic!("unexpected {other:?}"),
    }
    match conn.call(&Request::Infer(paper_request("tinymlp", 0.02))) {
        Response::Segment(r) => assert!(r.session > 0),
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(handle.worker_snapshots().len(), 1);
    assert_eq!(handle.snapshot().errors_total, 0);
    handle.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
