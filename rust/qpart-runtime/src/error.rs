//! Runtime error type (hand-rolled `Display`/`Error` impls — this build is
//! fully offline, so `thiserror` is not available).

use std::fmt;

/// Errors from artifact loading / PJRT execution.
#[derive(Debug)]
pub enum Error {
    /// Propagated qpart-core error (JSON schema, tensor format, ...).
    Core(qpart_core::Error),

    /// XLA / PJRT failure (compile or execute).
    Xla(String),

    /// Requested executable is not in the bundle.
    MissingExec(String),

    /// Model / dataset / arch not present in the manifest.
    NotInBundle(String),

    /// Shape mismatch between artifacts and runtime inputs.
    Shape(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // transparent: a propagated core error keeps its own message
            Error::Core(e) => write!(f, "{e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::MissingExec(m) => write!(f, "no executable: {m}"),
            Error::NotInBundle(m) => write!(f, "not in bundle: {m}"),
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // transparent wrapper: forward to the core error's own cause
            // (mirrors thiserror's #[error(transparent)] semantics)
            Error::Core(e) => std::error::Error::source(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<qpart_core::Error> for Error {
    fn from(e: qpart_core::Error) -> Self {
        Error::Core(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
