"""L1 Pallas kernel: fused dequantize -> matmul -> bias -> ReLU.

This is QPART's device-side compute hot-spot: every layer of the shipped
model segment runs with bit-packed weights that must be dequantized
(`w = mu + code * delta`, paper Eq. 9) before the matmul. Fusing the
dequantization into the matmul's operand load means the dequantized f32
weights never round-trip to HBM — on TPU the integer codes stream
HBM->VMEM, the VPU applies the affine map on the tile, and the MXU consumes
it directly (DESIGN.md §4, Hardware-Adaptation).

Tiling: the grid walks (G-blocks, D-blocks) with the D axis innermost;
partial products accumulate in the output VMEM block. Block sizes are the
largest divisors of D/G below the MXU-friendly 256/128 targets so BlockSpec
never needs masking. Convolutions reach this kernel through im2col at L2
(`ref.im2col`), the standard systolic-array formulation.

NOTE: lowered with `interpret=True` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; structure (tiling, fusion, accumulator reuse) is what
we optimize, real-TPU numbers are estimated in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

# MXU-friendly tile targets; actual blocks are the largest divisors <= these.
# Perf note (EXPERIMENTS.md §Perf): under interpret=True each grid step pays
# fixed interpreter overhead, so larger tiles (fewer steps) cut device-segment
# latency ~2x; 512-wide tiles keep the per-step VMEM residency (~1.7 MiB for
# the worst zoo layer) far below a 16 MiB TPU core, so the structure remains
# valid for real-TPU lowering.
_TARGET_D = 512
_TARGET_G = 512
_TARGET_ROWS = 256


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (>=1)."""
    best = 1
    for cand in range(1, min(dim, target) + 1):
        if dim % cand == 0:
            best = cand
    return best


def _kernel(x_ref, c_ref, qmin_ref, step_ref, b_ref, o_ref, *, n_d: int, relu: bool):
    """One (rows, Gblk) output tile; accumulates over the D grid axis."""
    d = pl.program_id(2)
    # Dequantize the code tile in registers/VMEM and feed the MXU directly.
    w = qmin_ref[0, 0] + c_ref[...] * step_ref[0, 0]
    part = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(d == 0)
    def _init():
        o_ref[...] = part + b_ref[...]

    @pl.when(d != 0)
    def _acc():
        o_ref[...] += part

    if relu:
        @pl.when(d == n_d - 1)
        def _act():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("relu",))
def qlinear(x, codes, qmin, step, bias, relu: bool = False):
    """Fused dequant-matmul. Shapes match :func:`ref.qlinear_ref`:

    x [B, D] f32, codes [D, G] f32 (integer-valued), qmin/step [1,1] f32,
    bias [1, G] f32 -> [B, G] f32.
    """
    b, d = x.shape
    d2, g = codes.shape
    assert d == d2, f"x {x.shape} vs codes {codes.shape}"
    rows_blk = _block(b, _TARGET_ROWS)
    d_blk = _block(d, _TARGET_D)
    g_blk = _block(g, _TARGET_G)
    n_rows, n_d, n_g = b // rows_blk, d // d_blk, g // g_blk

    return pl.pallas_call(
        functools.partial(_kernel, n_d=n_d, relu=relu),
        grid=(n_rows, n_g, n_d),  # D innermost: accumulate into o_ref
        in_specs=[
            pl.BlockSpec((rows_blk, d_blk), lambda r, gg, dd: (r, dd)),
            pl.BlockSpec((d_blk, g_blk), lambda r, gg, dd: (dd, gg)),
            pl.BlockSpec((1, 1), lambda r, gg, dd: (0, 0)),
            pl.BlockSpec((1, 1), lambda r, gg, dd: (0, 0)),
            pl.BlockSpec((1, g_blk), lambda r, gg, dd: (0, gg)),
        ],
        out_specs=pl.BlockSpec((rows_blk, g_blk), lambda r, gg, dd: (r, gg)),
        out_shape=jax.ShapeDtypeStruct((b, g), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, codes, qmin, step, bias)


@functools.partial(jax.jit, static_argnames=("relu", "k", "stride"))
def qconv(x, codes, qmin, step, bias, relu: bool, k: int, stride: int):
    """Quantized conv: L2 im2col + the fused L1 matmul kernel.

    x [B, C_in, H, W]; codes [C_in*k*k, C_out]; bias [1, C_out]
    -> [B, C_out, H', W'] ('SAME' padding).
    """
    cols, (b, hp, wp) = _ref.im2col(x, k, stride)
    y = qlinear(cols, codes, qmin, step, bias, relu=relu)
    c_out = y.shape[1]
    return y.reshape(b, hp, wp, c_out).transpose(0, 3, 1, 2)


def vmem_footprint_bytes(b: int, d: int, g: int) -> dict:
    """Estimated per-grid-step VMEM residency of `qlinear` (DESIGN.md §8):
    x tile + code tile + dequantized tile + bias tile + output accumulator,
    all f32. Used by the perf report, not by execution."""
    rows_blk = _block(b, _TARGET_ROWS)
    d_blk = _block(d, _TARGET_D)
    g_blk = _block(g, _TARGET_G)
    tiles = {
        "x_tile": rows_blk * d_blk * 4,
        "code_tile": d_blk * g_blk * 4,
        "dequant_tile": d_blk * g_blk * 4,
        "bias_tile": g_blk * 4,
        "out_tile": rows_blk * g_blk * 4,
    }
    tiles["total"] = sum(tiles.values())
    tiles["blocks"] = (rows_blk, d_blk, g_blk)
    return tiles
